//! Drive the standard analysis graph from a live source.
//!
//! [`run_live_pipeline`] is the on-line twin of
//! [`crate::analysis::run_pipeline`]: the same
//! [`PipelineDriver`](crate::analysis::PipelineDriver) (interval filter +
//! sink fan-out) fed from a blocking [`LiveSource`] instead of a parsed
//! trace, so every existing [`AnalysisSink`] runs unmodified while the
//! application executes. Optionally, sinks that implement
//! [`AnalysisSink::refresh`] are snapshotted on a period for interim
//! reports (`iprof --live --refresh <ms>`).

use super::source::{LatencySummary, LiveSource};
use crate::analysis::{AnalysisSink, PipelineDriver, Report};
use std::time::{Duration, Instant};

/// What a live pipeline run produced.
#[derive(Debug)]
pub struct LivePipelineResult {
    /// One final [`Report`] per sink, in sink order (same contract as
    /// `run_pipeline`).
    pub reports: Vec<Report>,
    /// Merge latency summary: how stale each message was when analyzed.
    pub latency: LatencySummary,
}

/// Run every sink on-line from `source` until the hub closes.
///
/// `refresh` enables periodic interim reports: each time the period
/// elapses (checked as messages flow), every sink's
/// [`AnalysisSink::refresh`] snapshot is handed to `on_refresh`. Sinks
/// that return `None` (the default) are skipped. Refresh is
/// message-driven: a completely idle stream produces no interim output,
/// which also means no lock-step wakeups compete with the merge.
pub fn run_live_pipeline<S>(
    mut source: LiveSource,
    sinks: &mut [Box<S>],
    refresh: Option<Duration>,
    mut on_refresh: impl FnMut(&str),
) -> LivePipelineResult
where
    S: AnalysisSink + ?Sized,
{
    let mut driver = PipelineDriver::new();
    let telemetry = source.hub().telemetry().clone();
    let mut last_refresh = Instant::now();
    for msg in source.by_ref() {
        driver.feed(&msg, sinks);
        if let Some(period) = refresh {
            if last_refresh.elapsed() >= period {
                last_refresh = Instant::now();
                let swept = Instant::now();
                for s in sinks.iter_mut() {
                    if let Some(report) = s.refresh() {
                        if let Some(text) = report.payload() {
                            on_refresh(text);
                        }
                    }
                }
                telemetry.sink_refresh.inc();
                telemetry
                    .sink_refresh_ns
                    .add(swept.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
            }
        }
    }
    let reports = driver.finish(sinks);
    LivePipelineResult { reports, latency: source.latency().clone() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::channel::LiveHub;
    use crate::tracer::btf::DecodedClass;
    use std::sync::Arc;

    fn msg(name: &str, ts: u64) -> crate::analysis::EventMsg {
        crate::analysis::EventMsg {
            ts,
            rank: 0,
            tid: 0,
            hostname: Arc::from("pipetest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: name.into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn live_pipeline_pairs_intervals_and_reports() {
        let hub = LiveHub::new("pipetest", 64, false);
        hub.ensure_channels(1);
        hub.push_batch(
            0,
            vec![
                msg("lttng_ust_ze:zeInit_entry", 10),
                msg("lttng_ust_ze:zeInit_exit", 30),
            ],
        );
        hub.close_all();
        let mut sinks: Vec<Box<dyn AnalysisSink>> =
            vec![Box::new(crate::analysis::TallySink::new())];
        let out = run_live_pipeline(LiveSource::new(hub), &mut sinks, None, |_| {});
        assert_eq!(out.reports.len(), 1);
        let text = out.reports[0].payload().unwrap();
        assert!(text.contains("zeInit"), "tally must contain the paired span: {text}");
        assert_eq!(out.latency.merged, 2);
    }

    #[test]
    fn refresh_snapshots_reach_the_callback() {
        let hub = LiveHub::new("pipetest", 64, false);
        hub.ensure_channels(1);
        let batch: Vec<_> = (0..40)
            .flat_map(|i| {
                vec![
                    msg("lttng_ust_ze:zeInit_entry", i * 10),
                    msg("lttng_ust_ze:zeInit_exit", i * 10 + 5),
                ]
            })
            .collect();
        hub.push_batch(0, batch);
        hub.close_all();
        let mut sinks: Vec<Box<dyn AnalysisSink>> =
            vec![Box::new(crate::analysis::TallySink::new())];
        let mut snapshots = 0;
        let out = run_live_pipeline(
            LiveSource::new(hub),
            &mut sinks,
            Some(Duration::ZERO), // every message qualifies
            |text| {
                assert!(text.contains("Time(%)"));
                snapshots += 1;
            },
        );
        assert!(snapshots > 0, "refresh must fire");
        assert_eq!(out.reports.len(), 1);
    }
}
