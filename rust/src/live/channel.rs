//! The live hub: bounded per-stream message channels with watermarks.
//!
//! One [`LiveHub`] sits between the tracing consumer thread and the live
//! analysis pipeline (the lttng-live relay analogue). Each traced stream
//! gets one bounded FIFO channel; the consumer decodes ring records as it
//! drains them and *try-pushes* the resulting [`EventMsg`]s — if a channel
//! is full the message is **dropped and counted**, never blocking the
//! consumer and therefore never back-pressuring the traced application
//! (paper §3.1 invariant, extended end to end).
//!
//! Each channel also carries a **watermark**: a timestamp lower bound for
//! every message the channel will deliver in the future. Watermarks
//! advance implicitly with every pushed event (per-stream timestamps are
//! non-decreasing) and explicitly through **beacons** — the LTTng-live
//! trick for quiet streams: the consumer periodically publishes "this
//! stream is quiet up to T" so the k-way merge can advance global time
//! without waiting on a stream that may never speak again.
//!
//! The hub is deliberately a single `Mutex<HubState>` + `Condvar`: the
//! consumer pushes whole drain batches under one short lock, the merge
//! ([`super::source::LiveSource`]) scans channel heads under the same
//! lock, and blocked producers/consumers park on the shared condvar.
//!
//! # Origins (multi-publisher namespacing)
//!
//! A hub can also act as the shared mirror of **several** remote
//! publishers (`iprof attach <addr> <addr>...`, see
//! [`crate::remote::fanin`]). Each publisher registers as an **origin**
//! ([`LiveHub::register_origin`]) and gets its own translation table from
//! *remote* stream ids to *shared* channel indices — two publishers that
//! both call their first stream "0" can never alias onto one channel.
//! Blocks are allocated in origin order at handshake time
//! ([`LiveHub::ensure_origin_channels`]), so the shared index order is
//! exactly the concatenation of the publishers' stream sets — which is
//! what makes the fan-in merge byte-identical to a single local `--live`
//! run over that concatenation. Late-registering remote streams append at
//! the end of the shared space (same tie-break caveat as any
//! late-registering local stream). Per-origin accounting
//! ([`LiveHub::origin_stats`]) keeps publisher-side drop totals separate
//! and **saturating** — a hostile or wrapped counter can never roll a
//! drop total back to "lossless".

use crate::analysis::msg::EventMsg;
use crate::tracer::btf::{registry_classes, DecodedClass};
use crate::tracer::encoder::decode_payload;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One entry in a channel queue: arrival sequence (merge tie-break),
/// the decoded message, and the push instant (latency accounting).
pub(super) struct Entry {
    pub(super) seq: u64,
    pub(super) msg: EventMsg,
    pub(super) pushed: Instant,
}

/// Per-stream channel state.
pub(super) struct Channel {
    pub(super) queue: VecDeque<Entry>,
    /// Arrival counter (monotone per channel).
    next_seq: u64,
    /// Lower bound on the timestamp of every future message.
    pub(super) watermark: u64,
    /// No further messages will ever arrive.
    pub(super) closed: bool,
    /// Messages accepted.
    received: u64,
    /// Messages dropped because the queue was full.
    dropped: u64,
    /// Beacons observed.
    beacons: u64,
}

impl Channel {
    fn new() -> Self {
        Channel {
            queue: VecDeque::new(),
            next_seq: 0,
            watermark: 0,
            closed: false,
            received: 0,
            dropped: 0,
            beacons: 0,
        }
    }
}

/// One registered remote publisher whose streams are namespaced into
/// this hub's shared channel index space (see module docs § Origins).
struct OriginState {
    /// Display label (usually the publisher's hostname).
    label: String,
    /// Remote stream index → shared channel index.
    map: Vec<usize>,
    /// Latest cumulative publisher-side drop count per remote stream
    /// (monotone: a stale or rewound wire value never lowers it).
    remote_drops: Vec<u64>,
    /// Events irrecoverably lost to resume gaps (`ResumeGap` frames:
    /// the publisher's replay ring evicted them before the subscriber
    /// reconnected). Saturating; see [`LiveHub::record_origin_gap`].
    resume_gaps: u64,
    /// Publisher-side hub totals from its Eos frame, if one arrived.
    eos: Option<(u64, u64)>,
    /// All of this origin's channels have been closed.
    closed: bool,
}

/// Per-origin accounting snapshot (see [`LiveHub::origin_stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OriginStats {
    /// Origin label (publisher hostname).
    pub label: String,
    /// Shared channels mapped to this origin.
    pub channels: usize,
    /// Messages accepted into this origin's channels (for a lossless
    /// fan-in feed: events merged from this publisher once drained).
    pub received: u64,
    /// Messages dropped at this origin's channels (always 0 for the
    /// lossless fan-in feed; nonzero only for local try-push use).
    pub dropped: u64,
    /// Beacons applied to this origin's channels.
    pub beacons: u64,
    /// Publisher-side cumulative drops reported over the wire —
    /// saturating sum of the latest per-stream counters.
    pub remote_dropped: u64,
    /// Events lost to resume gaps: the publisher replay-ring evicted
    /// them before a reconnecting subscriber could fetch them. Nonzero
    /// means the resumed view is incomplete by exactly this many events
    /// (`--live-strict` fails on it).
    pub resume_gaps: u64,
    /// Publisher-side Eos totals `(received, dropped)`, if the origin
    /// ended cleanly; `None` means the publisher died before Eos.
    pub eos: Option<(u64, u64)>,
    /// Every channel of this origin has closed.
    pub closed: bool,
}

pub(super) struct HubState {
    pub(super) channels: Vec<Channel>,
    /// Registered remote publishers (empty for purely local hubs).
    origins: Vec<OriginState>,
    /// Set by [`LiveHub::close_all`]: no new channels will appear.
    pub(super) sealed: bool,
}

impl HubState {
    /// THE release predicate of the live merge: a candidate at timestamp
    /// `ts` may be released iff every *empty* channel has closed or
    /// watermarked **strictly** past it (a watermark of exactly `ts`
    /// still admits a future equal-timestamp message that may sort
    /// earlier by stream index). [`super::source::LiveSource`] releases
    /// through this, and [`LiveHub::feed_remote`] waits through it — one
    /// definition, so the strict `>` byte-identity rule cannot drift
    /// between the two.
    pub(super) fn releasable(&self, ts: u64) -> bool {
        self.channels
            .iter()
            .all(|ch| !ch.queue.is_empty() || ch.closed || ch.watermark > ts)
    }

    /// Is at least one queued message releasable right now? (The head
    /// with the minimum timestamp is releasable iff any is.) Used by
    /// [`LiveHub::feed_remote`] to wait for queue space only when the
    /// merge is provably able to make progress.
    pub(super) fn has_releasable(&self) -> bool {
        let mut min_ts: Option<u64> = None;
        for ch in &self.channels {
            if let Some(e) = ch.queue.front() {
                min_ts = Some(min_ts.map_or(e.msg.ts, |b| b.min(e.msg.ts)));
            }
        }
        min_ts.map(|ts| self.releasable(ts)).unwrap_or(false)
    }
}

/// Cursor a remote forwarder keeps between [`LiveHub::next_forward_batch`]
/// calls: what has already been announced to the subscriber, so each
/// batch carries only the delta.
#[derive(Debug, Default)]
pub struct ForwardCursor {
    /// Channel count already announced.
    announced: usize,
    /// Per-channel last-forwarded state.
    per: Vec<ChannelCursor>,
}

impl ForwardCursor {
    /// Reset the delta baseline for a NEW subscriber connection that
    /// already knows about `announced` channels (its Hello said so):
    /// per-channel watermark/drop/close state is zeroed so the next
    /// [`LiveHub::next_forward_batch`] re-reports the *current* hub
    /// state in full. Watermarks and drop counters are monotone and
    /// closes idempotent on the subscriber, so re-reporting is always
    /// safe — this is how a resumed session resynchronizes everything
    /// that is not an event (events replay from the publisher's ring
    /// instead, see `crate::remote::publish`).
    pub fn resync(&mut self, announced: usize) {
        self.announced = announced;
        self.per.clear();
    }
}

#[derive(Debug, Default, Clone)]
struct ChannelCursor {
    watermark: u64,
    dropped: u64,
    closed: bool,
}

/// One round of forwardable progress popped from a hub — everything a
/// remote publisher must relay to keep a subscriber's mirror hub
/// equivalent. Events come out in per-stream FIFO order (the order the
/// consumer pushed them), which is all the subscriber's merge needs.
#[derive(Debug, Default)]
pub struct ForwardBatch {
    /// The channel set grew to this count (announce before the events).
    pub grown_to: Option<usize>,
    /// Popped messages as `(channel index, message)`.
    pub events: Vec<(usize, EventMsg)>,
    /// Channels whose watermark advanced, with the new watermark.
    pub beacons: Vec<(usize, u64)>,
    /// Channels whose drop count grew, with the new cumulative count.
    pub drops: Vec<(usize, u64)>,
    /// Channels that closed since the last batch.
    pub closed: Vec<usize>,
}

impl ForwardBatch {
    fn is_empty(&self) -> bool {
        self.grown_to.is_none()
            && self.events.is_empty()
            && self.beacons.is_empty()
            && self.drops.is_empty()
            && self.closed.is_empty()
    }
}

/// Aggregate live-transport statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LiveStats {
    /// Channels (one per traced stream).
    pub channels: usize,
    /// Messages accepted into channels.
    pub received: u64,
    /// Messages dropped at full channels (backpressure policy).
    pub dropped: u64,
    /// Beacons published.
    pub beacons: u64,
}

/// The live transport hub (see module docs).
///
/// # Examples
///
/// A miniature hub: one event on channel 0, channel 1 quiet — the
/// beacon and the close let the [`super::source::LiveSource`] merge
/// release past the quiet stream:
///
/// ```
/// use thapi::live::{LiveHub, LiveSource};
///
/// let hub = LiveHub::new("docnode", 64, false);
/// hub.ensure_channels(2);
/// let class = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
/// let msg = hub.decode(0, 0, class.id, 42, &0u64.to_le_bytes()).unwrap();
/// hub.push_batch(0, vec![msg]);
/// hub.beacon(1, 100); // stream 1 promises: nothing earlier than t=100
/// hub.close_all();
/// let merged: Vec<u64> = LiveSource::new(hub).map(|m| m.ts).collect();
/// assert_eq!(merged, vec![42]);
/// ```
pub struct LiveHub {
    pub(super) inner: Mutex<HubState>,
    pub(super) progress: Condvar,
    /// Per-channel queue bound, in messages.
    depth: usize,
    /// Also retain raw drained bytes in the session streams (memory-sink
    /// behaviour), so the same run can be re-analyzed post-mortem.
    retain: bool,
    /// Decoded-class table (registry metadata roundtrip) for on-line decode.
    classes: HashMap<u32, Arc<DecodedClass>>,
    /// Hostname stamped on decoded messages.
    hostname: Arc<str>,
}

impl std::fmt::Debug for LiveHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveHub")
            .field("depth", &self.depth)
            .field("retain", &self.retain)
            .field("hostname", &self.hostname)
            .finish_non_exhaustive()
    }
}

impl LiveHub {
    /// Create a hub for a session on `hostname` with the given per-stream
    /// channel `depth`. With `retain`, the consumer keeps the raw drained
    /// bytes as well (like the memory sink), so the identical run can also
    /// be analyzed post-mortem — used by the equivalence tests; production
    /// live mode runs with `retain = false` and O(streams × depth) memory.
    pub fn new(hostname: &str, depth: usize, retain: bool) -> Arc<LiveHub> {
        Arc::new(LiveHub {
            inner: Mutex::new(HubState {
                channels: Vec::new(),
                origins: Vec::new(),
                sealed: false,
            }),
            progress: Condvar::new(),
            depth: depth.max(1),
            retain,
            classes: registry_classes(),
            hostname: Arc::from(hostname),
        })
    }

    /// Per-stream channel bound, in messages.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Whether raw drained bytes are also retained for post-mortem use.
    pub fn retain(&self) -> bool {
        self.retain
    }

    /// Decode one raw ring record into a message, using the hub's
    /// registry-derived class table (`None` for unknown class ids, same
    /// policy as `parse_trace`).
    pub fn decode(&self, rank: u32, tid: u32, id: u32, ts: u64, payload: &[u8]) -> Option<EventMsg> {
        let class = self.classes.get(&id)?;
        Some(EventMsg {
            ts,
            rank,
            tid,
            hostname: self.hostname.clone(),
            class: class.clone(),
            fields: decode_payload(&class.fields, payload),
        })
    }

    /// Make sure channels `0..n` exist. Channel index i is the session's
    /// stream index i (registration order), which is also the stream's
    /// index in a post-mortem `collect` — the merge tie-break relies on
    /// this equality for byte-identical ordering.
    pub fn ensure_channels(&self, n: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if st.channels.len() < n {
            while st.channels.len() < n {
                st.channels.push(Channel::new());
            }
            self.progress.notify_all();
        }
    }

    /// Register a remote publisher as an **origin** of this hub and
    /// return its origin id. Origins namespace remote stream ids: each
    /// origin's streams map to their own shared channels, so identical
    /// per-publisher stream ids can never alias (see module docs).
    pub fn register_origin(&self, label: &str) -> usize {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.origins.push(OriginState {
            label: label.to_string(),
            map: Vec::new(),
            remote_drops: Vec::new(),
            resume_gaps: 0,
            eos: None,
            closed: false,
        });
        st.origins.len() - 1
    }

    /// Extend `origin`'s map so remote streams `0..n` all have shared
    /// channels. New channels append at the end of the shared space —
    /// called in origin order at handshake time this lays the origins
    /// out as contiguous, concatenated blocks.
    pub fn ensure_origin_channels(&self, origin: usize, n: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if st.origins[origin].map.len() < n {
            while st.origins[origin].map.len() < n {
                let shared = st.channels.len();
                st.channels.push(Channel::new());
                st.origins[origin].map.push(shared);
            }
            self.progress.notify_all();
        }
    }

    /// Translate `origin`'s remote stream index into its shared channel
    /// index, allocating the mapping (and channel) if it is new.
    pub fn origin_channel(&self, origin: usize, remote: usize) -> usize {
        self.ensure_origin_channels(origin, remote + 1);
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.origins[origin].map[remote]
    }

    /// Snapshot of `origin`'s remote→shared channel map (readers cache
    /// this so the hot event path needs no extra hub lock).
    pub fn origin_map(&self, origin: usize) -> Vec<usize> {
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.origins[origin].map.clone()
    }

    /// Record a publisher-side cumulative drop count for `origin`'s
    /// remote stream. Monotone per stream (a stale or rewound wire value
    /// never lowers it); totals aggregate saturating, never wrapping.
    pub fn record_origin_drops(&self, origin: usize, remote: usize, cumulative: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let o = &mut st.origins[origin];
        if o.remote_drops.len() <= remote {
            o.remote_drops.resize(remote + 1, 0);
        }
        if cumulative > o.remote_drops[remote] {
            o.remote_drops[remote] = cumulative;
        }
    }

    /// Record `origin`'s publisher-side Eos totals `(received, dropped)`.
    pub fn record_origin_eos(&self, origin: usize, received: u64, dropped: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.origins[origin].eos = Some((received, dropped));
    }

    /// Book `missed` events of `origin`'s remote stream as lost to a
    /// resume gap (a `ResumeGap` frame: the publisher's replay ring
    /// evicted them before the subscriber reconnected). Gaps accumulate
    /// saturating into the origin's drops ledger — unlike
    /// [`LiveHub::record_origin_drops`] these are deltas, not cumulative
    /// wire counters, because each gap names events that are gone for
    /// good. The remote stream index is recorded for attribution only;
    /// no channel state changes (the stream keeps flowing past the gap).
    pub fn record_origin_gap(&self, origin: usize, _remote: usize, missed: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let o = &mut st.origins[origin];
        o.resume_gaps = o.resume_gaps.saturating_add(missed);
    }

    /// Re-admit `origin` after a successful session resume: clears the
    /// origin's closed flag and re-opens its channels so replayed events
    /// can flow again. The inverse of [`LiveHub::close_origin`], for the
    /// reconnect path (`iprof attach --reconnect`).
    ///
    /// Safe by construction: re-opening only makes the merge *more*
    /// conservative (an empty, open channel holds candidates at or past
    /// its watermark until the publisher's post-resume state resync
    /// re-reports any genuine closes, which arrive immediately after the
    /// replay). No-op once the hub is sealed — the merge may already
    /// have terminated, and a terminated merge must stay terminated.
    pub fn reopen_origin(&self, origin: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if st.sealed {
            return;
        }
        let mapped = st.origins[origin].map.clone();
        for idx in mapped {
            st.channels[idx].closed = false;
        }
        st.origins[origin].closed = false;
        self.progress.notify_all();
    }

    /// Close every channel mapped to `origin` — and only those. A dying
    /// publisher ends its own streams without touching the rest of the
    /// union, so the fan-in merge degrades to a partial-but-correct
    /// analysis instead of stalling or tearing the session down.
    pub fn close_origin(&self, origin: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mapped = st.origins[origin].map.clone();
        for idx in mapped {
            st.channels[idx].closed = true;
        }
        st.origins[origin].closed = true;
        self.progress.notify_all();
    }

    /// Per-origin accounting, in registration order (empty for purely
    /// local hubs). All sums saturate.
    pub fn origin_stats(&self) -> Vec<OriginStats> {
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.origins
            .iter()
            .map(|o| {
                let mut s = OriginStats {
                    label: o.label.clone(),
                    channels: o.map.len(),
                    resume_gaps: o.resume_gaps,
                    eos: o.eos,
                    closed: o.closed,
                    ..Default::default()
                };
                for &idx in &o.map {
                    let ch = &st.channels[idx];
                    s.received = s.received.saturating_add(ch.received);
                    s.dropped = s.dropped.saturating_add(ch.dropped);
                    s.beacons = s.beacons.saturating_add(ch.beacons);
                }
                for &d in &o.remote_drops {
                    s.remote_dropped = s.remote_dropped.saturating_add(d);
                }
                s
            })
            .collect()
    }

    /// Try-push a batch of decoded messages onto channel `idx`, in order.
    /// Messages beyond the queue bound are dropped and counted — this
    /// call NEVER blocks (the consumer thread must stay realtime).
    /// Returns the number of messages dropped.
    pub fn push_batch(&self, idx: usize, batch: Vec<EventMsg>) -> u64 {
        if batch.is_empty() {
            return 0;
        }
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let depth = self.depth;
        let ch = &mut st.channels[idx];
        let mut dropped = 0;
        let now = Instant::now();
        for msg in batch {
            // the watermark advances with every delivered event: per-stream
            // timestamps are non-decreasing, so nothing later can undercut it
            ch.watermark = ch.watermark.max(msg.ts);
            if ch.queue.len() >= depth {
                dropped += 1;
                continue;
            }
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            ch.queue.push_back(Entry { seq, msg, pushed: now });
        }
        // saturating: a pathological counter must stick at max, never
        // wrap back toward "lossless"
        ch.dropped = ch.dropped.saturating_add(dropped);
        self.progress.notify_all();
        dropped
    }

    /// Blocking push used by trace **replay** (benches / golden tests):
    /// waits for queue space instead of dropping, so a replay through
    /// bounded channels is lossless. The tracing consumer must never use
    /// this — it uses [`LiveHub::push_batch`].
    pub fn feed_blocking(&self, idx: usize, batch: Vec<EventMsg>) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        for msg in batch {
            while st.channels[idx].queue.len() >= self.depth {
                st = self.progress.wait(st).unwrap_or_else(|p| p.into_inner());
            }
            let ch = &mut st.channels[idx];
            ch.watermark = ch.watermark.max(msg.ts);
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            // stamp AFTER any wait: residence latency must not include
            // the producer's own blocked time
            ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
            self.progress.notify_all();
        }
    }

    /// Publish a beacon on channel `idx`: every future message on this
    /// channel will have `ts >= watermark`. Watermarks only move forward.
    pub fn beacon(&self, idx: usize, watermark: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let ch = &mut st.channels[idx];
        ch.beacons += 1;
        if watermark > ch.watermark {
            ch.watermark = watermark;
            self.progress.notify_all();
        }
    }

    /// Close channel `idx`: no further messages will arrive (equivalent
    /// to a watermark of +infinity once its queue drains).
    pub fn close(&self, idx: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if !st.channels[idx].closed {
            st.channels[idx].closed = true;
            self.progress.notify_all();
        }
    }

    /// Close every channel and seal the hub (no new channels): the merge
    /// drains what is queued and then terminates. Called by the consumer
    /// after its final drain.
    pub fn close_all(&self) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.sealed = true;
        for ch in st.channels.iter_mut() {
            ch.closed = true;
        }
        self.progress.notify_all();
    }

    /// Hostname this hub stamps on decoded messages.
    pub fn hostname(&self) -> &str {
        &self.hostname
    }

    /// Block until there is forwardable progress beyond `cursor`, pop it
    /// and return it; `None` once the hub is sealed, every channel is
    /// closed and every queue is drained (clean end of stream).
    ///
    /// This is the **tee** a remote publisher (`iprof serve`) drains
    /// instead of a local [`super::source::LiveSource`]: it takes the
    /// merge's role of sole queue consumer, but performs no ordering work
    /// — events leave in per-stream FIFO order and the subscriber's own
    /// merge re-establishes global order. Watermarks, drop counts and
    /// closes are reported as deltas against `cursor`, so relaying every
    /// batch in order reproduces the hub state machine exactly.
    pub fn next_forward_batch(&self, cursor: &mut ForwardCursor) -> Option<ForwardBatch> {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let batch = Self::build_forward_batch(&mut st, cursor);
            if !batch.is_empty() {
                // replay producers may be parked waiting for queue space
                self.progress.notify_all();
                return Some(batch);
            }
            if st.sealed && st.channels.iter().all(|ch| ch.closed && ch.queue.is_empty()) {
                return None;
            }
            // Liveness backstop only, like the merge's own wait.
            let (guard, _) = self
                .progress
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }

    /// Non-blocking [`LiveHub::next_forward_batch`]: pop and return
    /// whatever is forwardable *right now*, or `None` when there is
    /// nothing new — including at end of stream. A resumable publisher
    /// uses this between subscriber connections to keep draining the
    /// hub into its replay ring, so a mid-run outage costs ring budget,
    /// not events.
    pub fn try_forward_batch(&self, cursor: &mut ForwardCursor) -> Option<ForwardBatch> {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let batch = Self::build_forward_batch(&mut st, cursor);
        if batch.is_empty() {
            None
        } else {
            self.progress.notify_all();
            Some(batch)
        }
    }

    /// The one forward-batch builder both flavors share: everything new
    /// past `cursor` is popped (events) or delta-reported (growth,
    /// watermarks, drops, closes).
    fn build_forward_batch(st: &mut HubState, cursor: &mut ForwardCursor) -> ForwardBatch {
        let mut batch = ForwardBatch::default();
        if st.channels.len() > cursor.per.len() {
            cursor.per.resize(st.channels.len(), ChannelCursor::default());
        }
        if st.channels.len() > cursor.announced {
            cursor.announced = st.channels.len();
            batch.grown_to = Some(cursor.announced);
        }
        for (i, ch) in st.channels.iter_mut().enumerate() {
            let cur = &mut cursor.per[i];
            while let Some(e) = ch.queue.pop_front() {
                batch.events.push((i, e.msg));
            }
            if ch.watermark > cur.watermark {
                cur.watermark = ch.watermark;
                batch.beacons.push((i, ch.watermark));
            }
            if ch.dropped > cur.dropped {
                cur.dropped = ch.dropped;
                batch.drops.push((i, ch.dropped));
            }
            if ch.closed && !cur.closed {
                cur.closed = true;
                batch.closed.push(i);
            }
        }
        batch
    }

    /// Lossless single-message feed for a **remote subscriber's** mirror
    /// hub (`iprof attach`). Unlike [`LiveHub::feed_blocking`] it ignores
    /// the per-channel depth and instead waits only while the *total*
    /// queued message count is at or above a soft cap of
    /// `depth × (total shared channels)` **and** the merge has releasable
    /// work — the one situation where waiting is provably deadlock-free.
    /// The cap is computed against the whole hub, so N fan-in readers
    /// sharing one hub throttle at the same union backlog a single
    /// attach would, not N times earlier. A reader thread multiplexes
    /// every stream of its connection, so blocking on one full channel
    /// could starve the very beacon frame (later in the byte stream) the
    /// merge needs to drain it; when nothing is releasable the message
    /// is admitted immediately and memory grows transiently, bounded by
    /// one publisher watermark round, not by the trace.
    pub fn feed_remote(&self, idx: usize, msg: EventMsg, depth: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let total: usize = st.channels.iter().map(|c| c.queue.len()).sum();
            let soft_cap = depth.max(1) * st.channels.len().max(1);
            if total < soft_cap || !st.has_releasable() {
                let ch = &mut st.channels[idx];
                ch.watermark = ch.watermark.max(msg.ts);
                let seq = ch.next_seq;
                ch.next_seq += 1;
                ch.received += 1;
                ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
                self.progress.notify_all();
                return;
            }
            st = self.progress.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Aggregate transport statistics.
    pub fn stats(&self) -> LiveStats {
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mut s = LiveStats { channels: st.channels.len(), ..Default::default() };
        for ch in &st.channels {
            s.received += ch.received;
            s.dropped += ch.dropped;
            s.beacons += ch.beacons;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracer::btf::DecodedClass;

    fn msg(ts: u64, rank: u32, tid: u32) -> EventMsg {
        EventMsg {
            ts,
            rank,
            tid,
            hostname: Arc::from("hubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn push_batch_drops_and_counts_beyond_depth() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        let dropped = hub.push_batch(0, (0..10).map(|i| msg(i, 0, 0)).collect());
        assert_eq!(dropped, 8);
        let s = hub.stats();
        assert_eq!(s.received, 2);
        assert_eq!(s.dropped, 8);
        // the watermark still advanced past the dropped events
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 9);
    }

    #[test]
    fn beacons_only_move_watermarks_forward() {
        let hub = LiveHub::new("hubtest", 8, false);
        hub.ensure_channels(1);
        hub.beacon(0, 100);
        hub.beacon(0, 50); // stale beacon must not rewind
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 100);
        assert_eq!(st.channels[0].beacons, 2);
    }

    #[test]
    fn forward_batches_report_events_watermarks_drops_and_eos() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        hub.push_batch(0, (0..5).map(|i| msg(i, 0, 0)).collect()); // 3 drop
        hub.beacon(1, 77);
        let mut cursor = ForwardCursor::default();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert_eq!(b.grown_to, Some(2));
        assert_eq!(b.events.len(), 2, "only the accepted messages are popped");
        assert_eq!(b.events[0].0, 0);
        assert!(b.beacons.contains(&(0, 4)), "watermark passed the dropped events");
        assert!(b.beacons.contains(&(1, 77)));
        assert_eq!(b.drops, vec![(0, 3)]);
        assert!(b.closed.is_empty());
        hub.close_all();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert!(b.events.is_empty());
        assert_eq!(b.closed, vec![0, 1]);
        assert!(hub.next_forward_batch(&mut cursor).is_none(), "then clean EOS");
        // the cursor keeps batches delta-only: nothing is ever re-reported
    }

    #[test]
    fn feed_remote_ignores_per_channel_depth_when_nothing_is_releasable() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        // channel 1 stays empty with watermark 0: nothing is releasable,
        // so feed_remote must admit far beyond depth*channels without
        // blocking (a blocked reader here would deadlock a real attach)
        for i in 0..50 {
            hub.feed_remote(0, msg(i, 0, 0), 4);
        }
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].queue.len(), 50, "lossless: nothing dropped");
        assert!(!st.has_releasable(), "channel 1 still vetoes");
    }

    #[test]
    fn colliding_origin_stream_ids_never_alias() {
        // the latent bug fan-in surfaced: two publishers both call their
        // first stream "0" — without namespacing they'd share a channel
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("node-a");
        let b = hub.register_origin("node-b");
        hub.ensure_origin_channels(a, 2);
        hub.ensure_origin_channels(b, 2);
        // contiguous blocks in origin order: a=[0,1], b=[2,3]
        assert_eq!(hub.origin_map(a), vec![0, 1]);
        assert_eq!(hub.origin_map(b), vec![2, 3]);
        assert_ne!(hub.origin_channel(a, 0), hub.origin_channel(b, 0));
        // both "stream 0" events land on distinct channels
        hub.feed_remote(hub.origin_channel(a, 0), msg(5, 0, 0), 64);
        hub.feed_remote(hub.origin_channel(b, 0), msg(5, 1, 0), 64);
        let stats = hub.origin_stats();
        assert_eq!(stats[a].received, 1);
        assert_eq!(stats[b].received, 1);
        // late growth appends at the end of the shared space
        assert_eq!(hub.origin_channel(a, 2), 4);
        assert_eq!(hub.origin_map(a), vec![0, 1, 4]);
    }

    #[test]
    fn origin_drop_counters_saturate_and_never_rewind() {
        let hub = LiveHub::new("hubtest", 8, false);
        let o = hub.register_origin("lossy-node");
        hub.record_origin_drops(o, 0, u64::MAX);
        hub.record_origin_drops(o, 1, 7);
        // sum would wrap past u64::MAX: must saturate instead
        assert_eq!(hub.origin_stats()[o].remote_dropped, u64::MAX);
        // cumulative counters are monotone: a rewound value is ignored
        hub.record_origin_drops(o, 1, 3);
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.origins[o].remote_drops[1], 7);
    }

    #[test]
    fn resume_gaps_accumulate_saturating_into_origin_stats() {
        let hub = LiveHub::new("hubtest", 8, false);
        let o = hub.register_origin("flappy");
        hub.record_origin_gap(o, 0, 5);
        hub.record_origin_gap(o, 1, 7);
        assert_eq!(hub.origin_stats()[o].resume_gaps, 12, "gaps are deltas, they add");
        hub.record_origin_gap(o, 0, u64::MAX);
        assert_eq!(hub.origin_stats()[o].resume_gaps, u64::MAX, "saturating, never wrapping");
    }

    #[test]
    fn reopen_origin_reverses_close_origin_until_sealed() {
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("a");
        hub.ensure_origin_channels(a, 2);
        hub.close_origin(a);
        assert!(hub.origin_stats()[a].closed);
        hub.reopen_origin(a);
        assert!(!hub.origin_stats()[a].closed);
        {
            let st = hub.inner.lock().unwrap();
            assert!(!st.channels[0].closed && !st.channels[1].closed);
        }
        // a reopened channel accepts events again
        hub.feed_remote(0, msg(5, 0, 0), 8);
        assert_eq!(hub.origin_stats()[a].received, 1);
        // but a sealed hub stays terminated: reopen is a no-op
        hub.close_all();
        hub.reopen_origin(a);
        let st = hub.inner.lock().unwrap();
        assert!(st.channels[0].closed, "reopen after seal must not resurrect the merge");
    }

    #[test]
    fn forward_cursor_resync_rereports_current_state_without_duplicating_events() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        hub.push_batch(0, (0..5).map(|i| msg(i, 0, 0)).collect()); // 3 drop
        let mut cursor = ForwardCursor::default();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert_eq!(b.events.len(), 2);
        // a new subscriber connection: resync re-reports watermark and
        // drops in full, but popped events are gone from the hub (the
        // publisher's replay ring re-sends those)
        cursor.resync(1);
        hub.close_all();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert!(b.events.is_empty(), "no event duplication from the hub side");
        assert_eq!(b.grown_to, None, "Hello already announced the channel");
        assert!(b.beacons.contains(&(0, 4)), "current watermark re-reported");
        assert_eq!(b.drops, vec![(0, 3)], "cumulative drops re-reported");
        assert_eq!(b.closed, vec![0], "closes re-reported");
    }

    #[test]
    fn close_origin_closes_only_its_own_channels() {
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("a");
        let b = hub.register_origin("b");
        hub.ensure_origin_channels(a, 2);
        hub.ensure_origin_channels(b, 1);
        hub.close_origin(a);
        let stats = hub.origin_stats();
        assert!(stats[a].closed);
        assert!(!stats[b].closed);
        let st = hub.inner.lock().unwrap();
        assert!(st.channels[0].closed && st.channels[1].closed);
        assert!(!st.channels[2].closed, "origin b must keep flowing");
    }

    #[test]
    fn decode_uses_registry_classes() {
        let hub = LiveHub::new("hubtest", 8, false);
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let payload = 7u64.to_le_bytes();
        let m = hub.decode(3, 9, class.id, 42, &payload).unwrap();
        assert_eq!(m.ts, 42);
        assert_eq!(m.rank, 3);
        assert_eq!(m.tid, 9);
        assert_eq!(m.class.name, "lttng_ust_ze:zeInit_entry");
        assert_eq!(m.fields[0].as_u64(), 7);
        assert!(hub.decode(0, 0, u32::MAX, 0, &[]).is_none(), "unknown id -> None");
    }
}
