//! The live hub: bounded per-stream message channels with watermarks,
//! sharded by origin.
//!
//! One [`LiveHub`] sits between the tracing consumer thread and the live
//! analysis pipeline (the lttng-live relay analogue). Each traced stream
//! gets one bounded FIFO channel; the consumer decodes ring records as it
//! drains them and *try-pushes* the resulting [`EventMsg`]s — if a channel
//! is full the message is **dropped and counted**, never blocking the
//! consumer and therefore never back-pressuring the traced application
//! (paper §3.1 invariant, extended end to end).
//!
//! Each channel also carries a **watermark**: a timestamp lower bound for
//! every message the channel will deliver in the future. Watermarks
//! advance implicitly with every pushed event (per-stream timestamps are
//! non-decreasing) and explicitly through **beacons** — the LTTng-live
//! trick for quiet streams: the consumer periodically publishes "this
//! stream is quiet up to T" so the k-way merge can advance global time
//! without waiting on a stream that may never speak again.
//!
//! # Sharding (the fan-in hot path)
//!
//! Channels live in **shards**: shard 0 holds the hub's local streams,
//! and every registered origin (remote publisher) gets its own shard.
//! Each shard has its own mutex, so K fan-in reader threads pushing into
//! K origins never contend with each other — a reader's hot path is one
//! shard lock plus two atomics (the global queued-total and channel
//! count), not one hub-wide mutex serializing every event in the
//! process. The merge takes a coherent *snapshot* per round
//! ([`LiveHub::merge_view`]: one short lock acquisition per shard) and
//! re-validates the hub topology version before popping
//! ([`LiveHub::pop_candidate`]), which restores the atomicity the old
//! single-lock design got for free:
//!
//! * a push to a **non-empty** channel appends behind that channel's
//!   head, and per-stream timestamps are non-decreasing, so it can never
//!   beat the snapshot's best candidate in `(ts, stream, seq)` order;
//! * a push to an **empty, open** channel carries `ts >=` that channel's
//!   watermark at push time, and the snapshot only declared the best
//!   releasable because every such watermark was *strictly* above the
//!   candidate — so the late event sorts strictly after it;
//! * a **new channel** bumps the topology version, which
//!   [`LiveHub::pop_candidate`] detects and turns into a rescan.
//!
//! Blocked producers and the merge park on one hub-wide condvar whose
//! waits are all bounded (50 ms re-check loops). With per-shard locks a
//! notification can in principle race a sleeper's predicate check; the
//! bound turns that lost wakeup into at most 50 ms of extra latency,
//! never a correctness problem — the same "liveness backstop only"
//! contract the waits documented before sharding.
//!
//! # Origins (multi-publisher namespacing)
//!
//! A hub can also act as the shared mirror of **several** remote
//! publishers (`iprof attach <addr> <addr>...`, see
//! [`crate::remote::fanin`]). Each publisher registers as an **origin**
//! ([`LiveHub::register_origin`]) and gets its own shard plus a
//! translation table from *remote* stream ids to *shared* channel
//! indices — two publishers that both call their first stream "0" can
//! never alias onto one channel. Blocks are allocated in origin order at
//! handshake time ([`LiveHub::ensure_origin_channels`]), so the shared
//! index order is exactly the concatenation of the publishers' stream
//! sets — which is what makes the fan-in merge byte-identical to a
//! single local `--live` run over that concatenation. Late-registering
//! remote streams append at the end of the shared space (same tie-break
//! caveat as any late-registering local stream). Per-origin accounting
//! ([`LiveHub::origin_stats`]) keeps publisher-side drop totals separate
//! and **saturating** — a hostile or wrapped counter can never roll a
//! drop total back to "lossless".

use crate::analysis::msg::EventMsg;
use crate::telemetry::{Counter, Gauge, Registry};
use crate::tracer::btf::{registry_classes, DecodedClass};
use crate::tracer::encoder::decode_payload;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

/// One entry in a channel queue: arrival sequence (merge tie-break),
/// the decoded message, and the push instant (latency accounting).
pub(super) struct Entry {
    pub(super) seq: u64,
    pub(super) msg: EventMsg,
    pub(super) pushed: Instant,
}

/// Per-stream channel state.
struct Channel {
    queue: VecDeque<Entry>,
    /// Arrival counter (monotone per channel).
    next_seq: u64,
    /// Lower bound on the timestamp of every future message.
    watermark: u64,
    /// No further messages will ever arrive.
    closed: bool,
    /// Messages accepted.
    received: u64,
    /// Messages dropped because the queue was full.
    dropped: u64,
    /// Beacons observed.
    beacons: u64,
    /// Telemetry series for this stream's drops (registered at channel
    /// creation; bumping is one relaxed atomic, no registry lock).
    tele_dropped: Arc<Counter>,
    /// Telemetry series for this stream's queue occupancy.
    tele_depth: Arc<Gauge>,
}

impl Channel {
    fn new(tele_dropped: Arc<Counter>, tele_depth: Arc<Gauge>) -> Self {
        Channel {
            queue: VecDeque::new(),
            next_seq: 0,
            watermark: 0,
            closed: false,
            received: 0,
            dropped: 0,
            beacons: 0,
            tele_dropped,
            tele_depth,
        }
    }
}

/// Bookkeeping for the remote publisher whose streams live in one origin
/// shard (see module docs § Origins).
struct OriginBook {
    /// Display label (usually the publisher's hostname).
    label: String,
    /// Remote stream index → shared (global) channel index.
    map: Vec<usize>,
    /// Latest cumulative publisher-side drop count per remote stream
    /// (monotone: a stale or rewound wire value never lowers it).
    remote_drops: Vec<u64>,
    /// Events irrecoverably lost to resume gaps (`ResumeGap` frames:
    /// the publisher's replay ring evicted them before the subscriber
    /// reconnected). Saturating; see [`LiveHub::record_origin_gap`].
    resume_gaps: u64,
    /// Publisher-side hub totals from its Eos frame, if one arrived.
    eos: Option<(u64, u64)>,
    /// All of this origin's channels have been closed.
    closed: bool,
    /// Negotiated THRL protocol version for this origin's connection
    /// (0 until the handshake reports one). v3 connections may carry
    /// batched events; v2 connections fall back to per-event frames.
    wire_version: u32,
    /// `EventBatch` frames decoded from this origin (0 on a v2
    /// connection — the batched-vs-fallback telltale). Saturating.
    batches: u64,
    /// Leaf publishers aggregated *through* this origin, when the
    /// origin is a relay (`Frame::Origin` frames): per-leaf ledgers
    /// keyed by hierarchical path, in first-seen order. Empty for
    /// ordinary publishers.
    subs: Vec<SubOrigin>,
    /// Telemetry mirrors of this origin's ledgers (labelled by origin
    /// label, registered once at [`LiveHub::register_origin`] time so
    /// the record paths never touch the registry's family lock).
    tele: OriginTelemetry,
}

/// Per-leaf ledgers for one publisher mirrored through a relay origin
/// (see [`LiveHub::record_origin_child`]). Every wire counter is
/// cumulative and monotone, so re-sent `Frame::Origin` frames
/// max-merge — exactly the `Drops` rule, per leaf.
struct SubOrigin {
    /// Hierarchical origin id, as sent by the relay (unique per relay
    /// connection; globally unique once prefixed with the relay's own
    /// origin label — see `telemetry::sub_origin_series_label`).
    path: String,
    /// The leaf publisher's hostname.
    hostname: String,
    /// Relay stream ids carrying this leaf's events (grow-only).
    streams: Vec<u32>,
    /// Cumulative publisher-side drops at the leaf.
    dropped: u64,
    /// Cumulative resume-gap events at the leaf.
    resume_gaps: u64,
    /// The leaf's own Eos totals, once it ended cleanly.
    eos: Option<(u64, u64)>,
    /// Lazily registered telemetry mirrors (label =
    /// `sub_origin_series_label`), bumped by monotone delta only.
    tele_resume_gaps: Arc<Counter>,
    tele_remote_dropped: Arc<Counter>,
}

/// Pre-registered labelled telemetry handles for one origin.
struct OriginTelemetry {
    resume_gaps: Arc<Counter>,
    remote_dropped: Arc<Counter>,
    batches: Arc<Counter>,
    wire_version: Arc<Gauge>,
}

impl OriginTelemetry {
    fn register(telemetry: &Registry, origin: usize, label: &str) -> OriginTelemetry {
        // index-prefixed: two publishers announcing the same hostname
        // must not collapse into one series (see `origin_series_label`)
        let label = crate::telemetry::origin_series_label(origin, label);
        OriginTelemetry {
            resume_gaps: telemetry.origin_resume_gaps.with_label(&label),
            remote_dropped: telemetry.origin_remote_dropped.with_label(&label),
            batches: telemetry.origin_batches.with_label(&label),
            wire_version: telemetry.origin_wire_version.with_label(&label),
        }
    }
}

/// Per-origin accounting snapshot (see [`LiveHub::origin_stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OriginStats {
    /// Origin label (publisher hostname).
    pub label: String,
    /// Shared channels mapped to this origin.
    pub channels: usize,
    /// Messages accepted into this origin's channels (for a lossless
    /// fan-in feed: events merged from this publisher once drained).
    pub received: u64,
    /// Messages dropped at this origin's channels (always 0 for the
    /// lossless fan-in feed; nonzero only for local try-push use).
    pub dropped: u64,
    /// Beacons applied to this origin's channels.
    pub beacons: u64,
    /// Publisher-side cumulative drops reported over the wire —
    /// saturating sum of the latest per-stream counters.
    pub remote_dropped: u64,
    /// Events lost to resume gaps: the publisher replay-ring evicted
    /// them before a reconnecting subscriber could fetch them. Nonzero
    /// means the resumed view is incomplete by exactly this many events
    /// (`--live-strict` fails on it).
    pub resume_gaps: u64,
    /// Publisher-side Eos totals `(received, dropped)`, if the origin
    /// ended cleanly; `None` means the publisher died before Eos.
    pub eos: Option<(u64, u64)>,
    /// Every channel of this origin has closed.
    pub closed: bool,
    /// Negotiated THRL protocol version (0 = not yet reported). A v3
    /// publisher streams batched; a v2 one fell back to per-event
    /// frames — `iprof attach` surfaces this per publisher.
    pub wire_version: u32,
    /// `EventBatch` frames decoded from this origin (0 under the v2
    /// per-event fallback). Saturating.
    pub batches: u64,
    /// Per-leaf accounting relayed through this origin
    /// (`Frame::Origin`), in first-seen order. Empty unless the origin
    /// is a relay. Each child's ledgers are *disjoint* from the parent
    /// connection's own: the parent books loss on the relay→here hop
    /// (its channels, its resume gaps, its Eos totals), the children
    /// book loss at and below the leaves, as learned by the relay.
    pub children: Vec<SubOriginStats>,
}

/// Per-leaf accounting snapshot for one publisher aggregated through a
/// relay (see [`OriginStats::children`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SubOriginStats {
    /// Hierarchical origin id as carried on the wire (e.g.
    /// `0:relay1/0:nodeA` once the receiver prefixes its own origin).
    pub path: String,
    /// The leaf publisher's hostname.
    pub hostname: String,
    /// Relay stream ids carrying this leaf's events.
    pub streams: Vec<u32>,
    /// Messages accepted into this leaf's share of the origin's
    /// channels — within an origin shard the channel index IS the
    /// remote stream index, so this is the sum of `received` over the
    /// leaf's `streams`. Together with [`Self::known_dropped`] this is
    /// the oracle-facing half of the per-leaf conservation law:
    /// `received + known_dropped() == events the leaf published`.
    pub received: u64,
    /// Cumulative publisher-side drops at the leaf.
    pub dropped: u64,
    /// Cumulative events the leaf lost to resume gaps.
    pub resume_gaps: u64,
    /// The leaf's own Eos totals `(received, dropped)`, if it ended
    /// cleanly.
    pub eos: Option<(u64, u64)>,
}

impl SubOriginStats {
    /// Best known loss at this leaf, deduplicated — the same
    /// max-compete rule as [`OriginStats::known_dropped`], applied to
    /// the leaf's own ledgers.
    pub fn known_dropped(&self) -> u64 {
        let ledger = self.dropped.saturating_add(self.resume_gaps);
        match self.eos {
            Some((_, eos_dropped)) => eos_dropped.max(ledger),
            None => ledger,
        }
    }
}

impl OriginStats {
    /// Best known publisher-side loss for this origin, deduplicated.
    ///
    /// The two receiver-side ledgers are disjoint by construction —
    /// `Drops` frames land in [`OriginStats::remote_dropped`],
    /// `ResumeGap` frames in [`OriginStats::resume_gaps`] — so their
    /// saturating sum never counts an event twice. The publisher's Eos
    /// total is one opaque self-reported number that may fold the same
    /// events in (a gap also booked as a channel drop), so it
    /// *competes* against the ledger sum instead of being added on top:
    /// whichever side knows about more loss wins, and an event booked
    /// on both sides still counts exactly once.
    pub fn known_dropped(&self) -> u64 {
        let ledger = self.remote_dropped.saturating_add(self.resume_gaps);
        let own = match self.eos {
            Some((_, eos_dropped)) => eos_dropped.max(ledger),
            None => ledger,
        };
        // children book loss at and below the leaves, disjoint from
        // the parent connection's own ledgers (see `children` docs) —
        // their sum stacks on top instead of competing
        self.children.iter().fold(own, |a, c| a.saturating_add(c.known_dropped()))
    }
}

/// One shard: a run of channels under their own lock. Shard 0 holds the
/// hub's local streams; every origin gets its own shard.
struct Shard {
    state: Mutex<ShardState>,
    /// Telemetry: events fed into this shard (shard 0 = local streams).
    tele_feed: Arc<Counter>,
    /// Telemetry: events the merge popped from this shard.
    tele_merged: Arc<Counter>,
}

impl Shard {
    fn new(origin: Option<OriginBook>, index: usize, telemetry: &Registry) -> Arc<Shard> {
        let label = index.to_string();
        Arc::new(Shard {
            state: Mutex::new(ShardState { channels: Vec::new(), global_ids: Vec::new(), origin }),
            tele_feed: telemetry.shard_feed.with_label(&label),
            tele_merged: telemetry.shard_merged.with_label(&label),
        })
    }

    fn lock(&self) -> MutexGuard<'_, ShardState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

struct ShardState {
    channels: Vec<Channel>,
    /// Shard-local channel index → global channel index.
    global_ids: Vec<usize>,
    /// `Some` for origin shards, `None` for shard 0 (local streams).
    origin: Option<OriginBook>,
}

/// The hub's channel directory: which shard owns which global channel.
/// Grows under the write lock only; every grower bumps
/// [`LiveHub::topo_version`] so snapshot consumers can detect it.
struct Topology {
    /// Global channel index → (shard index, shard-local index).
    dir: Vec<(usize, usize)>,
    /// Shard 0 = local streams; shard `1 + i` = origin `i`.
    shards: Vec<Arc<Shard>>,
    /// Set by [`LiveHub::close_all`]: no new channels will appear and
    /// the merge, once drained, stays terminated.
    sealed: bool,
}

/// The merge's per-round snapshot: best head candidate, whether it is
/// releasable, and whether the hub has fully terminated. Built by
/// [`LiveHub::merge_view`], consumed by [`LiveHub::pop_candidate`].
pub(super) struct MergeView {
    /// Topology version the snapshot was taken under.
    version: u64,
    /// Minimum head entry by `(ts, global index, seq)`, if any queue is
    /// non-empty.
    best: Option<BestHead>,
    /// THE release predicate of the live merge: the candidate may be
    /// released iff every *empty, open* channel has watermarked
    /// **strictly** past its timestamp (a watermark of exactly `ts`
    /// still admits a future equal-timestamp message that may sort
    /// earlier by stream index).
    pub(super) releasable: bool,
    /// Sealed, every channel closed, every queue drained: clean end.
    pub(super) finished: bool,
}

impl MergeView {
    /// Is there any queued candidate at all?
    pub(super) fn has_candidate(&self) -> bool {
        self.best.is_some()
    }
}

struct BestHead {
    ts: u64,
    global: usize,
    seq: u64,
    shard: usize,
    local: usize,
}

/// Cursor a remote forwarder keeps between [`LiveHub::next_forward_batch`]
/// calls: what has already been announced to the subscriber, so each
/// batch carries only the delta.
#[derive(Debug, Default)]
pub struct ForwardCursor {
    /// Channel count already announced.
    announced: usize,
    /// Per-channel last-forwarded state, indexed by global channel.
    per: Vec<ChannelCursor>,
}

impl ForwardCursor {
    /// Reset the delta baseline for a NEW subscriber connection that
    /// already knows about `announced` channels (its Hello said so):
    /// per-channel watermark/drop/close state is zeroed so the next
    /// [`LiveHub::next_forward_batch`] re-reports the *current* hub
    /// state in full. Watermarks and drop counters are monotone and
    /// closes idempotent on the subscriber, so re-reporting is always
    /// safe — this is how a resumed session resynchronizes everything
    /// that is not an event (events replay from the publisher's ring
    /// instead, see `crate::remote::publish`).
    pub fn resync(&mut self, announced: usize) {
        self.announced = announced;
        self.per.clear();
    }
}

#[derive(Debug, Default, Clone)]
struct ChannelCursor {
    watermark: u64,
    dropped: u64,
    closed: bool,
}

/// One round of forwardable progress popped from a hub — everything a
/// remote publisher must relay to keep a subscriber's mirror hub
/// equivalent. Events come out in per-stream FIFO order (the order the
/// consumer pushed them), which is all the subscriber's merge needs.
#[derive(Debug, Default)]
pub struct ForwardBatch {
    /// The channel set grew to this count (announce before the events).
    pub grown_to: Option<usize>,
    /// Popped messages as `(channel index, message)`.
    pub events: Vec<(usize, EventMsg)>,
    /// Channels whose watermark advanced, with the new watermark.
    pub beacons: Vec<(usize, u64)>,
    /// Channels whose drop count grew, with the new cumulative count.
    pub drops: Vec<(usize, u64)>,
    /// Channels that closed since the last batch.
    pub closed: Vec<usize>,
}

impl ForwardBatch {
    fn is_empty(&self) -> bool {
        self.grown_to.is_none()
            && self.events.is_empty()
            && self.beacons.is_empty()
            && self.drops.is_empty()
            && self.closed.is_empty()
    }
}

/// Aggregate live-transport statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LiveStats {
    /// Channels (one per traced stream).
    pub channels: usize,
    /// Messages accepted into channels.
    pub received: u64,
    /// Messages dropped at full channels (backpressure policy).
    pub dropped: u64,
    /// Beacons published.
    pub beacons: u64,
}

/// The live transport hub (see module docs).
///
/// # Examples
///
/// A miniature hub: one event on channel 0, channel 1 quiet — the
/// beacon and the close let the [`super::source::LiveSource`] merge
/// release past the quiet stream:
///
/// ```
/// use thapi::live::{LiveHub, LiveSource};
///
/// let hub = LiveHub::new("docnode", 64, false);
/// hub.ensure_channels(2);
/// let class = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
/// let msg = hub.decode(0, 0, class.id, 42, &0u64.to_le_bytes()).unwrap();
/// hub.push_batch(0, vec![msg]);
/// hub.beacon(1, 100); // stream 1 promises: nothing earlier than t=100
/// hub.close_all();
/// let merged: Vec<u64> = LiveSource::new(hub).map(|m| m.ts).collect();
/// assert_eq!(merged, vec![42]);
/// ```
pub struct LiveHub {
    /// Channel directory + shards. Read-locked on every data-path
    /// operation (shard routing), write-locked only to grow or seal.
    topo: RwLock<Topology>,
    /// Bumped on every topology growth (new channel or shard), so
    /// snapshot consumers ([`LiveHub::pop_candidate`]) can detect a
    /// directory that changed under their scan and rescan instead.
    topo_version: AtomicU64,
    /// Total queued entries across all shards ([`LiveHub::feed_remote`]'s
    /// soft cap reads this without touching any shard lock).
    queued: AtomicUsize,
    /// Total channels across all shards (same purpose).
    nchannels: AtomicUsize,
    /// Parking lot for blocked producers and the merge. The condvar
    /// deliberately pairs with this otherwise-empty mutex — not with any
    /// shard lock — so notifiers never need a shard lock to wake
    /// sleepers; all waits are 50 ms-bounded re-check loops (see module
    /// docs § Sharding).
    gate: Mutex<()>,
    pub(super) progress: Condvar,
    /// Per-channel queue bound, in messages.
    depth: usize,
    /// Also retain raw drained bytes in the session streams (memory-sink
    /// behaviour), so the same run can be re-analyzed post-mortem.
    retain: bool,
    /// Decoded-class table (registry metadata roundtrip) for on-line decode.
    classes: HashMap<u32, Arc<DecodedClass>>,
    /// Hostname stamped on decoded messages.
    hostname: Arc<str>,
    /// The pipeline's self-telemetry registry. Created with the hub and
    /// shared (via [`LiveHub::telemetry`]) with the publisher / fan-in
    /// layers driving the same pipeline, so one scrape endpoint sees
    /// every stage. Hot paths bump pre-registered handles — relaxed
    /// atomics only, no extra locking.
    telemetry: Arc<Registry>,
}

impl std::fmt::Debug for LiveHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveHub")
            .field("depth", &self.depth)
            .field("retain", &self.retain)
            .field("hostname", &self.hostname)
            .finish_non_exhaustive()
    }
}

impl LiveHub {
    /// Create a hub for a session on `hostname` with the given per-stream
    /// channel `depth`. With `retain`, the consumer keeps the raw drained
    /// bytes as well (like the memory sink), so the identical run can also
    /// be analyzed post-mortem — used by the equivalence tests; production
    /// live mode runs with `retain = false` and O(streams × depth) memory.
    pub fn new(hostname: &str, depth: usize, retain: bool) -> Arc<LiveHub> {
        let telemetry = Registry::new();
        let local_shard = Shard::new(None, 0, &telemetry);
        Arc::new(LiveHub {
            topo: RwLock::new(Topology {
                dir: Vec::new(),
                shards: vec![local_shard],
                sealed: false,
            }),
            topo_version: AtomicU64::new(0),
            queued: AtomicUsize::new(0),
            nchannels: AtomicUsize::new(0),
            gate: Mutex::new(()),
            progress: Condvar::new(),
            depth: depth.max(1),
            retain,
            classes: registry_classes(),
            hostname: Arc::from(hostname),
            telemetry,
        })
    }

    /// This hub's metrics registry. The publisher and fan-in layers feed
    /// the same registry, and the `--telemetry` endpoint serves snapshots
    /// of it; [`LiveHub::stats`] reads its totals, so the scrape and the
    /// end-of-run report can never disagree.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// A channel with its per-stream telemetry series registered up
    /// front (label = global stream index), so the hot push/pop paths
    /// never touch the registry's family lock.
    fn new_channel(&self, global: usize) -> Channel {
        let label = global.to_string();
        Channel::new(
            self.telemetry.channel_dropped.with_label(&label),
            self.telemetry.channel_depth.with_label(&label),
        )
    }

    fn topo_read(&self) -> std::sync::RwLockReadGuard<'_, Topology> {
        self.topo.read().unwrap_or_else(|p| p.into_inner())
    }

    fn topo_write(&self) -> std::sync::RwLockWriteGuard<'_, Topology> {
        self.topo.write().unwrap_or_else(|p| p.into_inner())
    }

    /// Park for one bounded re-check interval (see module docs: the
    /// timeout is a liveness backstop only, never a correctness lever).
    pub(super) fn wait_progress(&self) {
        self.telemetry.merge_gate_waits.inc();
        let guard = self.gate.lock().unwrap_or_else(|p| p.into_inner());
        let _ = self
            .progress
            .wait_timeout(guard, Duration::from_millis(50))
            .unwrap_or_else(|p| p.into_inner());
    }

    /// Per-stream channel bound, in messages.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Whether raw drained bytes are also retained for post-mortem use.
    pub fn retain(&self) -> bool {
        self.retain
    }

    /// Decode one raw ring record into a message, using the hub's
    /// registry-derived class table (`None` for unknown class ids, same
    /// policy as `parse_trace`).
    pub fn decode(&self, rank: u32, tid: u32, id: u32, ts: u64, payload: &[u8]) -> Option<EventMsg> {
        let class = self.classes.get(&id)?;
        Some(EventMsg {
            ts,
            rank,
            tid,
            hostname: self.hostname.clone(),
            class: class.clone(),
            fields: decode_payload(&class.fields, payload),
        })
    }

    /// Make sure channels `0..n` exist. Channel index i is the session's
    /// stream index i (registration order), which is also the stream's
    /// index in a post-mortem `collect` — the merge tie-break relies on
    /// this equality for byte-identical ordering. Local channels live in
    /// shard 0.
    pub fn ensure_channels(&self, n: usize) {
        let mut topo = self.topo_write();
        if topo.dir.len() >= n {
            return;
        }
        let shard = topo.shards[0].clone();
        let mut st = shard.lock();
        while topo.dir.len() < n {
            let global = topo.dir.len();
            topo.dir.push((0, st.channels.len()));
            st.channels.push(self.new_channel(global));
            st.global_ids.push(global);
        }
        self.nchannels.store(topo.dir.len(), Ordering::Relaxed);
        self.telemetry.live_channels.set(topo.dir.len() as u64);
        self.topo_version.fetch_add(1, Ordering::Release);
        drop(st);
        drop(topo);
        self.progress.notify_all();
    }

    /// Register a remote publisher as an **origin** of this hub and
    /// return its origin id. Origins namespace remote stream ids: each
    /// origin gets its own shard, so identical per-publisher stream ids
    /// can never alias and per-origin readers never contend on one lock
    /// (see module docs).
    pub fn register_origin(&self, label: &str) -> usize {
        let mut topo = self.topo_write();
        let index = topo.shards.len();
        topo.shards.push(Shard::new(
            Some(OriginBook {
                label: label.to_string(),
                map: Vec::new(),
                remote_drops: Vec::new(),
                resume_gaps: 0,
                eos: None,
                closed: false,
                wire_version: 0,
                batches: 0,
                subs: Vec::new(),
                tele: OriginTelemetry::register(&self.telemetry, index - 1, label),
            }),
            index,
            &self.telemetry,
        ));
        self.topo_version.fetch_add(1, Ordering::Release);
        topo.shards.len() - 2
    }

    /// `origin`'s shard (origin `i` owns shard `i + 1`; shard 0 is the
    /// local-stream shard).
    fn origin_shard(topo: &Topology, origin: usize) -> &Arc<Shard> {
        &topo.shards[origin + 1]
    }

    /// Extend `origin`'s map so remote streams `0..n` all have shared
    /// channels. New channels append at the end of the shared space —
    /// called in origin order at handshake time this lays the origins
    /// out as contiguous, concatenated blocks.
    pub fn ensure_origin_channels(&self, origin: usize, n: usize) {
        let mut topo = self.topo_write();
        let si = origin + 1;
        let shard = topo.shards[si].clone();
        let mut st = shard.lock();
        let book = st.origin.as_ref().expect("origin shard");
        if book.map.len() >= n {
            return;
        }
        while st.origin.as_ref().expect("origin shard").map.len() < n {
            let global = topo.dir.len();
            topo.dir.push((si, st.channels.len()));
            st.channels.push(self.new_channel(global));
            st.global_ids.push(global);
            st.origin.as_mut().expect("origin shard").map.push(global);
        }
        self.nchannels.store(topo.dir.len(), Ordering::Relaxed);
        self.telemetry.live_channels.set(topo.dir.len() as u64);
        self.topo_version.fetch_add(1, Ordering::Release);
        drop(st);
        drop(topo);
        self.progress.notify_all();
    }

    /// Translate `origin`'s remote stream index into its shared channel
    /// index, allocating the mapping (and channel) if it is new.
    pub fn origin_channel(&self, origin: usize, remote: usize) -> usize {
        self.ensure_origin_channels(origin, remote + 1);
        let topo = self.topo_read();
        let st = Self::origin_shard(&topo, origin).lock();
        st.origin.as_ref().expect("origin shard").map[remote]
    }

    /// Snapshot of `origin`'s remote→shared channel map (readers cache
    /// this so the hot event path needs no extra hub lock).
    pub fn origin_map(&self, origin: usize) -> Vec<usize> {
        let topo = self.topo_read();
        let st = Self::origin_shard(&topo, origin).lock();
        st.origin.as_ref().expect("origin shard").map.clone()
    }

    /// Run `f` over `origin`'s bookkeeping under its shard lock.
    fn with_origin_book<T>(&self, origin: usize, f: impl FnOnce(&mut OriginBook) -> T) -> T {
        let topo = self.topo_read();
        let mut st = Self::origin_shard(&topo, origin).lock();
        f(st.origin.as_mut().expect("origin shard"))
    }

    /// Record a publisher-side cumulative drop count for `origin`'s
    /// remote stream. Monotone per stream (a stale or rewound wire value
    /// never lowers it); totals aggregate saturating, never wrapping.
    pub fn record_origin_drops(&self, origin: usize, remote: usize, cumulative: u64) {
        self.with_origin_book(origin, |book| {
            if book.remote_drops.len() <= remote {
                book.remote_drops.resize(remote + 1, 0);
            }
            if cumulative > book.remote_drops[remote] {
                // mirror only the monotone delta: the registry counter
                // stays the saturating sum of the per-stream maxima
                book.tele.remote_dropped.add(cumulative - book.remote_drops[remote]);
                book.remote_drops[remote] = cumulative;
            }
        });
    }

    /// Record `origin`'s publisher-side Eos totals `(received, dropped)`.
    pub fn record_origin_eos(&self, origin: usize, received: u64, dropped: u64) {
        self.with_origin_book(origin, |book| book.eos = Some((received, dropped)));
    }

    /// Record the THRL protocol version negotiated with `origin`'s
    /// publisher (from the connection preamble). Reported per publisher
    /// by `iprof attach` so operators can see who fell back to the v2
    /// per-event wire.
    pub fn record_origin_wire(&self, origin: usize, version: u32) {
        self.with_origin_book(origin, |book| {
            book.wire_version = version;
            book.tele.wire_version.set(u64::from(version));
        });
    }

    /// Count `n` decoded `EventBatch` frames against `origin`.
    /// Saturating, like every other origin counter.
    pub fn record_origin_batches(&self, origin: usize, n: u64) {
        self.with_origin_book(origin, |book| {
            book.batches = book.batches.saturating_add(n);
            book.tele.batches.add(n);
        });
    }

    /// Book `missed` events of `origin`'s remote stream as lost to a
    /// resume gap (a `ResumeGap` frame: the publisher's replay ring
    /// evicted them before the subscriber reconnected). Gaps accumulate
    /// saturating into the origin's drops ledger — unlike
    /// [`LiveHub::record_origin_drops`] these are deltas, not cumulative
    /// wire counters, because each gap names events that are gone for
    /// good. The remote stream index is recorded for attribution only;
    /// no channel state changes (the stream keeps flowing past the gap).
    pub fn record_origin_gap(&self, origin: usize, _remote: usize, missed: u64) {
        self.with_origin_book(origin, |book| {
            book.resume_gaps = book.resume_gaps.saturating_add(missed);
            book.tele.resume_gaps.add(missed);
        });
    }

    /// Record (or max-merge) one leaf publisher relayed through
    /// `origin` (a decoded [`Frame::Origin`]; `iprof relay` re-sends
    /// the frame whenever a leaf's counters change). Keyed by `path`;
    /// all counters are cumulative and monotone, so a stale or
    /// re-ordered frame can never roll a leaf's ledger back — the same
    /// rule as [`LiveHub::record_origin_drops`]. The leaf's telemetry
    /// series register lazily on first sight under
    /// [`crate::telemetry::sub_origin_series_label`], which prefixes
    /// the relay connection's own `<index>:<label>` — two relays each
    /// forwarding an origin named `0:nodeA` stay distinct series (and
    /// distinct ledgers: they live in distinct origins' books).
    #[allow(clippy::too_many_arguments)]
    pub fn record_origin_child(
        &self,
        origin: usize,
        path: &str,
        hostname: &str,
        streams: &[u32],
        dropped: u64,
        resume_gaps: u64,
        eos: Option<(u64, u64)>,
    ) {
        let topo = self.topo_read();
        let mut st = Self::origin_shard(&topo, origin).lock();
        let book = st.origin.as_mut().expect("origin shard");
        let sub = match book.subs.iter_mut().position(|s| s.path == path) {
            Some(i) => &mut book.subs[i],
            None => {
                let label =
                    crate::telemetry::sub_origin_series_label(origin, &book.label, path);
                book.subs.push(SubOrigin {
                    path: path.to_string(),
                    hostname: hostname.to_string(),
                    streams: Vec::new(),
                    dropped: 0,
                    resume_gaps: 0,
                    eos: None,
                    tele_resume_gaps: self.telemetry.origin_resume_gaps.with_label(&label),
                    tele_remote_dropped: self.telemetry.origin_remote_dropped.with_label(&label),
                });
                book.subs.last_mut().expect("just pushed")
            }
        };
        if sub.hostname != hostname {
            sub.hostname = hostname.to_string();
        }
        if streams.len() > sub.streams.len() {
            sub.streams = streams.to_vec();
        }
        if dropped > sub.dropped {
            sub.tele_remote_dropped.add(dropped - sub.dropped);
            sub.dropped = dropped;
        }
        if resume_gaps > sub.resume_gaps {
            sub.tele_resume_gaps.add(resume_gaps - sub.resume_gaps);
            sub.resume_gaps = resume_gaps;
        }
        if eos.is_some() {
            sub.eos = eos;
        }
    }

    /// Re-admit `origin` after a successful session resume: clears the
    /// origin's closed flag and re-opens its channels so replayed events
    /// can flow again. The inverse of [`LiveHub::close_origin`], for the
    /// reconnect path (`iprof attach --reconnect`).
    ///
    /// Safe by construction: re-opening only makes the merge *more*
    /// conservative (an empty, open channel holds candidates at or past
    /// its watermark until the publisher's post-resume state resync
    /// re-reports any genuine closes, which arrive immediately after the
    /// replay). No-op once the hub is sealed — the merge may already
    /// have terminated, and a terminated merge must stay terminated.
    /// (The seal check and the shard mutation happen under the topology
    /// read lock, which [`LiveHub::close_all`] excludes with its write
    /// lock — reopen-vs-seal can never interleave.)
    pub fn reopen_origin(&self, origin: usize) {
        let topo = self.topo_read();
        if topo.sealed {
            return;
        }
        let mut st = Self::origin_shard(&topo, origin).lock();
        for ch in st.channels.iter_mut() {
            ch.closed = false;
        }
        st.origin.as_mut().expect("origin shard").closed = false;
        drop(st);
        drop(topo);
        self.progress.notify_all();
    }

    /// Close every channel mapped to `origin` — and only those. A dying
    /// publisher ends its own streams without touching the rest of the
    /// union, so the fan-in merge degrades to a partial-but-correct
    /// analysis instead of stalling or tearing the session down.
    pub fn close_origin(&self, origin: usize) {
        let topo = self.topo_read();
        let mut st = Self::origin_shard(&topo, origin).lock();
        for ch in st.channels.iter_mut() {
            ch.closed = true;
        }
        st.origin.as_mut().expect("origin shard").closed = true;
        drop(st);
        drop(topo);
        self.progress.notify_all();
    }

    /// Per-origin accounting, in registration order (empty for purely
    /// local hubs). All sums saturate.
    pub fn origin_stats(&self) -> Vec<OriginStats> {
        let topo = self.topo_read();
        topo.shards[1..]
            .iter()
            .map(|shard| {
                let st = shard.lock();
                let book = st.origin.as_ref().expect("origin shard");
                let mut s = OriginStats {
                    label: book.label.clone(),
                    channels: book.map.len(),
                    resume_gaps: book.resume_gaps,
                    eos: book.eos,
                    closed: book.closed,
                    wire_version: book.wire_version,
                    batches: book.batches,
                    children: book
                        .subs
                        .iter()
                        .map(|c| SubOriginStats {
                            path: c.path.clone(),
                            hostname: c.hostname.clone(),
                            streams: c.streams.clone(),
                            // origin-shard channels are indexed by remote
                            // stream id, so the leaf's merged share is the
                            // sum over its stream set
                            received: c.streams.iter().fold(0u64, |a, &sid| {
                                a.saturating_add(
                                    st.channels.get(sid as usize).map_or(0, |ch| ch.received),
                                )
                            }),
                            dropped: c.dropped,
                            resume_gaps: c.resume_gaps,
                            eos: c.eos,
                        })
                        .collect(),
                    ..Default::default()
                };
                for ch in &st.channels {
                    s.received = s.received.saturating_add(ch.received);
                    s.dropped = s.dropped.saturating_add(ch.dropped);
                    s.beacons = s.beacons.saturating_add(ch.beacons);
                }
                for &d in &book.remote_drops {
                    s.remote_dropped = s.remote_dropped.saturating_add(d);
                }
                s
            })
            .collect()
    }

    /// Try-push a batch of decoded messages onto channel `idx`, in order.
    /// Messages beyond the queue bound are dropped and counted — this
    /// call NEVER blocks (the consumer thread must stay realtime).
    /// Returns the number of messages dropped.
    pub fn push_batch(&self, idx: usize, batch: Vec<EventMsg>) -> u64 {
        if batch.is_empty() {
            return 0;
        }
        let depth = self.depth;
        let mut accepted = 0usize;
        let mut dropped = 0u64;
        {
            let topo = self.topo_read();
            let (si, li) = topo.dir[idx];
            let mut st = topo.shards[si].lock();
            let ch = &mut st.channels[li];
            let now = Instant::now();
            for msg in batch {
                // the watermark advances with every delivered event: per-stream
                // timestamps are non-decreasing, so nothing later can undercut it
                ch.watermark = ch.watermark.max(msg.ts);
                if ch.queue.len() >= depth {
                    dropped += 1;
                    continue;
                }
                let seq = ch.next_seq;
                ch.next_seq += 1;
                ch.received = ch.received.saturating_add(1);
                accepted += 1;
                ch.queue.push_back(Entry { seq, msg, pushed: now });
            }
            // saturating: a pathological counter must stick at max, never
            // wrap back toward "lossless"
            ch.dropped = ch.dropped.saturating_add(dropped);
            ch.tele_dropped.add(dropped);
            ch.tele_depth.set(ch.queue.len() as u64);
            topo.shards[si].tele_feed.add(accepted as u64);
        }
        let reg = &self.telemetry;
        reg.live_events_received.add(accepted as u64);
        reg.live_events_dropped.add(dropped);
        reg.live_queue_depth.add(accepted as u64);
        self.queued.fetch_add(accepted, Ordering::Relaxed);
        self.progress.notify_all();
        dropped
    }

    /// Blocking push used by trace **replay** (benches / golden tests):
    /// waits for queue space instead of dropping, so a replay through
    /// bounded channels is lossless. The tracing consumer must never use
    /// this — it uses [`LiveHub::push_batch`].
    pub fn feed_blocking(&self, idx: usize, batch: Vec<EventMsg>) {
        for msg in batch {
            let mut msg = Some(msg);
            loop {
                {
                    let topo = self.topo_read();
                    let (si, li) = topo.dir[idx];
                    let mut st = topo.shards[si].lock();
                    let ch = &mut st.channels[li];
                    if ch.queue.len() < self.depth {
                        let msg = msg.take().expect("unpushed message");
                        ch.watermark = ch.watermark.max(msg.ts);
                        let seq = ch.next_seq;
                        ch.next_seq += 1;
                        ch.received = ch.received.saturating_add(1);
                        // stamp AFTER any wait: residence latency must not
                        // include the producer's own blocked time
                        ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
                        ch.tele_depth.set(ch.queue.len() as u64);
                        topo.shards[si].tele_feed.inc();
                    }
                }
                if msg.is_none() {
                    self.queued.fetch_add(1, Ordering::Relaxed);
                    self.telemetry.live_events_received.inc();
                    self.telemetry.live_queue_depth.add(1);
                    self.progress.notify_all();
                    break;
                }
                self.wait_progress();
            }
        }
    }

    /// Publish a beacon on channel `idx`: every future message on this
    /// channel will have `ts >= watermark`. Watermarks only move forward.
    pub fn beacon(&self, idx: usize, watermark: u64) {
        let advanced = {
            let topo = self.topo_read();
            let (si, li) = topo.dir[idx];
            let mut st = topo.shards[si].lock();
            let ch = &mut st.channels[li];
            ch.beacons = ch.beacons.saturating_add(1);
            if watermark > ch.watermark {
                ch.watermark = watermark;
                true
            } else {
                false
            }
        };
        self.telemetry.live_beacons.inc();
        if advanced {
            self.progress.notify_all();
        }
    }

    /// Close channel `idx`: no further messages will arrive (equivalent
    /// to a watermark of +infinity once its queue drains).
    pub fn close(&self, idx: usize) {
        let newly = {
            let topo = self.topo_read();
            let (si, li) = topo.dir[idx];
            let mut st = topo.shards[si].lock();
            let ch = &mut st.channels[li];
            let newly = !ch.closed;
            ch.closed = true;
            newly
        };
        if newly {
            self.progress.notify_all();
        }
    }

    /// Close every channel and seal the hub (no new channels): the merge
    /// drains what is queued and then terminates. Called by the consumer
    /// after its final drain. Holds the topology write lock across the
    /// whole sweep so it cannot interleave with [`LiveHub::reopen_origin`].
    pub fn close_all(&self) {
        {
            let mut topo = self.topo_write();
            topo.sealed = true;
            for shard in &topo.shards {
                let mut st = shard.lock();
                for ch in st.channels.iter_mut() {
                    ch.closed = true;
                }
            }
        }
        self.progress.notify_all();
    }

    /// Hostname this hub stamps on decoded messages.
    pub fn hostname(&self) -> &str {
        &self.hostname
    }

    /// Take the merge's per-round snapshot: one short lock acquisition
    /// per shard, no global lock. See [`MergeView`] and module docs
    /// § Sharding for why a snapshot plus [`LiveHub::pop_candidate`]'s
    /// version re-validation is as good as the old hub-wide mutex.
    pub(super) fn merge_view(&self) -> MergeView {
        let topo = self.topo_read();
        // safe to read after taking the read lock: bumps happen only
        // under the write lock, which we now exclude
        let version = self.topo_version.load(Ordering::Acquire);
        let mut best: Option<BestHead> = None;
        let mut gate = u64::MAX;
        let mut all_closed_drained = true;
        for (si, shard) in topo.shards.iter().enumerate() {
            let st = shard.lock();
            for (li, ch) in st.channels.iter().enumerate() {
                if !(ch.closed && ch.queue.is_empty()) {
                    all_closed_drained = false;
                }
                match ch.queue.front() {
                    Some(e) => {
                        let global = st.global_ids[li];
                        let better = match &best {
                            None => true,
                            Some(b) => (e.msg.ts, global, e.seq) < (b.ts, b.global, b.seq),
                        };
                        if better {
                            best = Some(BestHead {
                                ts: e.msg.ts,
                                global,
                                seq: e.seq,
                                shard: si,
                                local: li,
                            });
                        }
                    }
                    None => {
                        if !ch.closed {
                            gate = gate.min(ch.watermark);
                        }
                    }
                }
            }
        }
        // strict `>`: the candidate releases only if every empty open
        // channel has watermarked strictly past it
        let releasable = best.as_ref().map_or(false, |b| b.ts < gate);
        let finished = topo.sealed && all_closed_drained && best.is_none();
        MergeView { version, best, releasable, finished }
    }

    /// Pop the snapshot's best candidate, or `None` if the topology
    /// changed since [`LiveHub::merge_view`] (a new channel could have
    /// invalidated the release decision — rescan). The head entry itself
    /// cannot have changed: the merge is the sole consumer and pushes
    /// only append.
    pub(super) fn pop_candidate(&self, view: &MergeView) -> Option<Entry> {
        let best = view.best.as_ref()?;
        let topo = self.topo_read();
        if self.topo_version.load(Ordering::Acquire) != view.version {
            return None;
        }
        let mut st = topo.shards[best.shard].lock();
        let ch = &mut st.channels[best.local];
        let entry = ch
            .queue
            .pop_front()
            .expect("merge candidate vanished (sole-consumer contract)");
        ch.tele_depth.set(ch.queue.len() as u64);
        topo.shards[best.shard].tele_merged.inc();
        self.queued.fetch_sub(1, Ordering::Relaxed);
        self.telemetry.live_queue_depth.sub(1);
        Some(entry)
    }

    /// Is at least one queued message releasable right now? (The head
    /// with the minimum timestamp is releasable iff any is.) Used by
    /// [`LiveHub::feed_remote`] to wait for queue space only when the
    /// merge is provably able to make progress.
    fn has_releasable(&self) -> bool {
        self.merge_view().releasable
    }

    /// Sealed, all closed, all drained?
    fn is_finished(&self) -> bool {
        let topo = self.topo_read();
        topo.sealed
            && topo.shards.iter().all(|shard| {
                let st = shard.lock();
                st.channels.iter().all(|ch| ch.closed && ch.queue.is_empty())
            })
    }

    /// Block until there is forwardable progress beyond `cursor`, pop it
    /// and return it; `None` once the hub is sealed, every channel is
    /// closed and every queue is drained (clean end of stream).
    ///
    /// This is the **tee** a remote publisher (`iprof serve`) drains
    /// instead of a local [`super::source::LiveSource`]: it takes the
    /// merge's role of sole queue consumer, but performs no ordering work
    /// — events leave in per-stream FIFO order and the subscriber's own
    /// merge re-establishes global order. Watermarks, drop counts and
    /// closes are reported as deltas against `cursor`, so relaying every
    /// batch in order reproduces the hub state machine exactly.
    pub fn next_forward_batch(&self, cursor: &mut ForwardCursor) -> Option<ForwardBatch> {
        loop {
            if let Some(batch) = self.try_forward_batch(cursor) {
                return Some(batch);
            }
            if self.is_finished() {
                return None;
            }
            // Liveness backstop only, like the merge's own wait.
            self.wait_progress();
        }
    }

    /// Non-blocking [`LiveHub::next_forward_batch`]: pop and return
    /// whatever is forwardable *right now*, or `None` when there is
    /// nothing new — including at end of stream. A resumable publisher
    /// uses this between subscriber connections to keep draining the
    /// hub into its replay ring, so a mid-run outage costs ring budget,
    /// not events.
    pub fn try_forward_batch(&self, cursor: &mut ForwardCursor) -> Option<ForwardBatch> {
        let batch = self.build_forward_batch(cursor);
        if batch.is_empty() {
            None
        } else {
            // replay producers may be parked waiting for queue space
            self.progress.notify_all();
            Some(batch)
        }
    }

    /// The one forward-batch builder both flavors share: everything new
    /// past `cursor` is popped (events) or delta-reported (growth,
    /// watermarks, drops, closes). Takes every shard lock for the walk
    /// (ascending order, one acquisition each) so the batch is a
    /// coherent cross-shard snapshot in **global channel order** —
    /// identical output to the pre-sharding single-lock builder. The
    /// forwarder is one thread and per-origin readers still only ever
    /// contend for their own shard, briefly.
    fn build_forward_batch(&self, cursor: &mut ForwardCursor) -> ForwardBatch {
        let topo = self.topo_read();
        let mut guards: Vec<MutexGuard<'_, ShardState>> =
            topo.shards.iter().map(|s| s.lock()).collect();
        let n = topo.dir.len();
        let mut batch = ForwardBatch::default();
        if n > cursor.per.len() {
            cursor.per.resize(n, ChannelCursor::default());
        }
        if n > cursor.announced {
            cursor.announced = n;
            batch.grown_to = Some(n);
        }
        let mut popped = 0usize;
        for global in 0..n {
            let (si, li) = topo.dir[global];
            let ch = &mut guards[si].channels[li];
            let cur = &mut cursor.per[global];
            while let Some(e) = ch.queue.pop_front() {
                batch.events.push((global, e.msg));
                popped += 1;
            }
            ch.tele_depth.set(0);
            if ch.watermark > cur.watermark {
                cur.watermark = ch.watermark;
                batch.beacons.push((global, ch.watermark));
            }
            if ch.dropped > cur.dropped {
                cur.dropped = ch.dropped;
                batch.drops.push((global, ch.dropped));
            }
            if ch.closed && !cur.closed {
                cur.closed = true;
                batch.closed.push(global);
            }
        }
        self.queued.fetch_sub(popped, Ordering::Relaxed);
        self.telemetry.live_queue_depth.sub(popped as u64);
        batch
    }

    /// Lossless single-message feed for a **remote subscriber's** mirror
    /// hub (`iprof attach`). Unlike [`LiveHub::feed_blocking`] it ignores
    /// the per-channel depth and instead waits only while the *total*
    /// queued message count is at or above a soft cap of
    /// `depth × (total shared channels)` **and** the merge has releasable
    /// work — the one situation where waiting is provably deadlock-free.
    /// The cap is computed against the whole hub, so N fan-in readers
    /// sharing one hub throttle at the same union backlog a single
    /// attach would, not N times earlier. A reader thread multiplexes
    /// every stream of its connection, so blocking on one full channel
    /// could starve the very beacon frame (later in the byte stream) the
    /// merge needs to drain it; when nothing is releasable the message
    /// is admitted immediately and memory grows transiently, bounded by
    /// one publisher watermark round, not by the trace. The cap check
    /// reads two atomics — the fast path under cap never scans the hub.
    pub fn feed_remote(&self, idx: usize, msg: EventMsg, depth: usize) {
        let mut msg = Some(msg);
        loop {
            let total = self.queued.load(Ordering::Relaxed);
            let soft_cap = depth.max(1) * self.nchannels.load(Ordering::Relaxed).max(1);
            if total < soft_cap || !self.has_releasable() {
                let taken = msg.take().expect("unpushed message");
                self.feed_now(idx, taken);
                return;
            }
            self.wait_progress();
        }
    }

    /// Batched [`LiveHub::feed_remote`]: one soft-cap check and one
    /// shard-lock acquisition admit the whole batch — the subscriber
    /// hot path for v3 `EventBatch` frames. The cap stays soft exactly
    /// as for single feeds (a batch may overshoot it by its own length,
    /// bounded by the wire's `MAX_BATCH_EVENTS`); accounting is per
    /// *event*, so drop ledgers and stats cannot tell a batch from the
    /// same events fed one by one.
    pub fn feed_remote_batch(&self, idx: usize, batch: Vec<EventMsg>, depth: usize) {
        if batch.is_empty() {
            return;
        }
        let mut batch = Some(batch);
        loop {
            let total = self.queued.load(Ordering::Relaxed);
            let soft_cap = depth.max(1) * self.nchannels.load(Ordering::Relaxed).max(1);
            if total < soft_cap || !self.has_releasable() {
                let taken = batch.take().expect("unpushed batch");
                let n = taken.len();
                {
                    let topo = self.topo_read();
                    let (si, li) = topo.dir[idx];
                    let mut st = topo.shards[si].lock();
                    let ch = &mut st.channels[li];
                    let now = Instant::now();
                    for msg in taken {
                        ch.watermark = ch.watermark.max(msg.ts);
                        let seq = ch.next_seq;
                        ch.next_seq += 1;
                        ch.received = ch.received.saturating_add(1);
                        ch.queue.push_back(Entry { seq, msg, pushed: now });
                    }
                    ch.tele_depth.set(ch.queue.len() as u64);
                    topo.shards[si].tele_feed.add(n as u64);
                }
                let reg = &self.telemetry;
                reg.live_events_received.add(n as u64);
                reg.live_queue_depth.add(n as u64);
                self.queued.fetch_add(n, Ordering::Relaxed);
                self.progress.notify_all();
                return;
            }
            self.wait_progress();
        }
    }

    /// The push half of [`LiveHub::feed_remote`], once admitted.
    fn feed_now(&self, idx: usize, msg: EventMsg) {
        {
            let topo = self.topo_read();
            let (si, li) = topo.dir[idx];
            let mut st = topo.shards[si].lock();
            let ch = &mut st.channels[li];
            ch.watermark = ch.watermark.max(msg.ts);
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received = ch.received.saturating_add(1);
            ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
            ch.tele_depth.set(ch.queue.len() as u64);
            topo.shards[si].tele_feed.inc();
        }
        self.telemetry.live_events_received.inc();
        self.telemetry.live_queue_depth.add(1);
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.progress.notify_all();
    }

    /// Aggregate transport statistics — a view over the telemetry
    /// registry (every feed path bumps the registry at the same site it
    /// bumps the per-channel ledgers), so the end-of-run report and a
    /// `--telemetry` scrape of the same moment are equal by
    /// construction, and this read takes no locks at all.
    pub fn stats(&self) -> LiveStats {
        LiveStats {
            channels: self.nchannels.load(Ordering::Relaxed),
            received: self.telemetry.live_events_received.get(),
            dropped: self.telemetry.live_events_dropped.get(),
            beacons: self.telemetry.live_beacons.get(),
        }
    }
}

#[cfg(test)]
impl LiveHub {
    /// Test peek: channel `idx`'s watermark.
    pub(crate) fn probe_watermark(&self, idx: usize) -> u64 {
        let topo = self.topo_read();
        let (si, li) = topo.dir[idx];
        let st = topo.shards[si].lock();
        st.channels[li].watermark
    }

    /// Test peek: channel `idx`'s queued-message count.
    pub(crate) fn probe_queue_len(&self, idx: usize) -> usize {
        let topo = self.topo_read();
        let (si, li) = topo.dir[idx];
        let st = topo.shards[si].lock();
        st.channels[li].queue.len()
    }

    /// Test peek: channel `idx`'s closed flag.
    pub(crate) fn probe_closed(&self, idx: usize) -> bool {
        let topo = self.topo_read();
        let (si, li) = topo.dir[idx];
        let st = topo.shards[si].lock();
        st.channels[li].closed
    }

    /// Test peek: channel `idx`'s beacon count.
    pub(crate) fn probe_beacons(&self, idx: usize) -> u64 {
        let topo = self.topo_read();
        let (si, li) = topo.dir[idx];
        let st = topo.shards[si].lock();
        st.channels[li].beacons
    }

    /// Test peek: `origin`'s latest cumulative drop counter for one
    /// remote stream.
    pub(crate) fn probe_remote_drops(&self, origin: usize, remote: usize) -> u64 {
        let topo = self.topo_read();
        let st = Self::origin_shard(&topo, origin).lock();
        st.origin.as_ref().expect("origin shard").remote_drops[remote]
    }

    /// Test peek: the release predicate at `ts` (see module docs).
    pub(crate) fn probe_releasable(&self, ts: u64) -> bool {
        let topo = self.topo_read();
        topo.shards.iter().all(|shard| {
            let st = shard.lock();
            st.channels
                .iter()
                .all(|ch| !ch.queue.is_empty() || ch.closed || ch.watermark > ts)
        })
    }

    /// Test peek: does any queued candidate release right now?
    pub(crate) fn probe_has_releasable(&self) -> bool {
        self.has_releasable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracer::btf::DecodedClass;

    fn msg(ts: u64, rank: u32, tid: u32) -> EventMsg {
        EventMsg {
            ts,
            rank,
            tid,
            hostname: Arc::from("hubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn push_batch_drops_and_counts_beyond_depth() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        let dropped = hub.push_batch(0, (0..10).map(|i| msg(i, 0, 0)).collect());
        assert_eq!(dropped, 8);
        let s = hub.stats();
        assert_eq!(s.received, 2);
        assert_eq!(s.dropped, 8);
        // the watermark still advanced past the dropped events
        assert_eq!(hub.probe_watermark(0), 9);
    }

    #[test]
    fn beacons_only_move_watermarks_forward() {
        let hub = LiveHub::new("hubtest", 8, false);
        hub.ensure_channels(1);
        hub.beacon(0, 100);
        hub.beacon(0, 50); // stale beacon must not rewind
        assert_eq!(hub.probe_watermark(0), 100);
        assert_eq!(hub.probe_beacons(0), 2);
    }

    #[test]
    fn forward_batches_report_events_watermarks_drops_and_eos() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        hub.push_batch(0, (0..5).map(|i| msg(i, 0, 0)).collect()); // 3 drop
        hub.beacon(1, 77);
        let mut cursor = ForwardCursor::default();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert_eq!(b.grown_to, Some(2));
        assert_eq!(b.events.len(), 2, "only the accepted messages are popped");
        assert_eq!(b.events[0].0, 0);
        assert!(b.beacons.contains(&(0, 4)), "watermark passed the dropped events");
        assert!(b.beacons.contains(&(1, 77)));
        assert_eq!(b.drops, vec![(0, 3)]);
        assert!(b.closed.is_empty());
        hub.close_all();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert!(b.events.is_empty());
        assert_eq!(b.closed, vec![0, 1]);
        assert!(hub.next_forward_batch(&mut cursor).is_none(), "then clean EOS");
        // the cursor keeps batches delta-only: nothing is ever re-reported
    }

    #[test]
    fn feed_remote_ignores_per_channel_depth_when_nothing_is_releasable() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        // channel 1 stays empty with watermark 0: nothing is releasable,
        // so feed_remote must admit far beyond depth*channels without
        // blocking (a blocked reader here would deadlock a real attach)
        for i in 0..50 {
            hub.feed_remote(0, msg(i, 0, 0), 4);
        }
        assert_eq!(hub.probe_queue_len(0), 50, "lossless: nothing dropped");
        assert!(!hub.probe_has_releasable(), "channel 1 still vetoes");
    }

    #[test]
    fn feed_remote_batch_matches_per_event_feeds() {
        let hub = LiveHub::new("hubtest", 4, false);
        let o = hub.register_origin("batched");
        hub.ensure_origin_channels(o, 2);
        // a batch overshooting the soft cap is still admitted whole when
        // nothing is releasable (channel 1 vetoes), exactly like the
        // per-event feed; counters count events, not batches
        hub.feed_remote_batch(0, (0..20).map(|i| msg(i, 0, 0)).collect(), 4);
        hub.feed_remote_batch(0, vec![], 4); // empty batch is a no-op
        assert_eq!(hub.probe_queue_len(0), 20);
        let stats = hub.origin_stats();
        assert_eq!(stats[o].received, 20);
        assert_eq!(stats[o].dropped, 0, "remote feeds are lossless");
        assert_eq!(hub.probe_watermark(0), 19);
        // seq/tie-break state matches per-event feeding: drain in order
        hub.close_all();
        let drained: Vec<u64> = crate::live::LiveSource::new(hub).map(|m| m.ts).collect();
        assert_eq!(drained, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn origin_wire_version_and_batches_surface_in_stats() {
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("v3-node");
        let b = hub.register_origin("v2-node");
        assert_eq!(hub.origin_stats()[a].wire_version, 0, "unknown until handshake");
        hub.record_origin_wire(a, 3);
        hub.record_origin_wire(b, 2);
        hub.record_origin_batches(a, 5);
        hub.record_origin_batches(a, u64::MAX); // saturates, never wraps
        let stats = hub.origin_stats();
        assert_eq!(stats[a].wire_version, 3);
        assert_eq!(stats[a].batches, u64::MAX);
        assert_eq!(stats[b].wire_version, 2);
        assert_eq!(stats[b].batches, 0, "v2 fallback never batches");
    }

    #[test]
    fn colliding_origin_stream_ids_never_alias() {
        // the latent bug fan-in surfaced: two publishers both call their
        // first stream "0" — without namespacing they'd share a channel
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("node-a");
        let b = hub.register_origin("node-b");
        hub.ensure_origin_channels(a, 2);
        hub.ensure_origin_channels(b, 2);
        // contiguous blocks in origin order: a=[0,1], b=[2,3]
        assert_eq!(hub.origin_map(a), vec![0, 1]);
        assert_eq!(hub.origin_map(b), vec![2, 3]);
        assert_ne!(hub.origin_channel(a, 0), hub.origin_channel(b, 0));
        // both "stream 0" events land on distinct channels
        hub.feed_remote(hub.origin_channel(a, 0), msg(5, 0, 0), 64);
        hub.feed_remote(hub.origin_channel(b, 0), msg(5, 1, 0), 64);
        let stats = hub.origin_stats();
        assert_eq!(stats[a].received, 1);
        assert_eq!(stats[b].received, 1);
        // late growth appends at the end of the shared space
        assert_eq!(hub.origin_channel(a, 2), 4);
        assert_eq!(hub.origin_map(a), vec![0, 1, 4]);
    }

    #[test]
    fn origin_drop_counters_saturate_and_never_rewind() {
        let hub = LiveHub::new("hubtest", 8, false);
        let o = hub.register_origin("lossy-node");
        hub.record_origin_drops(o, 0, u64::MAX);
        hub.record_origin_drops(o, 1, 7);
        // sum would wrap past u64::MAX: must saturate instead
        assert_eq!(hub.origin_stats()[o].remote_dropped, u64::MAX);
        // cumulative counters are monotone: a rewound value is ignored
        hub.record_origin_drops(o, 1, 3);
        assert_eq!(hub.probe_remote_drops(o, 1), 7);
    }

    #[test]
    fn resume_gaps_accumulate_saturating_into_origin_stats() {
        let hub = LiveHub::new("hubtest", 8, false);
        let o = hub.register_origin("flappy");
        hub.record_origin_gap(o, 0, 5);
        hub.record_origin_gap(o, 1, 7);
        assert_eq!(hub.origin_stats()[o].resume_gaps, 12, "gaps are deltas, they add");
        hub.record_origin_gap(o, 0, u64::MAX);
        assert_eq!(hub.origin_stats()[o].resume_gaps, u64::MAX, "saturating, never wrapping");
    }

    #[test]
    fn known_dropped_never_double_counts_a_gap_booked_as_a_drop() {
        let hub = LiveHub::new("hubtest", 8, false);
        let o = hub.register_origin("gappy");
        hub.record_origin_drops(o, 0, 4);
        hub.record_origin_gap(o, 0, 3);
        // no Eos yet: the disjoint receiver ledgers simply add
        assert_eq!(hub.origin_stats()[o].known_dropped(), 7);
        // a publisher whose Eos total folded the gap in (4 drops + 3
        // gap events booked as drops) must not count the gap twice:
        // the Eos total competes against the ledger sum, max wins
        hub.record_origin_eos(o, 100, 7);
        assert_eq!(hub.origin_stats()[o].known_dropped(), 7, "booked on both sides = once");
        // an Eos that knows about MORE loss than our ledgers wins
        hub.record_origin_eos(o, 100, 12);
        assert_eq!(hub.origin_stats()[o].known_dropped(), 12);
        // a publisher that died before Eos still reports its ledger sum
        let p = hub.register_origin("dead");
        hub.record_origin_drops(p, 0, 2);
        hub.record_origin_gap(p, 0, 5);
        assert_eq!(hub.origin_stats()[p].known_dropped(), 7);
        // saturating: a ledger sum at the pin stays pinned
        hub.record_origin_gap(p, 0, u64::MAX);
        assert_eq!(hub.origin_stats()[p].known_dropped(), u64::MAX);
    }

    #[test]
    fn reopen_origin_reverses_close_origin_until_sealed() {
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("a");
        hub.ensure_origin_channels(a, 2);
        hub.close_origin(a);
        assert!(hub.origin_stats()[a].closed);
        hub.reopen_origin(a);
        assert!(!hub.origin_stats()[a].closed);
        assert!(!hub.probe_closed(0) && !hub.probe_closed(1));
        // a reopened channel accepts events again
        hub.feed_remote(0, msg(5, 0, 0), 8);
        assert_eq!(hub.origin_stats()[a].received, 1);
        // but a sealed hub stays terminated: reopen is a no-op
        hub.close_all();
        hub.reopen_origin(a);
        assert!(hub.probe_closed(0), "reopen after seal must not resurrect the merge");
    }

    #[test]
    fn forward_cursor_resync_rereports_current_state_without_duplicating_events() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        hub.push_batch(0, (0..5).map(|i| msg(i, 0, 0)).collect()); // 3 drop
        let mut cursor = ForwardCursor::default();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert_eq!(b.events.len(), 2);
        // a new subscriber connection: resync re-reports watermark and
        // drops in full, but popped events are gone from the hub (the
        // publisher's replay ring re-sends those)
        cursor.resync(1);
        hub.close_all();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert!(b.events.is_empty(), "no event duplication from the hub side");
        assert_eq!(b.grown_to, None, "Hello already announced the channel");
        assert!(b.beacons.contains(&(0, 4)), "current watermark re-reported");
        assert_eq!(b.drops, vec![(0, 3)], "cumulative drops re-reported");
        assert_eq!(b.closed, vec![0], "closes re-reported");
    }

    #[test]
    fn close_origin_closes_only_its_own_channels() {
        let hub = LiveHub::new("hubtest", 8, false);
        let a = hub.register_origin("a");
        let b = hub.register_origin("b");
        hub.ensure_origin_channels(a, 2);
        hub.ensure_origin_channels(b, 1);
        hub.close_origin(a);
        let stats = hub.origin_stats();
        assert!(stats[a].closed);
        assert!(!stats[b].closed);
        assert!(hub.probe_closed(0) && hub.probe_closed(1));
        assert!(!hub.probe_closed(2), "origin b must keep flowing");
    }

    #[test]
    fn merge_view_snapshot_survives_topology_growth() {
        // pop_candidate must refuse a snapshot taken before a channel
        // appeared: the newcomer could have vetoed the release decision
        let hub = LiveHub::new("hubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(5, 0, 0)]);
        hub.close(0);
        let view = hub.merge_view();
        assert!(view.has_candidate() && view.releasable);
        hub.ensure_channels(2); // topology grows under the snapshot
        assert!(hub.pop_candidate(&view).is_none(), "stale snapshot must rescan");
        // a fresh snapshot sees the new empty channel veto (watermark 0)
        let view = hub.merge_view();
        assert!(view.has_candidate() && !view.releasable);
        // the event is still there — nothing was lost to the refusal
        assert_eq!(hub.probe_queue_len(0), 1);
    }

    #[test]
    fn decode_uses_registry_classes() {
        let hub = LiveHub::new("hubtest", 8, false);
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let payload = 7u64.to_le_bytes();
        let m = hub.decode(3, 9, class.id, 42, &payload).unwrap();
        assert_eq!(m.ts, 42);
        assert_eq!(m.rank, 3);
        assert_eq!(m.tid, 9);
        assert_eq!(m.class.name, "lttng_ust_ze:zeInit_entry");
        assert_eq!(m.fields[0].as_u64(), 7);
        assert!(hub.decode(0, 0, u32::MAX, 0, &[]).is_none(), "unknown id -> None");
    }
}
