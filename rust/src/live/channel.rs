//! The live hub: bounded per-stream message channels with watermarks.
//!
//! One [`LiveHub`] sits between the tracing consumer thread and the live
//! analysis pipeline (the lttng-live relay analogue). Each traced stream
//! gets one bounded FIFO channel; the consumer decodes ring records as it
//! drains them and *try-pushes* the resulting [`EventMsg`]s — if a channel
//! is full the message is **dropped and counted**, never blocking the
//! consumer and therefore never back-pressuring the traced application
//! (paper §3.1 invariant, extended end to end).
//!
//! Each channel also carries a **watermark**: a timestamp lower bound for
//! every message the channel will deliver in the future. Watermarks
//! advance implicitly with every pushed event (per-stream timestamps are
//! non-decreasing) and explicitly through **beacons** — the LTTng-live
//! trick for quiet streams: the consumer periodically publishes "this
//! stream is quiet up to T" so the k-way merge can advance global time
//! without waiting on a stream that may never speak again.
//!
//! The hub is deliberately a single `Mutex<HubState>` + `Condvar`: the
//! consumer pushes whole drain batches under one short lock, the merge
//! ([`super::source::LiveSource`]) scans channel heads under the same
//! lock, and blocked producers/consumers park on the shared condvar.

use crate::analysis::msg::EventMsg;
use crate::tracer::btf::{registry_classes, DecodedClass};
use crate::tracer::encoder::decode_payload;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One entry in a channel queue: arrival sequence (merge tie-break),
/// the decoded message, and the push instant (latency accounting).
pub(super) struct Entry {
    pub(super) seq: u64,
    pub(super) msg: EventMsg,
    pub(super) pushed: Instant,
}

/// Per-stream channel state.
pub(super) struct Channel {
    pub(super) queue: VecDeque<Entry>,
    /// Arrival counter (monotone per channel).
    next_seq: u64,
    /// Lower bound on the timestamp of every future message.
    pub(super) watermark: u64,
    /// No further messages will ever arrive.
    pub(super) closed: bool,
    /// Messages accepted.
    received: u64,
    /// Messages dropped because the queue was full.
    dropped: u64,
    /// Beacons observed.
    beacons: u64,
}

impl Channel {
    fn new() -> Self {
        Channel {
            queue: VecDeque::new(),
            next_seq: 0,
            watermark: 0,
            closed: false,
            received: 0,
            dropped: 0,
            beacons: 0,
        }
    }
}

pub(super) struct HubState {
    pub(super) channels: Vec<Channel>,
    /// Set by [`LiveHub::close_all`]: no new channels will appear.
    pub(super) sealed: bool,
}

/// Aggregate live-transport statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LiveStats {
    /// Channels (one per traced stream).
    pub channels: usize,
    /// Messages accepted into channels.
    pub received: u64,
    /// Messages dropped at full channels (backpressure policy).
    pub dropped: u64,
    /// Beacons published.
    pub beacons: u64,
}

/// The live transport hub (see module docs).
pub struct LiveHub {
    pub(super) inner: Mutex<HubState>,
    pub(super) progress: Condvar,
    /// Per-channel queue bound, in messages.
    depth: usize,
    /// Also retain raw drained bytes in the session streams (memory-sink
    /// behaviour), so the same run can be re-analyzed post-mortem.
    retain: bool,
    /// Decoded-class table (registry metadata roundtrip) for on-line decode.
    classes: HashMap<u32, Arc<DecodedClass>>,
    /// Hostname stamped on decoded messages.
    hostname: Arc<str>,
}

impl std::fmt::Debug for LiveHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveHub")
            .field("depth", &self.depth)
            .field("retain", &self.retain)
            .field("hostname", &self.hostname)
            .finish_non_exhaustive()
    }
}

impl LiveHub {
    /// Create a hub for a session on `hostname` with the given per-stream
    /// channel `depth`. With `retain`, the consumer keeps the raw drained
    /// bytes as well (like the memory sink), so the identical run can also
    /// be analyzed post-mortem — used by the equivalence tests; production
    /// live mode runs with `retain = false` and O(streams × depth) memory.
    pub fn new(hostname: &str, depth: usize, retain: bool) -> Arc<LiveHub> {
        Arc::new(LiveHub {
            inner: Mutex::new(HubState { channels: Vec::new(), sealed: false }),
            progress: Condvar::new(),
            depth: depth.max(1),
            retain,
            classes: registry_classes(),
            hostname: Arc::from(hostname),
        })
    }

    /// Per-stream channel bound, in messages.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Whether raw drained bytes are also retained for post-mortem use.
    pub fn retain(&self) -> bool {
        self.retain
    }

    /// Decode one raw ring record into a message, using the hub's
    /// registry-derived class table (`None` for unknown class ids, same
    /// policy as `parse_trace`).
    pub fn decode(&self, rank: u32, tid: u32, id: u32, ts: u64, payload: &[u8]) -> Option<EventMsg> {
        let class = self.classes.get(&id)?;
        Some(EventMsg {
            ts,
            rank,
            tid,
            hostname: self.hostname.clone(),
            class: class.clone(),
            fields: decode_payload(&class.fields, payload),
        })
    }

    /// Make sure channels `0..n` exist. Channel index i is the session's
    /// stream index i (registration order), which is also the stream's
    /// index in a post-mortem `collect` — the merge tie-break relies on
    /// this equality for byte-identical ordering.
    pub fn ensure_channels(&self, n: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if st.channels.len() < n {
            while st.channels.len() < n {
                st.channels.push(Channel::new());
            }
            self.progress.notify_all();
        }
    }

    /// Try-push a batch of decoded messages onto channel `idx`, in order.
    /// Messages beyond the queue bound are dropped and counted — this
    /// call NEVER blocks (the consumer thread must stay realtime).
    /// Returns the number of messages dropped.
    pub fn push_batch(&self, idx: usize, batch: Vec<EventMsg>) -> u64 {
        if batch.is_empty() {
            return 0;
        }
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let depth = self.depth;
        let ch = &mut st.channels[idx];
        let mut dropped = 0;
        let now = Instant::now();
        for msg in batch {
            // the watermark advances with every delivered event: per-stream
            // timestamps are non-decreasing, so nothing later can undercut it
            ch.watermark = ch.watermark.max(msg.ts);
            if ch.queue.len() >= depth {
                dropped += 1;
                continue;
            }
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            ch.queue.push_back(Entry { seq, msg, pushed: now });
        }
        ch.dropped += dropped;
        self.progress.notify_all();
        dropped
    }

    /// Blocking push used by trace **replay** (benches / golden tests):
    /// waits for queue space instead of dropping, so a replay through
    /// bounded channels is lossless. The tracing consumer must never use
    /// this — it uses [`LiveHub::push_batch`].
    pub fn feed_blocking(&self, idx: usize, batch: Vec<EventMsg>) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        for msg in batch {
            while st.channels[idx].queue.len() >= self.depth {
                st = self.progress.wait(st).unwrap_or_else(|p| p.into_inner());
            }
            let ch = &mut st.channels[idx];
            ch.watermark = ch.watermark.max(msg.ts);
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            // stamp AFTER any wait: residence latency must not include
            // the producer's own blocked time
            ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
            self.progress.notify_all();
        }
    }

    /// Publish a beacon on channel `idx`: every future message on this
    /// channel will have `ts >= watermark`. Watermarks only move forward.
    pub fn beacon(&self, idx: usize, watermark: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let ch = &mut st.channels[idx];
        ch.beacons += 1;
        if watermark > ch.watermark {
            ch.watermark = watermark;
            self.progress.notify_all();
        }
    }

    /// Close channel `idx`: no further messages will arrive (equivalent
    /// to a watermark of +infinity once its queue drains).
    pub fn close(&self, idx: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if !st.channels[idx].closed {
            st.channels[idx].closed = true;
            self.progress.notify_all();
        }
    }

    /// Close every channel and seal the hub (no new channels): the merge
    /// drains what is queued and then terminates. Called by the consumer
    /// after its final drain.
    pub fn close_all(&self) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.sealed = true;
        for ch in st.channels.iter_mut() {
            ch.closed = true;
        }
        self.progress.notify_all();
    }

    /// Aggregate transport statistics.
    pub fn stats(&self) -> LiveStats {
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mut s = LiveStats { channels: st.channels.len(), ..Default::default() };
        for ch in &st.channels {
            s.received += ch.received;
            s.dropped += ch.dropped;
            s.beacons += ch.beacons;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracer::btf::DecodedClass;

    fn msg(ts: u64, rank: u32, tid: u32) -> EventMsg {
        EventMsg {
            ts,
            rank,
            tid,
            hostname: Arc::from("hubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn push_batch_drops_and_counts_beyond_depth() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        let dropped = hub.push_batch(0, (0..10).map(|i| msg(i, 0, 0)).collect());
        assert_eq!(dropped, 8);
        let s = hub.stats();
        assert_eq!(s.received, 2);
        assert_eq!(s.dropped, 8);
        // the watermark still advanced past the dropped events
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 9);
    }

    #[test]
    fn beacons_only_move_watermarks_forward() {
        let hub = LiveHub::new("hubtest", 8, false);
        hub.ensure_channels(1);
        hub.beacon(0, 100);
        hub.beacon(0, 50); // stale beacon must not rewind
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 100);
        assert_eq!(st.channels[0].beacons, 2);
    }

    #[test]
    fn decode_uses_registry_classes() {
        let hub = LiveHub::new("hubtest", 8, false);
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let payload = 7u64.to_le_bytes();
        let m = hub.decode(3, 9, class.id, 42, &payload).unwrap();
        assert_eq!(m.ts, 42);
        assert_eq!(m.rank, 3);
        assert_eq!(m.tid, 9);
        assert_eq!(m.class.name, "lttng_ust_ze:zeInit_entry");
        assert_eq!(m.fields[0].as_u64(), 7);
        assert!(hub.decode(0, 0, u32::MAX, 0, &[]).is_none(), "unknown id -> None");
    }
}
