//! The live hub: bounded per-stream message channels with watermarks.
//!
//! One [`LiveHub`] sits between the tracing consumer thread and the live
//! analysis pipeline (the lttng-live relay analogue). Each traced stream
//! gets one bounded FIFO channel; the consumer decodes ring records as it
//! drains them and *try-pushes* the resulting [`EventMsg`]s — if a channel
//! is full the message is **dropped and counted**, never blocking the
//! consumer and therefore never back-pressuring the traced application
//! (paper §3.1 invariant, extended end to end).
//!
//! Each channel also carries a **watermark**: a timestamp lower bound for
//! every message the channel will deliver in the future. Watermarks
//! advance implicitly with every pushed event (per-stream timestamps are
//! non-decreasing) and explicitly through **beacons** — the LTTng-live
//! trick for quiet streams: the consumer periodically publishes "this
//! stream is quiet up to T" so the k-way merge can advance global time
//! without waiting on a stream that may never speak again.
//!
//! The hub is deliberately a single `Mutex<HubState>` + `Condvar`: the
//! consumer pushes whole drain batches under one short lock, the merge
//! ([`super::source::LiveSource`]) scans channel heads under the same
//! lock, and blocked producers/consumers park on the shared condvar.

use crate::analysis::msg::EventMsg;
use crate::tracer::btf::{registry_classes, DecodedClass};
use crate::tracer::encoder::decode_payload;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One entry in a channel queue: arrival sequence (merge tie-break),
/// the decoded message, and the push instant (latency accounting).
pub(super) struct Entry {
    pub(super) seq: u64,
    pub(super) msg: EventMsg,
    pub(super) pushed: Instant,
}

/// Per-stream channel state.
pub(super) struct Channel {
    pub(super) queue: VecDeque<Entry>,
    /// Arrival counter (monotone per channel).
    next_seq: u64,
    /// Lower bound on the timestamp of every future message.
    pub(super) watermark: u64,
    /// No further messages will ever arrive.
    pub(super) closed: bool,
    /// Messages accepted.
    received: u64,
    /// Messages dropped because the queue was full.
    dropped: u64,
    /// Beacons observed.
    beacons: u64,
}

impl Channel {
    fn new() -> Self {
        Channel {
            queue: VecDeque::new(),
            next_seq: 0,
            watermark: 0,
            closed: false,
            received: 0,
            dropped: 0,
            beacons: 0,
        }
    }
}

pub(super) struct HubState {
    pub(super) channels: Vec<Channel>,
    /// Set by [`LiveHub::close_all`]: no new channels will appear.
    pub(super) sealed: bool,
}

impl HubState {
    /// THE release predicate of the live merge: a candidate at timestamp
    /// `ts` may be released iff every *empty* channel has closed or
    /// watermarked **strictly** past it (a watermark of exactly `ts`
    /// still admits a future equal-timestamp message that may sort
    /// earlier by stream index). [`super::source::LiveSource`] releases
    /// through this, and [`LiveHub::feed_remote`] waits through it — one
    /// definition, so the strict `>` byte-identity rule cannot drift
    /// between the two.
    pub(super) fn releasable(&self, ts: u64) -> bool {
        self.channels
            .iter()
            .all(|ch| !ch.queue.is_empty() || ch.closed || ch.watermark > ts)
    }

    /// Is at least one queued message releasable right now? (The head
    /// with the minimum timestamp is releasable iff any is.) Used by
    /// [`LiveHub::feed_remote`] to wait for queue space only when the
    /// merge is provably able to make progress.
    pub(super) fn has_releasable(&self) -> bool {
        let mut min_ts: Option<u64> = None;
        for ch in &self.channels {
            if let Some(e) = ch.queue.front() {
                min_ts = Some(min_ts.map_or(e.msg.ts, |b| b.min(e.msg.ts)));
            }
        }
        min_ts.map(|ts| self.releasable(ts)).unwrap_or(false)
    }
}

/// Cursor a remote forwarder keeps between [`LiveHub::next_forward_batch`]
/// calls: what has already been announced to the subscriber, so each
/// batch carries only the delta.
#[derive(Debug, Default)]
pub struct ForwardCursor {
    /// Channel count already announced.
    announced: usize,
    /// Per-channel last-forwarded state.
    per: Vec<ChannelCursor>,
}

#[derive(Debug, Default, Clone)]
struct ChannelCursor {
    watermark: u64,
    dropped: u64,
    closed: bool,
}

/// One round of forwardable progress popped from a hub — everything a
/// remote publisher must relay to keep a subscriber's mirror hub
/// equivalent. Events come out in per-stream FIFO order (the order the
/// consumer pushed them), which is all the subscriber's merge needs.
#[derive(Debug, Default)]
pub struct ForwardBatch {
    /// The channel set grew to this count (announce before the events).
    pub grown_to: Option<usize>,
    /// Popped messages as `(channel index, message)`.
    pub events: Vec<(usize, EventMsg)>,
    /// Channels whose watermark advanced, with the new watermark.
    pub beacons: Vec<(usize, u64)>,
    /// Channels whose drop count grew, with the new cumulative count.
    pub drops: Vec<(usize, u64)>,
    /// Channels that closed since the last batch.
    pub closed: Vec<usize>,
}

impl ForwardBatch {
    fn is_empty(&self) -> bool {
        self.grown_to.is_none()
            && self.events.is_empty()
            && self.beacons.is_empty()
            && self.drops.is_empty()
            && self.closed.is_empty()
    }
}

/// Aggregate live-transport statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LiveStats {
    /// Channels (one per traced stream).
    pub channels: usize,
    /// Messages accepted into channels.
    pub received: u64,
    /// Messages dropped at full channels (backpressure policy).
    pub dropped: u64,
    /// Beacons published.
    pub beacons: u64,
}

/// The live transport hub (see module docs).
pub struct LiveHub {
    pub(super) inner: Mutex<HubState>,
    pub(super) progress: Condvar,
    /// Per-channel queue bound, in messages.
    depth: usize,
    /// Also retain raw drained bytes in the session streams (memory-sink
    /// behaviour), so the same run can be re-analyzed post-mortem.
    retain: bool,
    /// Decoded-class table (registry metadata roundtrip) for on-line decode.
    classes: HashMap<u32, Arc<DecodedClass>>,
    /// Hostname stamped on decoded messages.
    hostname: Arc<str>,
}

impl std::fmt::Debug for LiveHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveHub")
            .field("depth", &self.depth)
            .field("retain", &self.retain)
            .field("hostname", &self.hostname)
            .finish_non_exhaustive()
    }
}

impl LiveHub {
    /// Create a hub for a session on `hostname` with the given per-stream
    /// channel `depth`. With `retain`, the consumer keeps the raw drained
    /// bytes as well (like the memory sink), so the identical run can also
    /// be analyzed post-mortem — used by the equivalence tests; production
    /// live mode runs with `retain = false` and O(streams × depth) memory.
    pub fn new(hostname: &str, depth: usize, retain: bool) -> Arc<LiveHub> {
        Arc::new(LiveHub {
            inner: Mutex::new(HubState { channels: Vec::new(), sealed: false }),
            progress: Condvar::new(),
            depth: depth.max(1),
            retain,
            classes: registry_classes(),
            hostname: Arc::from(hostname),
        })
    }

    /// Per-stream channel bound, in messages.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Whether raw drained bytes are also retained for post-mortem use.
    pub fn retain(&self) -> bool {
        self.retain
    }

    /// Decode one raw ring record into a message, using the hub's
    /// registry-derived class table (`None` for unknown class ids, same
    /// policy as `parse_trace`).
    pub fn decode(&self, rank: u32, tid: u32, id: u32, ts: u64, payload: &[u8]) -> Option<EventMsg> {
        let class = self.classes.get(&id)?;
        Some(EventMsg {
            ts,
            rank,
            tid,
            hostname: self.hostname.clone(),
            class: class.clone(),
            fields: decode_payload(&class.fields, payload),
        })
    }

    /// Make sure channels `0..n` exist. Channel index i is the session's
    /// stream index i (registration order), which is also the stream's
    /// index in a post-mortem `collect` — the merge tie-break relies on
    /// this equality for byte-identical ordering.
    pub fn ensure_channels(&self, n: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if st.channels.len() < n {
            while st.channels.len() < n {
                st.channels.push(Channel::new());
            }
            self.progress.notify_all();
        }
    }

    /// Try-push a batch of decoded messages onto channel `idx`, in order.
    /// Messages beyond the queue bound are dropped and counted — this
    /// call NEVER blocks (the consumer thread must stay realtime).
    /// Returns the number of messages dropped.
    pub fn push_batch(&self, idx: usize, batch: Vec<EventMsg>) -> u64 {
        if batch.is_empty() {
            return 0;
        }
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let depth = self.depth;
        let ch = &mut st.channels[idx];
        let mut dropped = 0;
        let now = Instant::now();
        for msg in batch {
            // the watermark advances with every delivered event: per-stream
            // timestamps are non-decreasing, so nothing later can undercut it
            ch.watermark = ch.watermark.max(msg.ts);
            if ch.queue.len() >= depth {
                dropped += 1;
                continue;
            }
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            ch.queue.push_back(Entry { seq, msg, pushed: now });
        }
        ch.dropped += dropped;
        self.progress.notify_all();
        dropped
    }

    /// Blocking push used by trace **replay** (benches / golden tests):
    /// waits for queue space instead of dropping, so a replay through
    /// bounded channels is lossless. The tracing consumer must never use
    /// this — it uses [`LiveHub::push_batch`].
    pub fn feed_blocking(&self, idx: usize, batch: Vec<EventMsg>) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        for msg in batch {
            while st.channels[idx].queue.len() >= self.depth {
                st = self.progress.wait(st).unwrap_or_else(|p| p.into_inner());
            }
            let ch = &mut st.channels[idx];
            ch.watermark = ch.watermark.max(msg.ts);
            let seq = ch.next_seq;
            ch.next_seq += 1;
            ch.received += 1;
            // stamp AFTER any wait: residence latency must not include
            // the producer's own blocked time
            ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
            self.progress.notify_all();
        }
    }

    /// Publish a beacon on channel `idx`: every future message on this
    /// channel will have `ts >= watermark`. Watermarks only move forward.
    pub fn beacon(&self, idx: usize, watermark: u64) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let ch = &mut st.channels[idx];
        ch.beacons += 1;
        if watermark > ch.watermark {
            ch.watermark = watermark;
            self.progress.notify_all();
        }
    }

    /// Close channel `idx`: no further messages will arrive (equivalent
    /// to a watermark of +infinity once its queue drains).
    pub fn close(&self, idx: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if !st.channels[idx].closed {
            st.channels[idx].closed = true;
            self.progress.notify_all();
        }
    }

    /// Close every channel and seal the hub (no new channels): the merge
    /// drains what is queued and then terminates. Called by the consumer
    /// after its final drain.
    pub fn close_all(&self) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        st.sealed = true;
        for ch in st.channels.iter_mut() {
            ch.closed = true;
        }
        self.progress.notify_all();
    }

    /// Hostname this hub stamps on decoded messages.
    pub fn hostname(&self) -> &str {
        &self.hostname
    }

    /// Block until there is forwardable progress beyond `cursor`, pop it
    /// and return it; `None` once the hub is sealed, every channel is
    /// closed and every queue is drained (clean end of stream).
    ///
    /// This is the **tee** a remote publisher (`iprof serve`) drains
    /// instead of a local [`super::source::LiveSource`]: it takes the
    /// merge's role of sole queue consumer, but performs no ordering work
    /// — events leave in per-stream FIFO order and the subscriber's own
    /// merge re-establishes global order. Watermarks, drop counts and
    /// closes are reported as deltas against `cursor`, so relaying every
    /// batch in order reproduces the hub state machine exactly.
    pub fn next_forward_batch(&self, cursor: &mut ForwardCursor) -> Option<ForwardBatch> {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let mut batch = ForwardBatch::default();
            if st.channels.len() > cursor.per.len() {
                cursor.per.resize(st.channels.len(), ChannelCursor::default());
            }
            if st.channels.len() > cursor.announced {
                cursor.announced = st.channels.len();
                batch.grown_to = Some(cursor.announced);
            }
            for (i, ch) in st.channels.iter_mut().enumerate() {
                let cur = &mut cursor.per[i];
                while let Some(e) = ch.queue.pop_front() {
                    batch.events.push((i, e.msg));
                }
                if ch.watermark > cur.watermark {
                    cur.watermark = ch.watermark;
                    batch.beacons.push((i, ch.watermark));
                }
                if ch.dropped > cur.dropped {
                    cur.dropped = ch.dropped;
                    batch.drops.push((i, ch.dropped));
                }
                if ch.closed && !cur.closed {
                    cur.closed = true;
                    batch.closed.push(i);
                }
            }
            if !batch.is_empty() {
                // replay producers may be parked waiting for queue space
                self.progress.notify_all();
                return Some(batch);
            }
            if st.sealed && st.channels.iter().all(|ch| ch.closed && ch.queue.is_empty()) {
                return None;
            }
            // Liveness backstop only, like the merge's own wait.
            let (guard, _) = self
                .progress
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }

    /// Lossless single-message feed for a **remote subscriber's** mirror
    /// hub (`iprof attach`). Unlike [`LiveHub::feed_blocking`] it ignores
    /// the per-channel depth and instead waits only while the *total*
    /// queued message count is at or above `soft_cap` **and** the merge
    /// has releasable work — the one situation where waiting is provably
    /// deadlock-free. A single reader thread multiplexes every stream of
    /// the connection, so blocking on one full channel could starve the
    /// very beacon frame (later in the byte stream) the merge needs to
    /// drain it; when nothing is releasable the message is admitted
    /// immediately and memory grows transiently, bounded by one publisher
    /// watermark round, not by the trace.
    pub fn feed_remote(&self, idx: usize, msg: EventMsg, soft_cap: usize) {
        let mut st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let total: usize = st.channels.iter().map(|c| c.queue.len()).sum();
            if total < soft_cap || !st.has_releasable() {
                let ch = &mut st.channels[idx];
                ch.watermark = ch.watermark.max(msg.ts);
                let seq = ch.next_seq;
                ch.next_seq += 1;
                ch.received += 1;
                ch.queue.push_back(Entry { seq, msg, pushed: Instant::now() });
                self.progress.notify_all();
                return;
            }
            st = self.progress.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Aggregate transport statistics.
    pub fn stats(&self) -> LiveStats {
        let st = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mut s = LiveStats { channels: st.channels.len(), ..Default::default() };
        for ch in &st.channels {
            s.received += ch.received;
            s.dropped += ch.dropped;
            s.beacons += ch.beacons;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracer::btf::DecodedClass;

    fn msg(ts: u64, rank: u32, tid: u32) -> EventMsg {
        EventMsg {
            ts,
            rank,
            tid,
            hostname: Arc::from("hubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn push_batch_drops_and_counts_beyond_depth() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(1);
        let dropped = hub.push_batch(0, (0..10).map(|i| msg(i, 0, 0)).collect());
        assert_eq!(dropped, 8);
        let s = hub.stats();
        assert_eq!(s.received, 2);
        assert_eq!(s.dropped, 8);
        // the watermark still advanced past the dropped events
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 9);
    }

    #[test]
    fn beacons_only_move_watermarks_forward() {
        let hub = LiveHub::new("hubtest", 8, false);
        hub.ensure_channels(1);
        hub.beacon(0, 100);
        hub.beacon(0, 50); // stale beacon must not rewind
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].watermark, 100);
        assert_eq!(st.channels[0].beacons, 2);
    }

    #[test]
    fn forward_batches_report_events_watermarks_drops_and_eos() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        hub.push_batch(0, (0..5).map(|i| msg(i, 0, 0)).collect()); // 3 drop
        hub.beacon(1, 77);
        let mut cursor = ForwardCursor::default();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert_eq!(b.grown_to, Some(2));
        assert_eq!(b.events.len(), 2, "only the accepted messages are popped");
        assert_eq!(b.events[0].0, 0);
        assert!(b.beacons.contains(&(0, 4)), "watermark passed the dropped events");
        assert!(b.beacons.contains(&(1, 77)));
        assert_eq!(b.drops, vec![(0, 3)]);
        assert!(b.closed.is_empty());
        hub.close_all();
        let b = hub.next_forward_batch(&mut cursor).unwrap();
        assert!(b.events.is_empty());
        assert_eq!(b.closed, vec![0, 1]);
        assert!(hub.next_forward_batch(&mut cursor).is_none(), "then clean EOS");
        // the cursor keeps batches delta-only: nothing is ever re-reported
    }

    #[test]
    fn feed_remote_ignores_per_channel_depth_when_nothing_is_releasable() {
        let hub = LiveHub::new("hubtest", 2, false);
        hub.ensure_channels(2);
        // channel 1 stays empty with watermark 0: nothing is releasable,
        // so feed_remote must admit far beyond depth*channels without
        // blocking (a blocked reader here would deadlock a real attach)
        for i in 0..50 {
            hub.feed_remote(0, msg(i, 0, 0), 4);
        }
        let st = hub.inner.lock().unwrap();
        assert_eq!(st.channels[0].queue.len(), 50, "lossless: nothing dropped");
        assert!(!st.has_releasable(), "channel 1 still vetoes");
    }

    #[test]
    fn decode_uses_registry_classes() {
        let hub = LiveHub::new("hubtest", 8, false);
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let payload = 7u64.to_le_bytes();
        let m = hub.decode(3, 9, class.id, 42, &payload).unwrap();
        assert_eq!(m.ts, 42);
        assert_eq!(m.rank, 3);
        assert_eq!(m.tid, 9);
        assert_eq!(m.class.name, "lttng_ust_ze:zeInit_entry");
        assert_eq!(m.fields[0].as_u64(), 7);
        assert!(hub.decode(0, 0, u32::MAX, 0, &[]).is_none(), "unknown id -> None");
    }
}
