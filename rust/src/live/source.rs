//! `LiveSource`: the on-line k-way merge over a hub's channels.
//!
//! Yields decoded messages in exactly the global order the post-mortem
//! [`crate::analysis::MessageSource`] produces — non-decreasing timestamp,
//! ties broken by (stream index, in-stream arrival order) — but *while the
//! application is still running*. A message is released only once it is
//! provably final:
//!
//! * channels with queued messages are compared head-to-head;
//! * a channel with an **empty** queue vetoes release until its watermark
//!   moves strictly past the candidate timestamp (beacons advance the
//!   watermark when the stream is quiet) or the channel closes.
//!
//! The strict `>` matters: a watermark of `W` still permits a future
//! message at exactly `W`, and if that message belongs to an
//! earlier-indexed stream it must sort *before* an equal-timestamp
//! candidate — releasing on `>=` would break byte-identity with the
//! post-mortem merge.
//!
//! Memory is O(#streams × channel depth); the merge never buffers beyond
//! the channel bounds, which is the whole point of live mode.

use super::channel::LiveHub;
use crate::analysis::msg::EventMsg;
use std::sync::Arc;
use std::time::Duration;

/// Latency accounting for merged messages (push → pop).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    /// Messages merged.
    pub merged: u64,
    /// Sum of per-message channel residence times.
    pub total: Duration,
    /// Worst per-message channel residence time.
    pub max: Duration,
}

impl LatencySummary {
    /// Mean channel residence time per message.
    pub fn mean(&self) -> Duration {
        if self.merged == 0 {
            Duration::ZERO
        } else {
            // divide in u128 nanos: `Duration / u32` would truncate the
            // count (and panic on exact multiples of 2^32)
            Duration::from_nanos((self.total.as_nanos() / self.merged as u128) as u64)
        }
    }

    fn record(&mut self, d: Duration) {
        // saturating like every accounting counter: stick at max rather
        // than wrap back toward "nothing merged"
        self.merged = self.merged.saturating_add(1);
        self.total = self.total.saturating_add(d);
        self.max = self.max.max(d);
    }
}

/// Blocking message iterator over a [`LiveHub`] (see module docs).
pub struct LiveSource {
    hub: Arc<LiveHub>,
    latency: LatencySummary,
}

impl LiveSource {
    /// Open the merge over `hub`. One `LiveSource` per hub: the merge is
    /// the single consumer of every channel.
    pub fn new(hub: Arc<LiveHub>) -> Self {
        LiveSource { hub, latency: LatencySummary::default() }
    }

    /// Latency summary over everything merged so far.
    pub fn latency(&self) -> &LatencySummary {
        &self.latency
    }

    /// The hub this merge drains (the pipeline driver reaches its
    /// telemetry registry through this).
    pub fn hub(&self) -> &Arc<LiveHub> {
        &self.hub
    }
}

impl Iterator for LiveSource {
    type Item = EventMsg;

    /// Blocks until the next globally-ordered message is releasable, or
    /// returns `None` once the hub is sealed and fully drained.
    fn next(&mut self) -> Option<EventMsg> {
        loop {
            // Per-round snapshot over the sharded hub: best head by
            // (ts, channel index, arrival seq), release gate, termination.
            // One short lock acquisition per shard, no global lock.
            let view = self.hub.merge_view();
            if view.has_candidate() {
                if view.releasable {
                    // pop re-validates the topology version: a channel
                    // created since the scan could have vetoed the release,
                    // so a stale snapshot rescans instead of popping
                    if let Some(entry) = self.hub.pop_candidate(&view) {
                        let residence = entry.pushed.elapsed();
                        self.latency.record(residence);
                        let reg = self.hub.telemetry();
                        reg.merge_events.inc();
                        reg.merge_latency_ns.add(residence.as_nanos().min(u128::from(u64::MAX)) as u64);
                        // replay producers may be parked waiting for space
                        self.hub.progress.notify_all();
                        return Some(entry.msg);
                    }
                    continue;
                }
            } else if view.finished {
                return None;
            }
            // Nothing releasable: park until a push/beacon/close moves the
            // world. The timeout is a liveness backstop only (a vanished
            // producer, or a wakeup racing the snapshot); correctness
            // never depends on it.
            self.hub.wait_progress();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracer::btf::DecodedClass;

    fn msg(ts: u64, rank: u32, tid: u32) -> EventMsg {
        EventMsg {
            ts,
            rank,
            tid,
            hostname: Arc::from("srctest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn merges_two_channels_in_time_order_with_stream_tiebreak() {
        let hub = LiveHub::new("srctest", 64, false);
        hub.ensure_channels(2);
        hub.push_batch(0, vec![msg(5, 0, 0), msg(10, 0, 1)]);
        hub.push_batch(1, vec![msg(5, 1, 0), msg(7, 1, 1)]);
        hub.close_all();
        let got: Vec<(u64, u32)> = LiveSource::new(hub).map(|m| (m.ts, m.rank)).collect();
        // equal ts 5: stream 0 first; then 7 from stream 1; then 10
        assert_eq!(got, vec![(5, 0), (5, 1), (7, 1), (10, 0)]);
    }

    #[test]
    fn empty_channel_holds_merge_until_watermark_passes_strictly() {
        let hub = LiveHub::new("srctest", 64, false);
        hub.ensure_channels(2);
        hub.push_batch(0, vec![msg(100, 0, 0)]);
        // channel 1 quiet with watermark == candidate ts: must NOT release
        hub.beacon(1, 100);
        assert!(!hub.probe_releasable(100), "watermark == ts must still veto release");
        // a late equal-timestamp message on the quiet LOWER-indexed..
        // (here higher-indexed) stream arrives and must sort after;
        // then the strictly-greater beacon releases everything
        hub.push_batch(1, vec![msg(100, 1, 0)]);
        hub.close_all();
        let got: Vec<(u64, u32)> = LiveSource::new(hub).map(|m| (m.ts, m.rank)).collect();
        assert_eq!(got, vec![(100, 0), (100, 1)]);
    }

    #[test]
    fn quiet_beacon_only_channel_does_not_stall_the_merge() {
        let hub = LiveHub::new("srctest", 64, false);
        hub.ensure_channels(2);
        let h2 = hub.clone();
        let feeder = std::thread::spawn(move || {
            for i in 0..50u64 {
                h2.push_batch(0, vec![msg(i * 10, 0, i as u32)]);
                // channel 1 never carries an event — beacons only
                h2.beacon(1, i * 10 + 1);
            }
            h2.close_all();
        });
        let got: Vec<u64> = LiveSource::new(hub).map(|m| m.ts).collect();
        feeder.join().unwrap();
        assert_eq!(got.len(), 50);
        assert!(got.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn sealed_empty_hub_terminates() {
        let hub = LiveHub::new("srctest", 4, false);
        hub.close_all();
        assert_eq!(LiveSource::new(hub).count(), 0);
    }
}
