//! Live analysis: on-line sinks fed from the tracing consumer thread.
//!
//! The lttng-live / babeltrace2-live analogue. Post-mortem analysis
//! (paper §3.4) retains the whole trace before looking at it; at scale
//! that is exactly what doesn't fit. Live mode runs the *same* streaming
//! analysis graph while the application executes:
//!
//! ```text
//!  traced threads ── SPSC rings ──► consumer thread
//!                                      │ decode + try-push   (never blocks)
//!                                      ▼
//!                  LiveHub: bounded per-stream channels        channel.rs
//!                  + watermarks advanced by events and BEACONS
//!                                      │
//!                                      ▼
//!                  LiveSource: blocking k-way merge,           source.rs
//!                  byte-identical order to MessageSource
//!                                      │
//!                                      ▼
//!                  run_live_pipeline: IntervalTracker filter   pipeline.rs
//!                  + unmodified AnalysisSink fan-out, optional
//!                  periodic refresh snapshots
//! ```
//!
//! Three invariants carry the design:
//!
//! 1. **The application never blocks.** Rings drop-and-count when full
//!    (as before); channels drop-and-count when full; the consumer only
//!    ever try-pushes. A slow sink costs *completeness* (counted), never
//!    application time.
//! 2. **Bounded memory.** Analysis-side state is O(#streams × channel
//!    depth) plus sink state — independent of trace length. No
//!    `TraceData`, no `ParsedTrace`.
//! 3. **Byte-identical ordering.** `LiveSource` releases messages in the
//!    exact (ts, stream index, in-stream index) order of the post-mortem
//!    merge, using per-stream watermarks: beacons (periodic per-stream
//!    quiescence timestamps published by the consumer, LTTng-live style)
//!    let global time advance past quiet streams without unbounded
//!    buffering. See `rust/ARCHITECTURE.md` § "Live mode".
//!
//! Entry points: [`crate::coordinator::run_live`] (whole-workload runs,
//! `iprof --live`), [`replay_trace`] (drive a recorded trace through the
//! live machinery, for benches and equivalence tests). The hub also
//! exposes a forwarding tee ([`LiveHub::next_forward_batch`], plus the
//! non-blocking [`LiveHub::try_forward_batch`] a resumable publisher
//! drains between subscriber connections) and a remote-subscriber feed
//! ([`LiveHub::feed_remote`]) so [`crate::remote`] can split this
//! pipeline across a socket (`iprof serve` / `iprof attach`) without
//! touching the merge — origin registration
//! ([`LiveHub::register_origin`]) so one hub can mirror **several**
//! publishers at once with namespaced stream ids (`iprof attach
//! <addr> <addr>...`, see [`crate::remote::fanin`]) — and the
//! reconnect bookkeeping ([`LiveHub::record_origin_gap`] /
//! [`LiveHub::reopen_origin`]) that lets a dropped publisher re-join
//! its own origin with resume gaps accounted, never silent (THRL v2
//! session resumption; operator view in `docs/GUIDE.md`).

pub mod channel;
pub mod pipeline;
pub mod source;

pub use channel::{ForwardBatch, ForwardCursor, LiveHub, LiveStats, OriginStats, SubOriginStats};
pub use pipeline::{run_live_pipeline, LivePipelineResult};
pub use source::{LatencySummary, LiveSource};

use crate::tracer::btf::TraceData;
use crate::tracer::ringbuf::{self, RECORD_HEADER};
use std::time::Duration;

/// Live-mode knobs (the `iprof --live` surface).
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Per-stream channel bound, in messages. Live analysis memory is
    /// O(#streams × depth); a full channel drops (and counts) messages.
    pub channel_depth: usize,
    /// Also retain raw drained bytes (memory-sink behaviour) so the same
    /// run can be re-analyzed post-mortem. Used by equivalence tests;
    /// defeats the memory bound, so off by default.
    pub retain: bool,
    /// Period for interim sink snapshots (`--refresh <ms>`); `None`
    /// disables refresh.
    pub refresh: Option<Duration>,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig { channel_depth: 1024, retain: false, refresh: None }
    }
}

/// Replay a recorded trace through the live machinery: one feeder thread
/// per stream walks its raw records in `chunk`-sized batches, decodes
/// through the hub's class table, and **blocking-pushes** (lossless),
/// publishing a beacon at the next pending record's timestamp after every
/// batch — so the merge advances exactly as it would have on-line.
///
/// Feeders are per-stream threads on purpose: a blocked feeder only ever
/// waits for the merge to drain *its own full queue*, and the merge is
/// only ever vetoed by an *empty* channel — so no wait cycle can form.
/// Closes every channel (and seals the hub) when all streams end.
///
/// The class ids in `trace` must come from this process's registry
/// (true for any trace recorded or collected in-process).
pub fn replay_trace(hub: &LiveHub, trace: &TraceData, chunk: usize) {
    hub.ensure_channels(trace.streams.len());
    let chunk = chunk.max(1);
    std::thread::scope(|scope| {
        for (i, stream) in trace.streams.iter().enumerate() {
            scope.spawn(move || {
                let mut off = 0usize;
                loop {
                    let mut batch = Vec::with_capacity(chunk);
                    let mut next_ts = None;
                    while let Some((ts, record)) = peek_record(&stream.bytes, off) {
                        if batch.len() >= chunk {
                            next_ts = Some(ts);
                            break;
                        }
                        off += record.len();
                        let (id, ts, payload) = ringbuf::parse_record(record);
                        if let Some(msg) = hub.decode(stream.rank, stream.tid, id, ts, payload) {
                            batch.push(msg);
                        }
                    }
                    if !batch.is_empty() {
                        hub.feed_blocking(i, batch);
                    }
                    match next_ts {
                        // future records on this stream start exactly at next_ts
                        Some(ts) => hub.beacon(i, ts),
                        None => {
                            hub.close(i);
                            break;
                        }
                    }
                }
            });
        }
    });
    hub.close_all();
}

/// The record starting at `off`, as `(ts, full record slice)`, or `None`
/// at end of stream (or at wrap padding, which never reaches collected
/// streams).
fn peek_record(bytes: &[u8], off: usize) -> Option<(u64, &[u8])> {
    if off + RECORD_HEADER > bytes.len() {
        return None;
    }
    let total = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    if total == ringbuf::PAD_MARKER {
        return None;
    }
    let total = total as usize;
    let record = &bytes[off..off + total];
    let (_, ts, _) = ringbuf::parse_record(record);
    Some((ts, record))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    #[test]
    fn replay_trace_is_lossless_and_ordered() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..100 {
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);

        // tiny depth + tiny chunk: the blocking feed must still be lossless
        let hub = LiveHub::new("replaynode", 4, false);
        let source = LiveSource::new(hub.clone());
        let merged = std::thread::scope(|s| {
            let feeder = s.spawn(|| replay_trace(&hub, &trace, 3));
            let merged: Vec<u64> = source.map(|m| m.ts).collect();
            feeder.join().unwrap();
            merged
        });
        assert_eq!(merged.len() as u64, trace.record_count());
        assert!(merged.windows(2).all(|w| w[0] <= w[1]), "replay must be time-ordered");
        let stats = hub.stats();
        assert_eq!(stats.dropped, 0, "blocking replay never drops");
        assert_eq!(stats.received, trace.record_count());
    }
}
