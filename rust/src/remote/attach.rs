//! The subscribing end of a remote-live connection (`iprof attach`).
//!
//! [`Attachment::open`] performs the handshake (preamble check +
//! [`Frame::Hello`]), rebuilds the publisher's class table from the
//! shipped BTF metadata, and spawns a reader thread that mirrors every
//! frame into a local [`LiveHub`]: events are reconstructed into
//! [`EventMsg`]s and fed losslessly, beacons move watermarks, closes
//! close channels, and [`Frame::Eos`] seals the hub. The **unmodified**
//! [`LiveSource`] k-way merge then drains that mirror hub — so a remote
//! viewer runs the exact same merge + sinks as local `iprof --live`, and
//! for a lossless feed produces byte-identical output.
//!
//! The reader multiplexes all streams from one byte stream, so it must
//! never block on a single full channel (the beacon that would drain it
//! may be *behind* it in the stream); it feeds through
//! [`LiveHub::feed_remote`], which waits for queue space only while the
//! merge provably has releasable work.

use super::frame::{self, Frame, FrameError};
use crate::analysis::EventMsg;
use crate::live::{LiveHub, LiveSource};
use crate::tracer::btf::{parse_metadata, DecodedClass};
use std::collections::HashMap;
use std::io::{self, BufReader, Read};
use std::sync::Arc;
use std::thread::JoinHandle;

/// What the reader thread observed over the whole connection.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Frames received (Hello included).
    pub frames: u64,
    /// Event frames among them.
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Events skipped because their class id was not in the Hello
    /// metadata (same skip-unknown policy as `parse_trace`).
    pub unknown_classes: u64,
    /// Publisher-side total accepted messages (from Eos).
    pub server_received: u64,
    /// Publisher-side total dropped messages (from Eos) — the remote
    /// end of the drop accounting: nonzero means the on-line view is
    /// incomplete and says by exactly how much.
    pub server_dropped: u64,
    /// Transport/protocol error that ended the stream before a clean
    /// Eos, if any. The mirror hub is sealed either way, so everything
    /// received up to the cut was still merged and analyzed — partial
    /// reports survive a dying publisher, which is the whole point of
    /// watching one live.
    pub error: Option<String>,
}

/// A live connection to a remote publisher (see module docs).
pub struct Attachment {
    hub: Arc<LiveHub>,
    reader: JoinHandle<RemoteStats>,
    /// Hostname announced by the publisher's Hello.
    pub hostname: String,
}

impl Attachment {
    /// Handshake on `conn` and start mirroring.
    ///
    /// Blocks until the preamble and Hello arrive (so bad magic or an
    /// unsupported version fail *here*, synchronously), then spawns the
    /// reader thread. `depth` bounds the mirror hub's per-channel queues
    /// the same way `--live-depth` does locally; the reader's soft cap is
    /// `depth × channels` total messages (see [`LiveHub::feed_remote`]).
    pub fn open<R: Read + Send + 'static>(conn: R, depth: usize) -> io::Result<Attachment> {
        let mut r = BufReader::new(conn);
        frame::read_preamble(&mut r)?;
        let hello = frame::read_frame(&mut r)?;
        let Frame::Hello { hostname, metadata, streams } = hello else {
            return Err(FrameError::Malformed("first frame must be Hello").into());
        };
        if streams > frame::MAX_STREAMS {
            return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
        }
        let md = parse_metadata(&metadata)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
        let classes: HashMap<u32, Arc<DecodedClass>> =
            md.classes.into_iter().map(|(id, c)| (id, Arc::new(c))).collect();

        let hub = LiveHub::new(&hostname, depth, false);
        hub.ensure_channels(streams as usize);
        let host_arc: Arc<str> = Arc::from(hostname.as_str());
        let hub2 = hub.clone();
        let depth = depth.max(1);
        let reader = std::thread::Builder::new()
            .name("thapi-attach".into())
            .spawn(move || {
                let mut stats = RemoteStats { frames: 1, ..Default::default() };
                let mut channels = streams as usize;
                let res = pump(&mut r, &hub2, &classes, &host_arc, depth, &mut channels, &mut stats);
                // Always seal the mirror hub — also on transport errors —
                // so the merge terminates instead of waiting forever; the
                // stats (with the error recorded) survive alongside the
                // partial analysis.
                hub2.close_all();
                if let Err(e) = res {
                    stats.error = Some(e.to_string());
                }
                stats
            })?;
        Ok(Attachment { hub, reader, hostname })
    }

    /// The mirror hub (e.g. for [`LiveHub::stats`] after the run).
    pub fn hub(&self) -> &Arc<LiveHub> {
        &self.hub
    }

    /// Open the merge over the mirror hub. One source per attachment,
    /// like one `LiveSource` per local hub.
    pub fn source(&self) -> LiveSource {
        LiveSource::new(self.hub.clone())
    }

    /// Join the reader and return the connection totals. Call after the
    /// merge has drained (the reader returns at Eos or on error; a
    /// transport error is recorded in [`RemoteStats::error`] rather than
    /// discarding the stats, so partial runs keep their accounting).
    pub fn finish(self) -> io::Result<RemoteStats> {
        self.reader
            .join()
            .map_err(|_| io::Error::new(io::ErrorKind::Other, "attach reader thread panicked"))
    }
}

/// Frame pump: apply every frame to the mirror hub until Eos.
///
/// `channels` is the reader's local view of the channel count — grown on
/// `Streams` frames and on out-of-range indices — so the hot Event path
/// takes no extra hub lock to recompute its soft cap. Stream counts and
/// indices are bounded by [`frame::MAX_STREAMS`]: a corrupt frame is a
/// protocol error, never a giant allocation.
fn pump(
    r: &mut impl Read,
    hub: &LiveHub,
    classes: &HashMap<u32, Arc<DecodedClass>>,
    hostname: &Arc<str>,
    depth: usize,
    channels: &mut usize,
    stats: &mut RemoteStats,
) -> io::Result<()> {
    fn grow(hub: &LiveHub, channels: &mut usize, want: u32) -> io::Result<usize> {
        if want > frame::MAX_STREAMS {
            return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
        }
        let want = want as usize;
        if want > *channels {
            hub.ensure_channels(want);
            *channels = want;
        }
        Ok(want)
    }

    loop {
        let f = frame::read_frame(r)?;
        stats.frames += 1;
        match f {
            Frame::Hello { .. } => {
                return Err(FrameError::Malformed("duplicate Hello").into());
            }
            Frame::Streams { count } => {
                grow(hub, channels, count)?;
            }
            Frame::Event { stream, event } => {
                let idx = grow(hub, channels, stream.saturating_add(1))? - 1;
                stats.events += 1;
                match classes.get(&event.class_id) {
                    Some(class) => {
                        let msg = EventMsg {
                            ts: event.ts,
                            rank: event.rank,
                            tid: event.tid,
                            hostname: hostname.clone(),
                            class: class.clone(),
                            fields: event.fields,
                        };
                        hub.feed_remote(idx, msg, depth * (*channels).max(1));
                    }
                    None => stats.unknown_classes += 1,
                }
            }
            Frame::Beacon { stream, watermark } => {
                let idx = grow(hub, channels, stream.saturating_add(1))? - 1;
                hub.beacon(idx, watermark);
                stats.beacons += 1;
            }
            Frame::Drops { .. } => {
                // Cumulative per-stream counts; the Eos totals are what we
                // surface. Nothing to mirror locally — drops happened
                // before the wire.
            }
            Frame::Close { stream } => {
                let idx = grow(hub, channels, stream.saturating_add(1))? - 1;
                hub.close(idx);
            }
            Frame::Eos { received, dropped } => {
                stats.server_received = received;
                stats.server_dropped = dropped;
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::remote::publish::publish;
    use crate::tracer::btf::registry_classes;
    use std::io::Cursor;

    fn sample_msg(hub: &LiveHub, ts: u64) -> EventMsg {
        // go through the real registry so the attach side can resolve the id
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        hub.decode(0, 7, class.id, ts, &0u64.to_le_bytes()).unwrap()
    }

    #[test]
    fn attach_mirrors_a_published_hub_end_to_end() {
        let hub = LiveHub::new("servnode", 64, false);
        hub.ensure_channels(2);
        hub.push_batch(0, vec![sample_msg(&hub, 5), sample_msg(&hub, 10)]);
        hub.push_batch(1, vec![sample_msg(&hub, 7)]);
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();

        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.hostname, "servnode");
        let merged: Vec<(u64, u32)> = att.source().map(|m| (m.ts, m.tid)).collect();
        assert_eq!(merged.iter().map(|(ts, _)| *ts).collect::<Vec<_>>(), vec![5, 7, 10]);
        assert!(merged.iter().all(|(_, tid)| *tid == 7), "tid survives the wire");
        let stats = att.finish().unwrap();
        assert_eq!(stats.events, 3);
        assert_eq!(stats.server_received, 3);
        assert_eq!(stats.server_dropped, 0);
        assert_eq!(stats.unknown_classes, 0);
    }

    #[test]
    fn attach_rejects_wrong_version_synchronously() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&frame::MAGIC);
        wire.extend_from_slice(&99u32.to_le_bytes());
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn hostile_stream_counts_are_rejected_not_allocated() {
        // a 9-byte Streams frame must never become a multi-GB channel table
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: String::new(),
                streams: 1,
            },
        )
        .unwrap();
        frame::write_frame(&mut wire, &Frame::Streams { count: u32::MAX }).unwrap();
        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.source().count(), 0);
        let stats = att.finish().unwrap();
        assert!(
            stats.error.as_deref().unwrap_or("").contains("MAX_STREAMS"),
            "{stats:?}"
        );
        // and a hostile Hello fails synchronously
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: String::new(),
                streams: u32::MAX,
            },
        )
        .unwrap();
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("MAX_STREAMS"), "{err}");
    }

    #[test]
    fn attach_requires_hello_first() {
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(&mut wire, &Frame::Streams { count: 1 }).unwrap();
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("Hello"), "{err}");
    }

    #[test]
    fn reconstructed_messages_decode_like_local_ones() {
        // unknown class ids are skipped and counted, not fatal
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: crate::tracer::btf::generate_metadata(&[]),
                streams: 1,
            },
        )
        .unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Event {
                stream: 0,
                event: frame::WireEvent {
                    ts: 1,
                    rank: 0,
                    tid: 0,
                    class_id: u32::MAX, // not in the registry
                    fields: vec![],
                },
            },
        )
        .unwrap();
        frame::write_frame(&mut wire, &Frame::Eos { received: 1, dropped: 0 }).unwrap();
        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.source().count(), 0);
        let stats = att.finish().unwrap();
        assert_eq!(stats.unknown_classes, 1);
        // sanity: the registry table used by real publishers is non-trivial
        assert!(!registry_classes().is_empty());
    }
}
