//! The subscribing end of a remote-live connection (`iprof attach`).
//!
//! [`Attachment`] is the single-publisher special case of the
//! multi-publisher fan-in ([`super::fanin::FanIn`]) and delegates to it:
//! [`Attachment::open`] performs the handshake (preamble check +
//! [`Frame::Hello`](super::frame::Frame::Hello)) synchronously, then a
//! reader thread mirrors every frame into a local [`LiveHub`]: events
//! are reconstructed into [`EventMsg`](crate::analysis::EventMsg)s and
//! fed losslessly, beacons move watermarks, closes close channels, and
//! Eos seals the hub. The **unmodified** [`LiveSource`] k-way merge then
//! drains that mirror hub — so a remote viewer runs the exact same merge
//! + sinks as local `iprof --live`, and for a lossless feed produces
//! byte-identical output.
//!
//! The reader multiplexes all streams from one byte stream, so it must
//! never block on a single full channel (the beacon that would drain it
//! may be *behind* it in the stream); it feeds through
//! [`LiveHub::feed_remote`] (or, for a v3 `EventBatch`, one
//! [`LiveHub::feed_remote_batch`] push per frame), which waits for queue
//! space only while the merge provably has releasable work. Which wire
//! the publisher spoke — batched v3 or the per-event v2 fallback — is
//! reported per connection in [`RemoteStats::wire_version`] /
//! [`RemoteStats::batches`].

use super::fanin::FanIn;
pub use super::fanin::RemoteStats;
use crate::live::{LiveHub, LiveSource};
use std::io::{self, Read};
use std::sync::Arc;

/// A live connection to one remote publisher (see module docs).
///
/// # Examples
///
/// Mirror a published wire (here: an in-memory one) and drain it with
/// the standard merge:
///
/// ```
/// use thapi::live::LiveHub;
/// use thapi::remote::{publish, Attachment};
///
/// // a tiny publisher-side hub with one event, published to bytes
/// let hub = LiveHub::new("node0", 64, false);
/// hub.ensure_channels(1);
/// let class = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
/// let msg = hub.decode(0, 0, class.id, 5, &0u64.to_le_bytes()).unwrap();
/// hub.push_batch(0, vec![msg]);
/// hub.close_all();
/// let mut wire = Vec::new();
/// publish(&hub, &mut wire).unwrap();
///
/// // the subscriber side: handshake, mirror, merge, account
/// let att = Attachment::open(std::io::Cursor::new(wire), 64).unwrap();
/// assert_eq!(att.hostname, "node0");
/// let merged: Vec<u64> = att.source().map(|m| m.ts).collect();
/// assert_eq!(merged, vec![5]);
/// let stats = att.finish().unwrap();
/// assert_eq!(stats.server_dropped, 0, "lossless feed");
/// ```
///
/// For reconnect/resume against a live `iprof serve --resume-buffer`
/// publisher, use [`FanIn::open_resumable`] (an `Attachment` is its
/// N = 1 case) — see `docs/GUIDE.md`.
pub struct Attachment {
    fanin: FanIn,
    /// Hostname announced by the publisher's Hello.
    pub hostname: String,
}

impl Attachment {
    /// Handshake on `conn` and start mirroring.
    ///
    /// Blocks until the preamble and Hello arrive (so bad magic or an
    /// unsupported version fail *here*, synchronously), then spawns the
    /// reader thread. `depth` bounds the mirror hub's per-channel queues
    /// the same way `--live-depth` does locally; the reader's soft cap is
    /// `depth × channels` total messages (see [`LiveHub::feed_remote`]).
    pub fn open<R: Read + Send + 'static>(conn: R, depth: usize) -> io::Result<Attachment> {
        let fanin = FanIn::open(vec![conn], depth)?;
        let hostname = fanin.hostnames[0].clone();
        Ok(Attachment { fanin, hostname })
    }

    /// The mirror hub (e.g. for [`LiveHub::stats`] after the run).
    pub fn hub(&self) -> &Arc<LiveHub> {
        self.fanin.hub()
    }

    /// Open the merge over the mirror hub. One source per attachment,
    /// like one `LiveSource` per local hub.
    pub fn source(&self) -> LiveSource {
        self.fanin.source()
    }

    /// Join the reader and return the connection totals. Call after the
    /// merge has drained (the reader returns at Eos or on error; a
    /// transport error is recorded in [`RemoteStats::error`] rather than
    /// discarding the stats, so partial runs keep their accounting).
    pub fn finish(self) -> io::Result<RemoteStats> {
        let stats = self.fanin.finish()?;
        Ok(stats.per.into_iter().next().expect("one reader per attachment"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::EventMsg;
    use crate::remote::frame::{self, Frame};
    use crate::remote::publish::publish;
    use crate::tracer::btf::registry_classes;
    use std::io::Cursor;

    fn sample_msg(hub: &LiveHub, ts: u64) -> EventMsg {
        // go through the real registry so the attach side can resolve the id
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        hub.decode(0, 7, class.id, ts, &0u64.to_le_bytes()).unwrap()
    }

    #[test]
    fn attach_mirrors_a_published_hub_end_to_end() {
        let hub = LiveHub::new("servnode", 64, false);
        hub.ensure_channels(2);
        hub.push_batch(0, vec![sample_msg(&hub, 5), sample_msg(&hub, 10)]);
        hub.push_batch(1, vec![sample_msg(&hub, 7)]);
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();

        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.hostname, "servnode");
        let merged: Vec<(u64, u32)> = att.source().map(|m| (m.ts, m.tid)).collect();
        assert_eq!(merged.iter().map(|(ts, _)| *ts).collect::<Vec<_>>(), vec![5, 7, 10]);
        assert!(merged.iter().all(|(_, tid)| *tid == 7), "tid survives the wire");
        let stats = att.finish().unwrap();
        assert_eq!(stats.events, 3);
        assert_eq!(stats.server_received, 3);
        assert_eq!(stats.server_dropped, 0);
        assert_eq!(stats.unknown_classes, 0);
        assert_eq!(stats.wire_version, 3, "default publish speaks v3");
        assert!(stats.batches >= 1, "v3 events arrive batched");
    }

    #[test]
    fn attach_rejects_wrong_version_synchronously() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&frame::MAGIC);
        wire.extend_from_slice(&99u32.to_le_bytes());
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn hostile_stream_counts_are_rejected_not_allocated() {
        // a 9-byte Streams frame must never become a multi-GB channel table
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: String::new(),
                streams: 1,
                epoch: 0,
            },
        )
        .unwrap();
        frame::write_frame(&mut wire, &Frame::Streams { count: u32::MAX }).unwrap();
        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.source().count(), 0);
        let stats = att.finish().unwrap();
        assert!(
            stats.error.as_deref().unwrap_or("").contains("MAX_STREAMS"),
            "{stats:?}"
        );
        // and a hostile Hello fails synchronously
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: String::new(),
                streams: u32::MAX,
                epoch: 0,
            },
        )
        .unwrap();
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("MAX_STREAMS"), "{err}");
    }

    #[test]
    fn attach_requires_hello_first() {
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(&mut wire, &Frame::Streams { count: 1 }).unwrap();
        let err = Attachment::open(Cursor::new(wire), 8).unwrap_err();
        assert!(err.to_string().contains("Hello"), "{err}");
    }

    #[test]
    fn reconstructed_messages_decode_like_local_ones() {
        // unknown class ids are skipped and counted, not fatal
        let mut wire = Vec::new();
        frame::write_preamble(&mut wire).unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "h".into(),
                metadata: crate::tracer::btf::generate_metadata(&[]),
                streams: 1,
                epoch: 0,
            },
        )
        .unwrap();
        frame::write_frame(
            &mut wire,
            &Frame::Event {
                stream: 0,
                event: frame::WireEvent {
                    ts: 1,
                    rank: 0,
                    tid: 0,
                    class_id: u32::MAX, // not in the registry
                    fields: vec![],
                },
            },
        )
        .unwrap();
        frame::write_frame(&mut wire, &Frame::Eos { received: 1, dropped: 0 }).unwrap();
        let att = Attachment::open(Cursor::new(wire), 8).unwrap();
        assert_eq!(att.source().count(), 0);
        let stats = att.finish().unwrap();
        assert_eq!(stats.unknown_classes, 1);
        // sanity: the registry table used by real publishers is non-trivial
        assert!(!registry_classes().is_empty());
    }
}
