//! The publishing end of a remote-live connection (`iprof serve`).
//!
//! [`publish`] is the `lttng-relayd` analogue collapsed into the traced
//! process: it drains a [`LiveHub`]'s per-stream channels through
//! [`LiveHub::next_forward_batch`] and relays everything — events,
//! watermark beacons, drop counts, closes — as THRL frames over any
//! reliable byte stream, finishing with a clean [`Frame::Eos`]. It is
//! the one-shot, non-resumable path: Hello advertises epoch 0 and a
//! dropped connection ends the relay for good.
//!
//! # The hot path (v3)
//!
//! On a v3 wire the pump coalesces each forward round's events into
//! [`Frame::EventBatch`] frames — one per consecutive same-stream run,
//! capped at [`frame::MAX_BATCH_EVENTS`] — with delta timestamps and the
//! per-connection `(rank, tid, class_id)` dictionary
//! ([`frame::BatchDictEncoder`]), then flushes the whole round with one
//! vectored write (manual `IoSlice` batching over the `Write` sink)
//! instead of one `write` per frame. `iprof serve --wire 2` keeps the
//! exact per-event v2 byte stream for old subscribers; see
//! `docs/PROTOCOL.md` § Versioning for the fallback matrix.
//!
//! [`Publisher`] is the resumable flavor (`iprof serve --resume-buffer`):
//! it owns a session **epoch** and a byte-budgeted [replay ring] of the
//! event frames it has relayed, and serves a *sequence* of connections
//! over the same session. Each connection handshakes
//! `Hello(epoch) → Resume(epoch, cursors)`, replays every ringed event
//! past the subscriber's per-stream cursors (answering
//! [`Frame::ResumeGap`] where the ring already evicted them), resyncs
//! watermark/drop/close state, and then pumps live batches until the
//! next disconnect or the final [`Frame::Eos`]:
//!
//! ```text
//!            ┌───────────── one session (epoch E) ──────────────┐
//! subscriber │ conn 1            conn 2                conn 3   │
//!   ────────►│ Hello(E)          Hello(E)              Hello(E) │
//!   Resume ─►│ (E, [])           (E, cursors)          ...      │
//!   ◄──────  │ events...  ✂      ResumeGap? + replay + events...│──► Eos
//!            └──────────────────────────────────────────────────┘
//!                    ✂ = transport died; ring keeps the tail
//! ```
//!
//! The ring always stores **per-event v2 `Event` frames**, whatever the
//! live wire speaks: replayed frames are valid on both wire versions (v3
//! is a byte-superset of v2), and ring sequence numbers keep counting
//! *events*, so resume cursors, gap ledgers and drop accounting are
//! untouched by batching.
//!
//! The publisher inherits the hub's backpressure contract end to end: it
//! never pushes back on the tracing consumer. If the transport stalls
//! (slow subscriber, slow network), the hub's bounded channels fill and
//! the consumer's try-push **drops and counts**; the loss is then
//! reported to the subscriber through [`Frame::Drops`] / [`Frame::Eos`],
//! so both ends always agree on completeness. The traced application
//! never waits on a socket — and never waits on a *vanished* subscriber
//! either: between connections the hub keeps draining into the ring
//! exactly as fast as before.
//!
//! [replay ring]: Publisher#replay-ring-semantics

use super::frame::{self, BatchEvent, BatchKey, Frame, FrameError, WireEvent};
use super::relay::{origin_snapshot, HubPump, OriginWire};
use crate::live::{ForwardCursor, LiveHub};
use crate::telemetry::{Counter, Registry};
use crate::tracer::btf::generate_metadata;
use crate::tracer::encoder::FieldValue;
use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// What one [`publish`] call (or one whole [`Publisher`] session)
/// relayed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PublishStats {
    /// Frames written (preamble excluded).
    pub frames: u64,
    /// Events relayed live (replays excluded). Counts *events*, not
    /// frames: a v3 batch of n events adds n here and 1 to `frames`.
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Bytes written, preambles included.
    pub bytes: u64,
    /// Subscriber connections served (always 1 for [`publish`]).
    pub connections: u64,
    /// Event frames re-sent from the replay ring on resume.
    pub replayed: u64,
    /// Events a resuming subscriber asked for that the ring had already
    /// evicted (the sum of all [`Frame::ResumeGap`] `missed` counts) —
    /// each one is an event permanently absent from the remote view.
    pub gaps: u64,
    /// `EventBatch` frames written (0 on a v2 wire).
    pub batches: u64,
    /// Batch-dictionary definitions written: first sightings of a
    /// `(rank, tid, class_id)` triple on this connection (0 on v2).
    pub dict_defs: u64,
    /// Batch-dictionary references written: repeat sightings resolved to
    /// a dictionary index. `refs / (defs + refs)` is the dictionary hit
    /// rate the telemetry endpoint exposes.
    pub dict_refs: u64,
}

impl PublishStats {
    /// Mirror these cumulative wire statistics into the registry.
    /// Absolute values via [`crate::telemetry::Counter::store_max`]: the
    /// struct is single-writer monotone, so after every sync the
    /// registry series *equals* the struct — the scrape endpoint and the
    /// end-of-run `ServeReport` can never disagree, and a re-sync can
    /// never double-count a round.
    fn sync_telemetry(&self, reg: &Registry) {
        reg.publish_frames.store_max(self.frames);
        reg.publish_events.store_max(self.events);
        reg.publish_bytes.store_max(self.bytes);
        reg.publish_batches.store_max(self.batches);
        reg.publish_dict_defs.store_max(self.dict_defs);
        reg.publish_dict_refs.store_max(self.dict_refs);
        reg.publish_replayed.store_max(self.replayed);
        reg.publish_gap_events.store_max(self.gaps);
        reg.publish_connections.store_max(self.connections);
    }
}

/// Encode one event as its complete per-event v2 `Event` frame — the
/// ONE place event bytes of that shape are produced, so the one-shot,
/// offline-drain and live-resumable paths can never encode differently
/// (ring replay byte-identity depends on that).
fn encode_event_parts(
    stream: usize,
    ts: u64,
    rank: u32,
    tid: u32,
    class_id: u32,
    fields: Vec<FieldValue>,
) -> Vec<u8> {
    let f = Frame::Event {
        stream: stream as u32,
        event: WireEvent { ts, rank, tid, class_id, fields },
    };
    let mut buf = Vec::with_capacity(64);
    frame::encode(&f, &mut buf);
    buf
}

/// [`encode_event_parts`] straight from a hub message.
fn encode_event(stream: usize, msg: crate::analysis::EventMsg) -> Vec<u8> {
    encode_event_parts(stream, msg.ts, msg.rank, msg.tid, msg.class.id, msg.fields)
}

/// Encode one frame into its own buffer.
fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    frame::encode(f, &mut buf);
    buf
}

/// Write every buffer with as few calls as the sink allows: manual
/// `IoSlice` batching over `Write::write_vectored`, chunked to stay
/// under typical `IOV_MAX` limits, advancing through partial writes.
/// For sinks without real vectored I/O the default `write_vectored`
/// degrades to one plain write of the first slice per call — still
/// correct, just unbatched. Returns the total bytes written.
fn write_all_vectored(w: &mut impl Write, bufs: &[&[u8]]) -> io::Result<u64> {
    const MAX_SLICES: usize = 512;
    let mut total = 0u64;
    let mut i = 0usize; // first unfinished buffer
    let mut off = 0usize; // bytes of bufs[i] already written
    while i < bufs.len() {
        if off >= bufs[i].len() {
            i += 1;
            off = 0;
            continue;
        }
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(MAX_SLICES.min(bufs.len() - i));
        slices.push(IoSlice::new(&bufs[i][off..]));
        for b in bufs[i + 1..].iter().take(MAX_SLICES - 1) {
            slices.push(IoSlice::new(b));
        }
        let mut n = w.write_vectored(&slices)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::WriteZero, "failed to write frames"));
        }
        total += n as u64;
        while n > 0 {
            let left = bufs[i].len() - off;
            if n >= left {
                n -= left;
                i += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(total)
}

/// The per-connection event encoder: either the v2 per-event wire or
/// the v3 batched wire with its running dictionary.
enum EventEncoder {
    /// Per-event `Event` frames, exactly the v2 byte stream.
    PerEvent,
    /// `EventBatch` frames with the connection dictionary.
    Batched(frame::BatchDictEncoder),
}

impl EventEncoder {
    fn new(wire: u32) -> EventEncoder {
        if wire >= 3 {
            EventEncoder::Batched(frame::BatchDictEncoder::new())
        } else {
            EventEncoder::PerEvent
        }
    }

    /// Encode one forward round's events into wire frames (appended to
    /// `wire_frames`) and optionally ring entries (appended to
    /// `ring_frames` as `(stream, v2 event frame)` — the replay ring
    /// stores per-event frames whatever the wire speaks). Batched mode
    /// cuts one `EventBatch` per consecutive same-stream run, capped at
    /// [`frame::MAX_BATCH_EVENTS`].
    fn encode_events(
        &mut self,
        stats: &mut PublishStats,
        events: Vec<(usize, crate::analysis::EventMsg)>,
        wire_frames: &mut Vec<Vec<u8>>,
        mut ring_frames: Option<&mut Vec<(usize, Vec<u8>)>>,
    ) {
        match self {
            EventEncoder::PerEvent => {
                for (idx, msg) in events {
                    let buf = encode_event(idx, msg);
                    stats.frames = stats.frames.saturating_add(1);
                    stats.events = stats.events.saturating_add(1);
                    match ring_frames.as_deref_mut() {
                        // the identical bytes serve wire and ring; the
                        // round writer borrows them from the ring list
                        Some(ring) => ring.push((idx, buf)),
                        None => wire_frames.push(buf),
                    }
                }
            }
            EventEncoder::Batched(dict) => {
                let mut run_stream = usize::MAX;
                let mut run: Vec<BatchEvent> = Vec::new();
                let mut flush =
                    |stream: usize, run: &mut Vec<BatchEvent>, stats: &mut PublishStats| {
                        if run.is_empty() {
                            return;
                        }
                        let f = Frame::EventBatch {
                            stream: stream as u32,
                            events: std::mem::take(run),
                        };
                        wire_frames.push(encode_frame(&f));
                        stats.frames = stats.frames.saturating_add(1);
                        stats.batches = stats.batches.saturating_add(1);
                    };
                for (idx, mut msg) in events {
                    if idx != run_stream || run.len() >= frame::MAX_BATCH_EVENTS as usize {
                        flush(run_stream, &mut run, stats);
                        run_stream = idx;
                    }
                    if let Some(ring) = ring_frames.as_deref_mut() {
                        ring.push((
                            idx,
                            encode_event_parts(
                                idx,
                                msg.ts,
                                msg.rank,
                                msg.tid,
                                msg.class.id,
                                msg.fields.clone(),
                            ),
                        ));
                    }
                    let key = dict.key_for(msg.rank, msg.tid, msg.class.id);
                    match key {
                        BatchKey::Ref(_) => stats.dict_refs = stats.dict_refs.saturating_add(1),
                        BatchKey::Def { .. } => {
                            stats.dict_defs = stats.dict_defs.saturating_add(1)
                        }
                    }
                    run.push(BatchEvent {
                        ts: msg.ts,
                        key,
                        fields: std::mem::take(&mut msg.fields),
                    });
                    stats.events = stats.events.saturating_add(1);
                }
                flush(run_stream, &mut run, stats);
            }
        }
    }
}

/// One forward round, encoded and ready to hit the wire: control frames
/// in protocol order around the event frames. `write` flushes the whole
/// round with one vectored write.
#[derive(Default)]
struct EncodedRound {
    /// Frames that must precede the events (`Streams` growth).
    pre: Vec<Vec<u8>>,
    /// Event frames (v2 per-event or v3 batches). For a ringed v2 round
    /// this stays empty — the wire borrows `ring` instead.
    events: Vec<Vec<u8>>,
    /// `(stream, v2 event frame)` entries bound for the replay ring.
    ring: Vec<(usize, Vec<u8>)>,
    /// Does the wire borrow `ring` as its event bytes? (v2 + ring)
    wire_uses_ring: bool,
    /// Frames that follow the events (beacons, drops, closes).
    post: Vec<Vec<u8>>,
}

impl EncodedRound {
    /// Encode one forward batch. `ringed` selects whether per-event v2
    /// frames are produced for the replay ring.
    fn encode(
        stats: &mut PublishStats,
        enc: &mut EventEncoder,
        batch: crate::live::ForwardBatch,
        ringed: bool,
    ) -> EncodedRound {
        let mut round = EncodedRound {
            wire_uses_ring: ringed && matches!(enc, EventEncoder::PerEvent),
            ..Default::default()
        };
        if let Some(count) = batch.grown_to {
            round.pre.push(encode_frame(&Frame::Streams { count: count as u32 }));
            stats.frames = stats.frames.saturating_add(1);
        }
        enc.encode_events(
            stats,
            batch.events,
            &mut round.events,
            ringed.then_some(&mut round.ring),
        );
        for (idx, watermark) in batch.beacons {
            round.post.push(encode_frame(&Frame::Beacon { stream: idx as u32, watermark }));
            stats.frames = stats.frames.saturating_add(1);
            stats.beacons = stats.beacons.saturating_add(1);
        }
        for (idx, dropped) in batch.drops {
            round.post.push(encode_frame(&Frame::Drops { stream: idx as u32, dropped }));
            stats.frames = stats.frames.saturating_add(1);
        }
        for idx in batch.closed {
            round.post.push(encode_frame(&Frame::Close { stream: idx as u32 }));
            stats.frames = stats.frames.saturating_add(1);
        }
        round
    }

    /// One vectored write for the whole round.
    fn write(&self, w: &mut impl Write) -> io::Result<u64> {
        let mut bufs: Vec<&[u8]> =
            Vec::with_capacity(self.pre.len() + self.events.len() + self.ring.len() + self.post.len());
        bufs.extend(self.pre.iter().map(Vec::as_slice));
        if self.wire_uses_ring {
            bufs.extend(self.ring.iter().map(|(_, b)| b.as_slice()));
        } else {
            bufs.extend(self.events.iter().map(Vec::as_slice));
        }
        bufs.extend(self.post.iter().map(Vec::as_slice));
        write_all_vectored(w, &bufs)
    }
}

/// [`publish`] with an explicit wire version: 3 (the default) batches
/// events into [`Frame::EventBatch`] frames; 2 emits the exact legacy
/// per-event byte stream for v2-only subscribers (`iprof serve
/// --wire 2`). Panics on a version this build does not speak.
pub fn publish_with<W: Write>(hub: &LiveHub, mut conn: W, wire: u32) -> io::Result<PublishStats> {
    assert!(
        frame::SUPPORTED_VERSIONS.contains(&wire),
        "publisher wire version {wire} not in {:?}",
        frame::SUPPORTED_VERSIONS
    );
    let mut stats = PublishStats { connections: 1, ..Default::default() };
    let mut head = Vec::with_capacity(256);
    frame::write_preamble_version(&mut head, wire)?;
    frame::encode(
        &Frame::Hello {
            hostname: hub.hostname().to_string(),
            // The same registry-derived metadata a post-mortem `collect`
            // writes: the subscriber decodes class ids through the
            // identical descriptor path.
            metadata: generate_metadata(&[]),
            streams: hub.stats().channels as u32,
            // epoch 0 = not resumable: the subscriber must not send
            // Resume, and a dropped connection is a permanent end of feed
            epoch: 0,
        },
        &mut head,
    );
    conn.write_all(&head)?;
    conn.flush()?;
    stats.bytes = stats.bytes.saturating_add(head.len() as u64);
    stats.frames = stats.frames.saturating_add(1);
    let reg = hub.telemetry();
    reg.publish_rounds.inc(); // the handshake round
    stats.sync_telemetry(reg);

    let mut enc = EventEncoder::new(wire);
    let mut cursor = ForwardCursor::default();
    while let Some(batch) = hub.next_forward_batch(&mut cursor) {
        let round = EncodedRound::encode(&mut stats, &mut enc, batch, false);
        stats.bytes = stats.bytes.saturating_add(round.write(&mut conn)?);
        // One flush per round: frames reach the subscriber with
        // drain-round granularity (milliseconds), not buffer-fill
        // granularity.
        conn.flush()?;
        reg.publish_rounds.inc();
        stats.sync_telemetry(reg);
    }

    let totals = hub.stats();
    let eos = encode_frame(&Frame::Eos { received: totals.received, dropped: totals.dropped });
    conn.write_all(&eos)?;
    conn.flush()?;
    stats.bytes = stats.bytes.saturating_add(eos.len() as u64);
    stats.frames = stats.frames.saturating_add(1);
    stats.sync_telemetry(reg);
    Ok(stats)
}

/// Publish `hub` over `conn` until the hub seals and drains: preamble,
/// then [`Frame::Hello`] carrying the hostname and the full BTF metadata
/// text (the subscriber's class table), then forward batches as they
/// appear, then [`Frame::Eos`] with the hub's final received/dropped
/// totals. Speaks the default wire version ([`frame::VERSION`], batched);
/// see [`publish_with`] for the v2 fallback.
///
/// Blocks until end of stream; run it on its own thread next to the
/// workload (see [`crate::coordinator::run_serve`]). Returns an error as
/// soon as the transport fails — the traced session is unaffected, the
/// hub just stops being drained and its channels degrade to
/// drop-and-count.
pub fn publish<W: Write>(hub: &LiveHub, conn: W) -> io::Result<PublishStats> {
    publish_with(hub, conn, frame::VERSION)
}

// ---------------------------------------------------------------------------
// Replay ring: the bounded memory a resumable session keeps per stream
// ---------------------------------------------------------------------------

/// Per-stream retained window. `start_seq..end_seq` are the sequence
/// numbers of the encoded event frames currently held: `end_seq` counts
/// every event ever relayed on the stream, `start_seq` trails it by the
/// entries not yet evicted (`end_seq - start_seq == entries.len()`
/// always).
#[derive(Default)]
struct StreamRing {
    start_seq: u64,
    end_seq: u64,
    entries: VecDeque<Vec<u8>>,
}

/// What one [`ReplayRing::replay`] wrote.
#[derive(Debug, Default, PartialEq, Eq)]
struct ReplaySummary {
    /// Event frames re-sent.
    replayed: u64,
    /// Events irrecoverably lost (sum of all `ResumeGap.missed`).
    gaps: u64,
    /// `ResumeGap` frames written (streams with a gap).
    gap_frames: u64,
    /// Total bytes written.
    bytes: u64,
}

/// Byte-budgeted replay storage for a resumable session: every event
/// frame relayed to the subscriber is retained until the total retained
/// size exceeds the budget, then the globally oldest entries are evicted
/// first. Sequence numbers are per stream and *dense* — a subscriber's
/// cursor is simply its count of delivered events on that stream.
/// Entries are always per-event v2 `Event` frames (valid on both wire
/// versions), so one ring serves v2 and v3 connections alike and its
/// sequence numbers count events regardless of live-path batching.
struct ReplayRing {
    streams: Vec<StreamRing>,
    /// Streams in global push order: per-stream queues are FIFO, so the
    /// front of this queue always names the stream holding the globally
    /// oldest retained entry — O(1) eviction instead of an O(streams)
    /// scan per evicted event.
    evict_order: VecDeque<u32>,
    budget: usize,
    total: usize,
    /// Event frames evicted over the ring's lifetime (each one is a
    /// potential future resume gap). Saturating; mirrored to telemetry.
    evicted: u64,
}

impl ReplayRing {
    fn new(budget: usize) -> ReplayRing {
        ReplayRing {
            streams: Vec::new(),
            evict_order: VecDeque::new(),
            budget: budget.max(1),
            total: 0,
            evicted: 0,
        }
    }

    fn ensure(&mut self, n: usize) {
        while self.streams.len() < n {
            self.streams.push(StreamRing::default());
        }
    }

    /// Retain one relayed event frame, evicting oldest-first (across all
    /// streams) once over budget. Eviction moves the stream's
    /// `start_seq` forward: a later resume below it is a gap.
    fn push(&mut self, stream: usize, bytes: Vec<u8>) {
        self.push_unevicted(stream, bytes);
        while self.over_budget() {
            if self.evict_one().is_none() {
                break;
            }
        }
    }

    /// Retain one event frame WITHOUT evicting — the broadcast pump
    /// pushes this way and runs its own entitlement-gated eviction
    /// ([`Broadcaster`]), where the decision to evict depends on every
    /// live subscriber's cursor, not just the budget.
    fn push_unevicted(&mut self, stream: usize, bytes: Vec<u8>) {
        self.ensure(stream + 1);
        self.total += bytes.len();
        let s = &mut self.streams[stream];
        s.entries.push_back(bytes);
        s.end_seq += 1;
        self.evict_order.push_back(stream as u32);
    }

    fn over_budget(&self) -> bool {
        self.total > self.budget
    }

    /// The globally oldest retained entry as `(stream, seq, len)` — the
    /// next eviction victim.
    fn oldest(&self) -> Option<(usize, u64, usize)> {
        let &idx = self.evict_order.front()?;
        let s = &self.streams[idx as usize];
        s.entries.front().map(|e| (idx as usize, s.start_seq, e.len()))
    }

    /// Evict the globally oldest entry, returning `(stream, seq, len)`.
    fn evict_one(&mut self) -> Option<(usize, u64, usize)> {
        let idx = self.evict_order.pop_front()? as usize;
        let s = &mut self.streams[idx];
        let seq = s.start_seq;
        let evicted = s.entries.pop_front().expect("evict queue tracks live entries 1:1");
        self.total -= evicted.len();
        s.start_seq += 1;
        self.evicted = self.evicted.saturating_add(1);
        Some((idx, seq, evicted.len()))
    }

    /// Bytes retained beyond the given per-stream cursors — the lag a
    /// subscriber sitting at `cursors` would have to drain.
    fn bytes_behind(&self, cursors: &[u64]) -> usize {
        let mut total = 0usize;
        for (i, s) in self.streams.iter().enumerate() {
            let c = cursors.get(i).copied().unwrap_or(0);
            let skip = c.saturating_sub(s.start_seq) as usize;
            total += s.entries.iter().skip(skip).map(Vec::len).sum::<usize>();
        }
        total
    }

    /// Replay everything past the subscriber's per-stream `cursors` into
    /// `w`, stream by stream: a [`Frame::ResumeGap`] for any stream
    /// whose cursor fell below the retained window, immediately followed
    /// by that stream's retained event frames in original order (the
    /// `stream-replay` production in `docs/PROTOCOL.md`).
    fn replay<W: Write>(&self, cursors: &[u64], w: &mut W) -> io::Result<ReplaySummary> {
        // cursors beyond the streams we ever relayed on can only be 0
        for (i, &c) in cursors.iter().enumerate() {
            let sent = self.streams.get(i).map(|s| s.end_seq).unwrap_or(0);
            if c > sent {
                return Err(FrameError::Malformed("resume cursor beyond relayed events").into());
            }
        }
        let mut out = ReplaySummary::default();
        for (i, s) in self.streams.iter().enumerate() {
            let c = cursors.get(i).copied().unwrap_or(0);
            if c < s.start_seq {
                let missed = s.start_seq - c;
                out.bytes +=
                    frame::write_frame(w, &Frame::ResumeGap { stream: i as u32, missed })? as u64;
                out.gaps += missed;
                out.gap_frames += 1;
            }
            let skip = c.saturating_sub(s.start_seq) as usize;
            for e in s.entries.iter().skip(skip) {
                w.write_all(e)?;
                out.bytes += e.len() as u64;
                out.replayed += 1;
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Resumable publisher
// ---------------------------------------------------------------------------

/// How one subscriber connection ended, from the publisher's side.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeOutcome {
    /// The session fully drained and [`Frame::Eos`] reached the wire:
    /// the publisher is done for good.
    Complete,
    /// The connection died (transport error, bad handshake, hostile
    /// subscriber) before Eos. The session state — replay ring, epoch,
    /// totals — is intact; accept another connection and call
    /// [`Publisher::serve_connection`] again to let the subscriber
    /// resume.
    Lost(String),
}

/// A resumable publishing session over a sequence of connections (see
/// the module docs for the wire lifecycle).
///
/// # Replay ring semantics
///
/// Every event relayed to the subscriber is also pushed into a
/// byte-budgeted ring (`--resume-buffer <bytes>`) as its per-event v2
/// `Event` frame, keyed by dense per-stream sequence numbers — the
/// subscriber's resume cursor for a stream is simply how many events it
/// has delivered there, batched or not. On resume the publisher replays
/// `ring[cursor..]` per stream; cursors that fell below the retained
/// window get a [`Frame::ResumeGap`] with the exact evicted count, which
/// the subscriber books into its drops ledger (the merged view is then
/// incomplete by exactly that many events and `--live-strict` fails).
/// Watermarks, cumulative drop counts and closes are *not* ringed: they
/// are monotone or idempotent, so each new connection just re-reports
/// the current values ([`ForwardCursor::resync`]).
pub struct Publisher {
    /// The session's hub drain — the one shared pump implementation
    /// ([`HubPump`]), owning the session's single forward cursor.
    pump: HubPump,
    epoch: u64,
    ring: ReplayRing,
    stats: PublishStats,
    wire: u32,
}

impl Publisher {
    /// Create a resumable session over `hub` with a `resume_buffer`-byte
    /// replay ring. `epoch` must be nonzero (use
    /// [`Publisher::fresh_epoch`] outside of tests): epoch 0 on the wire
    /// means "not resumable". Speaks the default wire version; see
    /// [`Publisher::with_wire`].
    pub fn new(hub: Arc<LiveHub>, epoch: u64, resume_buffer: usize) -> Publisher {
        assert!(epoch != 0, "epoch 0 means non-resumable; pick a nonzero session epoch");
        Publisher {
            pump: HubPump::new(hub),
            epoch,
            ring: ReplayRing::new(resume_buffer),
            stats: PublishStats::default(),
            wire: frame::VERSION,
        }
    }

    /// Select the wire version for every connection this session serves:
    /// 3 (default) batches events, 2 emits the legacy per-event stream
    /// for v2-only subscribers. Panics on a version this build does not
    /// speak. The replay ring is version-independent, so the choice only
    /// affects the live pump's framing.
    pub fn with_wire(mut self, wire: u32) -> Publisher {
        assert!(
            frame::SUPPORTED_VERSIONS.contains(&wire),
            "publisher wire version {wire} not in {:?}",
            frame::SUPPORTED_VERSIONS
        );
        self.wire = wire;
        self
    }

    /// A fresh, effectively unique nonzero session epoch (wall-clock
    /// nanoseconds mixed with the process id). Two session *instances*
    /// never share an epoch in practice, which is all resumption needs:
    /// a subscriber reconnecting to a restarted publisher must see a
    /// different epoch and know its cursors are meaningless.
    pub fn fresh_epoch() -> u64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        (nanos ^ ((std::process::id() as u64) << 48)) | 1
    }

    /// The session epoch advertised in every Hello.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Cumulative wire statistics across every connection served so far.
    pub fn stats(&self) -> PublishStats {
        self.stats.clone()
    }

    /// Drain whatever the hub holds *right now* into the replay ring,
    /// without a connection. A resumable serve loop calls this while no
    /// subscriber is attached, so a mid-run outage consumes ring budget
    /// instead of filling the hub's bounded channels (which would make
    /// the consumer drop-and-count — loss that resumption exists to
    /// avoid). Watermark/drop/close deltas need no recording: every new
    /// connection re-reports current state via
    /// [`ForwardCursor::resync`].
    pub fn drain_to_ring(&mut self) {
        let ring = &mut self.ring;
        self.pump.drain_now(|batch| {
            for (idx, msg) in batch.events {
                ring.push(idx, encode_event(idx, msg));
            }
        });
        self.sync_ring_telemetry();
    }

    /// Mirror the ring's occupancy and lifetime evictions into the
    /// registry (occupancy is a gauge — it shrinks on eviction).
    fn sync_ring_telemetry(&self) {
        let reg = self.pump.hub().telemetry();
        reg.ring_bytes.set(self.ring.total as u64);
        reg.ring_evicted_events.store_max(self.ring.evicted);
    }

    /// Serve one subscriber connection: handshake (preamble, Hello with
    /// this session's epoch, then the subscriber's [`Frame::Resume`]),
    /// replay past its cursors, resync state, pump live batches, and
    /// finish with [`Frame::Eos`] once the hub drains.
    ///
    /// Returns [`ServeOutcome::Lost`] on any error — the session
    /// survives, call again with the next accepted connection. A
    /// disconnect can race the final Eos; a subscriber that missed it
    /// reconnects and this method re-runs the (now trivial) pump to a
    /// clean Eos again.
    pub fn serve_connection<S: Read + Write>(&mut self, mut conn: S) -> ServeOutcome {
        self.stats.connections = self.stats.connections.saturating_add(1);
        match self.serve_inner(&mut conn) {
            Ok(()) => ServeOutcome::Complete,
            Err(e) => ServeOutcome::Lost(e.to_string()),
        }
    }

    fn serve_inner<S: Read + Write>(&mut self, conn: &mut S) -> io::Result<()> {
        // Handshake. The Hello goes out unbuffered so the subscriber can
        // answer; the streaming phase below writes whole rounds.
        let announced = self.pump.hub().stats().channels;
        let mut head = Vec::with_capacity(256);
        frame::write_preamble_version(&mut head, self.wire)?;
        frame::encode(
            &Frame::Hello {
                hostname: self.pump.hub().hostname().to_string(),
                metadata: generate_metadata(&[]),
                streams: announced as u32,
                epoch: self.epoch,
            },
            &mut head,
        );
        conn.write_all(&head)?;
        conn.flush()?;
        self.stats.bytes = self.stats.bytes.saturating_add(head.len() as u64);
        self.stats.frames = self.stats.frames.saturating_add(1);
        self.pump.hub().telemetry().publish_rounds.inc(); // the handshake round
        self.stats.sync_telemetry(self.pump.hub().telemetry());

        // The one subscriber→publisher frame: where to resume from.
        let Frame::Resume { epoch, cursors } = frame::read_frame(conn)? else {
            return Err(FrameError::Malformed("expected Resume after Hello").into());
        };
        if epoch != self.epoch {
            return Err(FrameError::Malformed("Resume epoch does not match this session").into());
        }

        // Replay is always per-event v2 frames straight from the ring —
        // valid on either wire version, cursors count events.
        let replay = self.ring.replay(&cursors, conn)?;
        self.stats.replayed = self.stats.replayed.saturating_add(replay.replayed);
        self.stats.gaps = self.stats.gaps.saturating_add(replay.gaps);
        self.stats.bytes = self.stats.bytes.saturating_add(replay.bytes);
        self.stats.frames = self
            .stats
            .frames
            .saturating_add(replay.replayed)
            .saturating_add(replay.gap_frames);
        self.stats.sync_telemetry(self.pump.hub().telemetry());
        conn.flush()?;

        // Re-report current watermarks/drops/closes from scratch: all
        // monotone or idempotent on the subscriber, so a fresh delta
        // baseline resynchronizes everything that is not an event. The
        // batch dictionary is per-connection state on both ends, so it
        // starts empty here too.
        self.pump.resync(announced);
        let mut enc = EventEncoder::new(self.wire);
        while let Some(batch) = self.pump.next() {
            let round = EncodedRound::encode(&mut self.stats, &mut enc, batch, true);
            // Write the round, then ring EVERY popped event — even when
            // the wire just died mid-round: popped events exist nowhere
            // else, and the resuming subscriber's cursor decides which
            // ones it actually got.
            let wrote = round.write(conn);
            for (idx, buf) in round.ring {
                self.ring.push(idx, buf);
            }
            self.sync_ring_telemetry();
            match wrote {
                Ok(n) => self.stats.bytes = self.stats.bytes.saturating_add(n),
                Err(e) => return Err(e),
            }
            conn.flush()?;
            self.pump.hub().telemetry().publish_rounds.inc();
            self.stats.sync_telemetry(self.pump.hub().telemetry());
        }

        let totals = self.pump.hub().stats();
        let eos = encode_frame(&Frame::Eos { received: totals.received, dropped: totals.dropped });
        conn.write_all(&eos)?;
        conn.flush()?;
        self.stats.bytes = self.stats.bytes.saturating_add(eos.len() as u64);
        self.stats.frames = self.stats.frames.saturating_add(1);
        self.stats.sync_telemetry(self.pump.hub().telemetry());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Broadcast publisher: one session, N concurrent subscribers
// ---------------------------------------------------------------------------

/// What one broadcast subscriber connection received, from the
/// publisher's side — one row of the `ServeReport` subscriber table and
/// the source of the per-subscriber telemetry family.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SubscriberStats {
    /// Subscriber id, in connection-accept order (the telemetry label).
    pub id: usize,
    /// Wire version this connection negotiated (publisher-selected).
    pub wire: u32,
    /// Events encoded for this connection's wire. On a cleanly finished
    /// connection this is exactly the events delivered; the final round
    /// of a dying connection may have been cut short by the transport.
    pub forwarded: u64,
    /// Events this subscriber missed because the ring evicted them
    /// before delivery — each one was booked onto its wire as part of an
    /// exact [`Frame::ResumeGap`] total (`lagged == Σ missed`).
    pub lagged: u64,
    /// Demotion episodes: the subscriber exceeded the lag budget
    /// (`--max-lag`) under eviction pressure and lost its eviction
    /// entitlement for the rest of the connection (sticky), degrading to
    /// gap delivery. 0 or 1 per connection.
    pub demoted: u64,
    /// 1 if the connection ended before [`Frame::Eos`] (transport death,
    /// bad handshake); 0 on a clean finish.
    pub disconnects: u64,
    /// Frames written to this connection (preamble excluded).
    pub frames: u64,
    /// Bytes written to this connection, preamble included.
    pub bytes: u64,
    /// Why the connection ended early, if it did.
    pub error: Option<String>,
}

/// Pre-registered per-subscriber telemetry series (label = subscriber
/// id), mirrored from the single-writer [`SubscriberStats`] with
/// `store_max` so a scrape always equals the slot's own accounting.
struct SubscriberTelemetry {
    forwarded: Arc<Counter>,
    lagged: Arc<Counter>,
    demoted: Arc<Counter>,
    disconnects: Arc<Counter>,
}

impl SubscriberTelemetry {
    fn register(reg: &Registry, id: usize) -> SubscriberTelemetry {
        let label = id.to_string();
        SubscriberTelemetry {
            forwarded: reg.subscriber_forwarded_events.with_label(&label),
            lagged: reg.subscriber_lagged_events.with_label(&label),
            demoted: reg.subscriber_demotions.with_label(&label),
            disconnects: reg.subscriber_disconnects.with_label(&label),
        }
    }

    fn sync(&self, stats: &SubscriberStats) {
        self.forwarded.store_max(stats.forwarded);
        self.lagged.store_max(stats.lagged);
        self.demoted.store_max(stats.demoted);
        self.disconnects.store_max(stats.disconnects);
    }
}

/// Monotone/idempotent non-event state the pump mirrors out of the hub
/// so every subscriber can re-derive its own deltas: announced stream
/// count, per-stream watermarks (max-merged), cumulative drop counts
/// and closes. Events are NOT here — they live in the shared ring.
#[derive(Default)]
struct StreamBoard {
    announced: usize,
    watermark: Vec<u64>,
    dropped: Vec<u64>,
    closed: Vec<bool>,
}

impl StreamBoard {
    fn ensure(&mut self, n: usize) {
        if n > self.announced {
            self.announced = n;
        }
        while self.watermark.len() < n {
            self.watermark.push(0);
            self.dropped.push(0);
            self.closed.push(false);
        }
    }
}

/// One subscriber's registration in the shared broadcast state.
struct SubscriberSlot {
    /// Events delivered per stream — this connection's independent
    /// forward cursor into the shared ring (dense per-stream sequence
    /// numbers, exactly the resume-cursor currency).
    cursors: Vec<u64>,
    /// While true, ring entries this cursor has not consumed are pinned
    /// against eviction. Cleared on demotion and on disconnect.
    entitled: bool,
    /// The connection ended; the slot remains as its stats record.
    gone: bool,
    /// Ring bytes retained beyond this slot's cursors (its lag, the
    /// `--max-lag` currency).
    behind: usize,
    stats: SubscriberStats,
}

/// Everything the pump and the N subscriber threads share, under one
/// lock: the ring of per-event v2 frames, the non-event stream board,
/// the hub's final totals once it drained, and the subscriber slots.
struct BroadcastShared {
    ring: ReplayRing,
    board: StreamBoard,
    /// `(received, dropped)` once the hub sealed and drained — the Eos
    /// payload every subscriber finishes with.
    finished: Option<(u64, u64)>,
    slots: Vec<SubscriberSlot>,
    /// Relay mode only ([`Broadcaster::with_origin_relay`]): the
    /// per-leaf accounting entries mirrored from the hub, max-merged by
    /// path. Monotone like the board, so every subscriber delta-diffs
    /// against its own [`BoardView`] copy. Empty outside relay mode.
    origins: Vec<OriginWire>,
}

/// One frame round bound for one subscriber's wire, built under the
/// shared lock, written outside it.
#[derive(Default)]
struct SubscriberRound {
    frames: Vec<Vec<u8>>,
    /// Eos was appended: the connection is complete after this write.
    done: bool,
}

/// A broadcast publishing session: ONE hub serving N concurrent
/// subscriber connections over one shared replay ring (`iprof serve
/// --subscribers <n>`).
///
/// Where [`Publisher`] serves a *sequence* of connections with one
/// forward cursor, `Broadcaster` decouples draining from delivery: a
/// single [`Broadcaster::pump`] thread is the hub's only (destructive)
/// consumer and mirrors everything into shared state — events into a
/// [`ReplayRing`] of per-event v2 `Event` frames, watermarks/drops/
/// closes onto a monotone [`StreamBoard`] — while every accepted
/// connection runs [`Broadcaster::serve_connection`] on its own thread
/// with its own per-stream cursors, wire version and batch dictionary,
/// reading the shared ring. On the wire each connection is an
/// independent, fully conforming resumable THRL connection (preamble,
/// `Hello(epoch)`, `Resume`, items, `Eos`): broadcast is a server-side
/// concern, invisible to subscribers.
///
/// # Eviction, entitlement and the lag budget
///
/// Ring eviction is driven by the slowest *entitled* cursor: an entry
/// no entitled subscriber still needs is evictable once the ring is
/// over budget, but an entry an entitled cursor has not consumed is
/// pinned — the ring grows past its budget rather than losing data a
/// live viewer is owed. The per-subscriber lag budget caps that growth:
/// under eviction pressure, a subscriber more than `max_lag` bytes
/// behind is **demoted** — it loses entitlement for the rest of its
/// connection (sticky) and degrades to gap delivery: the next round it
/// reads books an exact [`Frame::ResumeGap`] for the evicted span and
/// advances its cursor, instead of stalling the ring for everyone.
/// With no lag budget (`usize::MAX`, the default) live subscribers are
/// never demoted and a stalled viewer pins ring memory — set
/// `--max-lag` to bound it. Disconnected subscribers are always
/// unregistered from entitlement immediately, on every exit path, so a
/// crashed viewer can never pin the ring.
pub struct Broadcaster {
    /// The session's hub drain — the one shared pump implementation
    /// ([`HubPump`]), owning the session's single forward cursor:
    /// forward batches are destructive, so exactly one drain path owns
    /// them.
    pump: HubPump,
    epoch: u64,
    max_lag: usize,
    /// Re-publish the hub's per-origin accounting as [`Frame::Origin`]
    /// frames on every v3 subscriber wire (`iprof relay`). See
    /// [`Broadcaster::with_origin_relay`].
    origin_relay: bool,
    shared: Mutex<BroadcastShared>,
    /// Signaled after every applied batch, at finish, and when a slot
    /// unregisters: subscriber threads block here between rounds.
    progress: Condvar,
}

impl Broadcaster {
    /// Create a broadcast session over `hub` with a `resume_buffer`-byte
    /// shared ring. `epoch` must be nonzero ([`Publisher::fresh_epoch`]
    /// outside of tests): every connection handshakes `Hello(epoch) →
    /// Resume`, so a mid-run joiner replays the retained window and a
    /// reconnecting subscriber resumes from its cursors — as a fresh
    /// slot.
    pub fn new(hub: Arc<LiveHub>, epoch: u64, resume_buffer: usize) -> Broadcaster {
        assert!(epoch != 0, "epoch 0 means non-resumable; pick a nonzero session epoch");
        Broadcaster {
            pump: HubPump::new(hub),
            epoch,
            max_lag: usize::MAX,
            origin_relay: false,
            shared: Mutex::new(BroadcastShared {
                ring: ReplayRing::new(resume_buffer),
                board: StreamBoard::default(),
                finished: None,
                slots: Vec::new(),
                origins: Vec::new(),
            }),
            progress: Condvar::new(),
        }
    }

    /// Set the per-subscriber lag budget in bytes (`--max-lag`): under
    /// eviction pressure, a subscriber further behind than this is
    /// demoted to gap delivery instead of pinning the ring.
    pub fn with_max_lag(mut self, max_lag: usize) -> Broadcaster {
        self.max_lag = max_lag.max(1);
        self
    }

    /// Publish this hub's per-origin accounting upstream: before every
    /// applied batch (and once more at seal) the hub's origins — and
    /// their sub-origins, for deeper trees — are mirrored as monotone
    /// [`OriginWire`] entries and delivered to every **v3** subscriber
    /// as [`Frame::Origin`] frames, paths extended with this node's own
    /// origin names (`0:nodeA` → `0:relay1/0:nodeA` one hop up). This
    /// is what makes `iprof relay` lossless for accounting: the root
    /// keeps one drops/eos/gap ledger and one telemetry series *per
    /// leaf*, not per relay, and stamps merged events with leaf
    /// hostnames. A v2 subscriber of the same session is unaffected
    /// (the frame type does not exist on its wire).
    pub fn with_origin_relay(mut self) -> Broadcaster {
        self.origin_relay = true;
        self
    }

    /// The session epoch advertised in every Hello.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Drain the hub until it seals, then record the final totals: the
    /// one destructive hub consumer. Run on its own thread; it never
    /// blocks on any subscriber's socket.
    pub fn pump(&self) {
        self.pump.run(|batch| {
            self.refresh_origins();
            self.apply(batch);
        });
        // Ledger-only updates (a late downstream ResumeGap, an Eos)
        // ride no forward batch: refresh once more so the per-leaf
        // accounting is exact before any subscriber sees Eos.
        self.refresh_origins();
        let totals = self.pump.hub().stats();
        let mut g = self.shared.lock().unwrap();
        g.finished = Some((totals.received, totals.dropped));
        drop(g);
        self.progress.notify_all();
    }

    /// Drain whatever the hub holds *right now* into the shared state
    /// without waiting for more — the broadcast analogue of
    /// [`Publisher::drain_to_ring`], and the hook deterministic tests
    /// use to interleave pushes with subscriber progress. Does not mark
    /// the session finished; [`Broadcaster::pump`] does that.
    pub fn drain_to_ring(&self) {
        self.pump.drain_now(|batch| {
            self.refresh_origins();
            self.apply(batch);
        });
    }

    /// Mirror the hub's per-origin accounting into the shared state as
    /// wire-ready [`OriginWire`] entries (relay mode only, no-op
    /// otherwise). Runs *before* each applied batch: an origin's entry
    /// (with its stream mapping and leaf hostname) is therefore
    /// board-visible no later than the first event it carries, so any
    /// round delivering an event also delivers or was preceded by the
    /// Origin entry naming its stream — the ordering leaf-hostname
    /// stamping at the receiver relies on. Max-merge keeps every ledger
    /// monotone under racing snapshots.
    fn refresh_origins(&self) {
        if !self.origin_relay {
            return;
        }
        let snapshot = origin_snapshot(self.pump.hub());
        if snapshot.is_empty() {
            return;
        }
        let mut g = self.shared.lock().unwrap();
        let mut changed = false;
        for e in snapshot {
            match g.origins.iter_mut().find(|o| o.path == e.path) {
                Some(o) => {
                    if *o != e {
                        o.merge(e);
                        changed = true;
                    }
                }
                None => {
                    g.origins.push(e);
                    changed = true;
                }
            }
        }
        drop(g);
        if changed {
            self.progress.notify_all();
        }
    }

    /// Mirror one forward batch into the shared ring + board, running
    /// entitlement-gated eviction per pushed event.
    fn apply(&self, batch: crate::live::ForwardBatch) {
        let mut g = self.shared.lock().unwrap();
        let shared = &mut *g;
        if let Some(count) = batch.grown_to {
            shared.board.ensure(count);
        }
        for (idx, msg) in batch.events {
            shared.board.ensure(idx + 1);
            let buf = encode_event(idx, msg);
            let len = buf.len();
            shared.ring.push_unevicted(idx, buf);
            for slot in shared.slots.iter_mut() {
                if !slot.gone {
                    slot.behind = slot.behind.saturating_add(len);
                }
            }
            Self::evict_entitled(shared, self.max_lag);
        }
        for (idx, watermark) in batch.beacons {
            shared.board.ensure(idx + 1);
            let w = &mut shared.board.watermark[idx];
            *w = (*w).max(watermark);
        }
        for (idx, dropped) in batch.drops {
            shared.board.ensure(idx + 1);
            let d = &mut shared.board.dropped[idx];
            *d = (*d).max(dropped);
        }
        for idx in batch.closed {
            shared.board.ensure(idx + 1);
            shared.board.closed[idx] = true;
        }
        self.sync_ring_telemetry(&shared.ring);
        drop(g);
        self.progress.notify_all();
    }

    /// Evict while over budget, honoring entitlement: the oldest entry
    /// is pinned by any *entitled* subscriber whose cursor has not
    /// consumed it — unless that subscriber is over the lag budget, in
    /// which case it is demoted (sticky) and stops pinning anything.
    /// Stops at the first genuinely pinned entry (eviction is FIFO, so
    /// nothing behind it can go either). The invariant the property
    /// tests pin: an entry is only ever evicted when every entitled
    /// cursor has already consumed it.
    fn evict_entitled(shared: &mut BroadcastShared, max_lag: usize) {
        while shared.ring.over_budget() {
            let Some((stream, seq, len)) = shared.ring.oldest() else { break };
            let mut pinned = false;
            for slot in shared.slots.iter_mut() {
                if !slot.entitled {
                    continue;
                }
                if slot.cursors.get(stream).copied().unwrap_or(0) > seq {
                    continue; // already delivered this entry
                }
                if slot.behind > max_lag {
                    slot.entitled = false;
                    slot.stats.demoted = slot.stats.demoted.saturating_add(1);
                } else {
                    pinned = true;
                }
            }
            if pinned {
                break;
            }
            shared.ring.evict_one();
            // the evicted bytes are no longer lag for whoever had not
            // read them — they will surface as an exact ResumeGap instead
            for slot in shared.slots.iter_mut() {
                if !slot.gone && slot.cursors.get(stream).copied().unwrap_or(0) <= seq {
                    slot.behind = slot.behind.saturating_sub(len);
                }
            }
        }
    }

    fn sync_ring_telemetry(&self, ring: &ReplayRing) {
        let reg = self.pump.hub().telemetry();
        reg.ring_bytes.set(ring.total as u64);
        reg.ring_evicted_events.store_max(ring.evicted);
    }

    /// Register a fresh slot: entitled, cursors at zero, lag equal to
    /// everything currently retained (a joiner is owed the whole
    /// window until its Resume says otherwise).
    fn register(&self, wire: u32) -> usize {
        let mut g = self.shared.lock().unwrap();
        let id = g.slots.len();
        let behind = g.ring.total;
        g.slots.push(SubscriberSlot {
            cursors: Vec::new(),
            entitled: true,
            gone: false,
            behind,
            stats: SubscriberStats { id, wire, ..Default::default() },
        });
        id
    }

    /// Has [`Broadcaster::pump`] drained the hub to its end?
    pub fn finished(&self) -> bool {
        self.shared.lock().unwrap().finished.is_some()
    }

    /// Per-subscriber rows, in connection-accept order.
    pub fn subscriber_stats(&self) -> Vec<SubscriberStats> {
        self.shared.lock().unwrap().slots.iter().map(|s| s.stats.clone()).collect()
    }

    /// Aggregate wire statistics across every subscriber served, in
    /// [`PublishStats`] shape: `events` sums forwarded events (each
    /// subscriber's delivery counts once), `gaps` sums lagged events,
    /// `connections` counts accepted subscribers.
    pub fn stats(&self) -> PublishStats {
        let g = self.shared.lock().unwrap();
        let mut out = PublishStats::default();
        for s in &g.slots {
            out.frames = out.frames.saturating_add(s.stats.frames);
            out.events = out.events.saturating_add(s.stats.forwarded);
            out.bytes = out.bytes.saturating_add(s.stats.bytes);
            out.gaps = out.gaps.saturating_add(s.stats.lagged);
            out.connections = out.connections.saturating_add(1);
        }
        out
    }

    /// Serve one subscriber connection on the caller's thread: an
    /// independent, fully conforming THRL connection over the shared
    /// state (see the type docs). `wire` picks this connection's
    /// version — different subscribers of one session may speak
    /// different wires. Returns like [`Publisher::serve_connection`];
    /// on any outcome the slot is unregistered from eviction
    /// entitlement before this returns (also on panic), so a dead
    /// subscriber never pins the ring.
    pub fn serve_connection<S: Read + Write>(&self, conn: S, wire: u32) -> ServeOutcome {
        assert!(
            frame::SUPPORTED_VERSIONS.contains(&wire),
            "publisher wire version {wire} not in {:?}",
            frame::SUPPORTED_VERSIONS
        );
        let id = self.register(wire);
        let mut guard = SlotGuard {
            bc: self,
            id,
            tele: SubscriberTelemetry::register(self.pump.hub().telemetry(), id),
            completed: false,
        };
        match self.serve_slot(conn, wire, id, &guard.tele) {
            Ok(()) => {
                guard.completed = true;
                ServeOutcome::Complete
            }
            Err(e) => {
                let msg = e.to_string();
                self.shared.lock().unwrap().slots[id].stats.error = Some(msg.clone());
                ServeOutcome::Lost(msg)
            }
        }
    }

    fn serve_slot<S: Read + Write>(
        &self,
        mut conn: S,
        wire: u32,
        id: usize,
        tele: &SubscriberTelemetry,
    ) -> io::Result<()> {
        // Handshake: identical grammar to Publisher::serve_connection.
        // The slot registered BEFORE this point, so from the first byte
        // of the Hello the window this subscriber is owed is pinned.
        let hello_streams = self.shared.lock().unwrap().board.announced;
        let mut head = Vec::with_capacity(256);
        frame::write_preamble_version(&mut head, wire)?;
        frame::encode(
            &Frame::Hello {
                hostname: self.pump.hub().hostname().to_string(),
                metadata: generate_metadata(&[]),
                streams: hello_streams as u32,
                epoch: self.epoch,
            },
            &mut head,
        );
        conn.write_all(&head)?;
        conn.flush()?;
        {
            let mut g = self.shared.lock().unwrap();
            let slot = &mut g.slots[id];
            slot.stats.frames = slot.stats.frames.saturating_add(1);
            slot.stats.bytes = slot.stats.bytes.saturating_add(head.len() as u64);
        }
        self.pump.hub().telemetry().publish_rounds.inc();

        // The one subscriber→publisher frame: where to resume from.
        let Frame::Resume { epoch, cursors } = frame::read_frame(&mut conn)? else {
            return Err(FrameError::Malformed("expected Resume after Hello").into());
        };
        if epoch != self.epoch {
            return Err(FrameError::Malformed("Resume epoch does not match this session").into());
        }
        {
            let mut g = self.shared.lock().unwrap();
            for (i, &c) in cursors.iter().enumerate() {
                let sent = g.ring.streams.get(i).map(|s| s.end_seq).unwrap_or(0);
                if c > sent {
                    return Err(
                        FrameError::Malformed("resume cursor beyond relayed events").into()
                    );
                }
            }
            let behind = g.ring.bytes_behind(&cursors);
            let slot = &mut g.slots[id];
            slot.cursors = cursors;
            slot.behind = behind;
        }

        // Unified delivery loop: replay-after-Resume and the live pump
        // are the same ring-driven rounds. The first round is the
        // resume replay, always per-event frames (the `stream-replay`
        // production); later rounds batch on a v3 wire with this
        // connection's own dictionary.
        let mut view = BoardView::new(hello_streams);
        let mut enc = EventEncoder::new(wire);
        let mut replay_round = true;
        loop {
            let mut round = SubscriberRound::default();
            {
                let mut g = self.shared.lock().unwrap();
                loop {
                    Self::build_round(&mut g, id, &mut view, &mut enc, replay_round, &mut round);
                    if !round.frames.is_empty() || round.done {
                        break;
                    }
                    let (back, _) =
                        self.progress.wait_timeout(g, Duration::from_millis(50)).unwrap();
                    g = back;
                }
            }
            let bufs: Vec<&[u8]> = round.frames.iter().map(Vec::as_slice).collect();
            let wrote = write_all_vectored(&mut conn, &bufs)?;
            conn.flush()?;
            replay_round = false;
            {
                let mut g = self.shared.lock().unwrap();
                let slot = &mut g.slots[id];
                slot.stats.frames = slot.stats.frames.saturating_add(round.frames.len() as u64);
                slot.stats.bytes = slot.stats.bytes.saturating_add(wrote);
                tele.sync(&slot.stats);
            }
            self.pump.hub().telemetry().publish_rounds.inc();
            if round.done {
                return Ok(());
            }
        }
    }

    /// Bring one subscriber fully up to date with the shared state,
    /// appending frames to `round` (idempotent: a second call with
    /// nothing new appends nothing). Runs under the shared lock; the
    /// socket write happens outside it.
    ///
    /// Per stream: an exact [`Frame::ResumeGap`] if the cursor fell
    /// below the retained window (demotion or joined-past-eviction),
    /// then every retained entry past the cursor — cloned v2 frames on
    /// a v2 wire or on the replay round, re-batched under the
    /// connection dictionary on a live v3 round. Then board deltas
    /// against this connection's own view (Streams growth before the
    /// events; beacons/drops/closes after), and Eos once the session
    /// finished — by then this round has delivered everything, so no
    /// separate caught-up check is needed.
    fn build_round(
        shared: &mut BroadcastShared,
        id: usize,
        view: &mut BoardView,
        enc: &mut EventEncoder,
        replay_round: bool,
        round: &mut SubscriberRound,
    ) {
        let BroadcastShared { ring, board, finished, slots, origins } = shared;
        let slot = &mut slots[id];
        if board.announced > view.announced {
            round.frames.push(encode_frame(&Frame::Streams { count: board.announced as u32 }));
            view.announced = board.announced;
        }
        view.ensure(board.announced);
        // Per-leaf accounting (relay mode): changed Origin entries go
        // out before this round's events, v3 wires only — the frame
        // type does not exist on a v2 wire. Entries are monotone, so
        // "changed vs this connection's view" is a plain comparison; a
        // fresh slot (join or resume) re-receives every entry.
        if !origins.is_empty() && matches!(enc, EventEncoder::Batched(_)) {
            for o in origins.iter() {
                if view.origins.iter().find(|v| v.path == o.path) != Some(o) {
                    round.frames.push(encode_frame(&o.frame()));
                }
            }
            if view.origins != *origins {
                view.origins = origins.clone();
            }
        }
        while slot.cursors.len() < ring.streams.len() {
            slot.cursors.push(0);
        }
        for i in 0..ring.streams.len() {
            let s = &ring.streams[i];
            let mut c = slot.cursors[i];
            if c < s.start_seq {
                let missed = s.start_seq - c;
                round.frames.push(encode_frame(&Frame::ResumeGap { stream: i as u32, missed }));
                slot.stats.lagged = slot.stats.lagged.saturating_add(missed);
                c = s.start_seq;
            }
            if c < s.end_seq {
                let skip = (c - s.start_seq) as usize;
                let mut delivered = 0usize;
                match (&mut *enc, replay_round) {
                    (EventEncoder::PerEvent, _) | (_, true) => {
                        for e in s.entries.iter().skip(skip) {
                            delivered += e.len();
                            round.frames.push(e.clone());
                        }
                    }
                    (EventEncoder::Batched(dict), false) => {
                        let mut run: Vec<BatchEvent> = Vec::new();
                        for e in s.entries.iter().skip(skip) {
                            delivered += e.len();
                            let (f, _) = frame::decode(e)
                                .expect("ring entries are well-formed frames")
                                .expect("ring entries are complete frames");
                            let Frame::Event { event, .. } = f else {
                                unreachable!("the ring stores only Event frames")
                            };
                            if run.len() >= frame::MAX_BATCH_EVENTS as usize {
                                round.frames.push(encode_frame(&Frame::EventBatch {
                                    stream: i as u32,
                                    events: std::mem::take(&mut run),
                                }));
                            }
                            let key = dict.key_for(event.rank, event.tid, event.class_id);
                            run.push(BatchEvent { ts: event.ts, key, fields: event.fields });
                        }
                        if !run.is_empty() {
                            round.frames.push(encode_frame(&Frame::EventBatch {
                                stream: i as u32,
                                events: run,
                            }));
                        }
                    }
                }
                slot.stats.forwarded = slot.stats.forwarded.saturating_add(s.end_seq - c);
                slot.behind = slot.behind.saturating_sub(delivered);
                c = s.end_seq;
            }
            slot.cursors[i] = c;
        }
        for i in 0..board.announced {
            if board.watermark[i] > view.watermark[i] {
                round.frames.push(encode_frame(&Frame::Beacon {
                    stream: i as u32,
                    watermark: board.watermark[i],
                }));
                view.watermark[i] = board.watermark[i];
            }
            if board.dropped[i] > view.dropped[i] {
                round.frames.push(encode_frame(&Frame::Drops {
                    stream: i as u32,
                    dropped: board.dropped[i],
                }));
                view.dropped[i] = board.dropped[i];
            }
            if board.closed[i] && !view.closed[i] {
                round.frames.push(encode_frame(&Frame::Close { stream: i as u32 }));
                view.closed[i] = true;
            }
        }
        if let Some((received, dropped)) = *finished {
            round.frames.push(encode_frame(&Frame::Eos { received, dropped }));
            round.done = true;
        }
    }
}

/// One subscriber thread's private record of what its wire has been
/// told about the non-event stream board.
struct BoardView {
    announced: usize,
    watermark: Vec<u64>,
    dropped: Vec<u64>,
    closed: Vec<bool>,
    /// The Origin entries this wire has been told (relay mode): a fresh
    /// view (new connection or resume) re-receives every entry, which
    /// is safe — they max-merge at the receiver.
    origins: Vec<OriginWire>,
}

impl BoardView {
    fn new(announced: usize) -> BoardView {
        BoardView {
            announced,
            watermark: Vec::new(),
            dropped: Vec::new(),
            closed: Vec::new(),
            origins: Vec::new(),
        }
    }

    fn ensure(&mut self, n: usize) {
        while self.watermark.len() < n {
            self.watermark.push(0);
            self.dropped.push(0);
            self.closed.push(false);
        }
    }
}

/// Unregisters a subscriber slot on EVERY exit path of
/// [`Broadcaster::serve_connection`] — clean Eos, transport error, or
/// panic. This is what keeps a crashed viewer from pinning the ring:
/// the slot loses eviction entitlement immediately and any over-budget
/// retention it was pinning is shed right here, not at the next push.
struct SlotGuard<'a> {
    bc: &'a Broadcaster,
    id: usize,
    tele: SubscriberTelemetry,
    completed: bool,
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        let mut g = self.bc.shared.lock().unwrap();
        {
            let slot = &mut g.slots[self.id];
            slot.entitled = false;
            slot.gone = true;
            if !self.completed {
                slot.stats.disconnects = 1;
            }
        }
        Broadcaster::evict_entitled(&mut g, self.bc.max_lag);
        self.bc.sync_ring_telemetry(&g.ring);
        self.tele.sync(&g.slots[self.id].stats);
        drop(g);
        self.bc.progress.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Fault-injection wrapper for reconnect testing (`iprof serve
/// --kill-after <bytes>` and the CI reconnect-smoke job): reads pass
/// through untouched; writes fail with `BrokenPipe` once `budget` bytes
/// have gone through — from the subscriber's side the publisher dies
/// mid-stream, possibly mid-frame. Dropping the wrapper drops the inner
/// connection, so a TCP peer observes EOF. (Vectored writes funnel
/// through the same budget: the default `write_vectored` forwards to
/// `write`.)
pub struct KillAfter<S> {
    inner: S,
    remaining: usize,
}

impl<S> KillAfter<S> {
    /// Fail every write after `budget` bytes have been written.
    pub fn new(inner: S, budget: usize) -> KillAfter<S> {
        KillAfter { inner, remaining: budget }
    }
}

impl<S: Read> Read for KillAfter<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.inner.read(buf)
    }
}

impl<S: Write> Write for KillAfter<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.remaining == 0 {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected connection kill (--kill-after)",
            ));
        }
        let n = buf.len().min(self.remaining);
        let written = self.inner.write(&buf[..n])?;
        self.remaining -= written;
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::EventMsg;
    use crate::tracer::btf::DecodedClass;
    use std::sync::Arc;

    fn msg(ts: u64) -> EventMsg {
        EventMsg {
            ts,
            rank: 0,
            tid: 0,
            hostname: Arc::from("pubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    /// Every event timestamp in wire order, per-event and batched frames
    /// alike (one decoder dictionary per call = per connection).
    fn event_ts_of(wire: &[u8]) -> Vec<u64> {
        let mut r = wire;
        frame::read_preamble(&mut r).unwrap();
        let mut dict = frame::BatchDict::new();
        let mut ts_seen = Vec::new();
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Event { event, .. } => ts_seen.push(event.ts),
                Frame::EventBatch { events, .. } => {
                    for ev in events {
                        dict.resolve(ev.key).unwrap();
                        ts_seen.push(ev.ts);
                    }
                }
                Frame::Eos { .. } => return ts_seen,
                _ => {}
            }
        }
    }

    #[test]
    fn publish_emits_preamble_hello_events_and_eos() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.bytes as usize, wire.len());
        assert!(stats.batches >= 1, "v3 default coalesces events into batches");

        let mut r = &wire[..];
        assert_eq!(frame::read_preamble(&mut r).unwrap(), 3, "default wire is v3");
        let mut frames = Vec::new();
        // read until Eos (the protocol guarantees it terminates the stream)
        loop {
            let f = frame::read_frame(&mut r).unwrap();
            let done = matches!(f, Frame::Eos { .. });
            frames.push(f);
            if done {
                break;
            }
        }
        assert!(
            matches!(frames[0], Frame::Hello { epoch: 0, .. }),
            "one-shot publish advertises a non-resumable session (epoch 0)"
        );
        assert_eq!(event_ts_of(&wire), vec![1, 2], "per-stream event order is preserved");
        assert!(frames.iter().any(|f| matches!(f, Frame::Close { stream: 0 })));
        assert!(matches!(frames.last(), Some(Frame::Eos { received: 2, dropped: 0 })));
        assert!(r.is_empty(), "Eos is the final frame");
    }

    #[test]
    fn publish_with_wire2_emits_the_legacy_per_event_stream() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish_with(&hub, &mut wire, 2).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.batches, 0, "a v2 wire never batches");
        assert_eq!(stats.bytes as usize, wire.len());
        let mut r = &wire[..];
        assert_eq!(frame::read_preamble(&mut r).unwrap(), 2, "preamble announces the fallback");
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::EventBatch { .. } => panic!("EventBatch on a v2 wire"),
                Frame::Eos { .. } => break,
                _ => {}
            }
        }
        assert_eq!(event_ts_of(&wire), vec![1, 2]);
    }

    #[test]
    fn v3_batches_split_on_stream_change_and_share_one_dictionary() {
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(2);
        // same (rank, tid, class) everywhere: the first batch defines the
        // triple, every later event refs it — across batch boundaries
        hub.push_batch(0, (0..10).map(msg).collect());
        hub.push_batch(1, (10..14).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 14);
        assert_eq!(stats.batches, 2, "one batch per consecutive same-stream run");
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut defs = 0;
        let mut refs = 0;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::EventBatch { events, .. } => {
                    for ev in &events {
                        match ev.key {
                            frame::BatchKey::Def { .. } => defs += 1,
                            frame::BatchKey::Ref(0) => refs += 1,
                            frame::BatchKey::Ref(_) => panic!("one triple, one index"),
                        }
                    }
                }
                Frame::Eos { .. } => break,
                _ => {}
            }
        }
        assert_eq!((defs, refs), (1, 13), "dictionary is connection state, not batch state");
    }

    #[test]
    fn publish_relays_drop_counts() {
        let hub = LiveHub::new("pubtest", 2, false);
        hub.ensure_channels(1);
        // depth 2: 3 of 5 messages drop at the hub
        hub.push_batch(0, (0..5).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut saw_drops = None;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Drops { stream: 0, dropped } => saw_drops = Some(dropped),
                Frame::Eos { received, dropped } => {
                    assert_eq!(received, 2);
                    assert_eq!(dropped, 3);
                    break;
                }
                _ => {}
            }
        }
        assert_eq!(saw_drops, Some(3), "per-stream cumulative drop count is relayed");
    }

    /// Encode one fake event frame of a known payload size.
    fn fake_event_frame(stream: u32, ts: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        frame::encode(
            &Frame::Event {
                stream,
                event: WireEvent { ts, rank: 0, tid: 0, class_id: 0, fields: vec![] },
            },
            &mut buf,
        );
        buf
    }

    #[test]
    fn replay_ring_replays_exactly_past_the_cursor() {
        let mut ring = ReplayRing::new(1 << 20);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        ring.push(1, fake_event_frame(1, 100));
        // cursor [2, 0]: replay stream 0 events 2..5 and all of stream 1
        let mut out = Vec::new();
        let s = ring.replay(&[2], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (4, 0, 0));
        assert_eq!(s.bytes as usize, out.len());
        let mut ts_seen = Vec::new();
        let mut off = 0;
        while off < out.len() {
            let (f, n) = frame::decode(&out[off..]).unwrap().unwrap();
            let Frame::Event { event, .. } = f else { panic!("only events replay") };
            ts_seen.push(event.ts);
            off += n;
        }
        assert_eq!(ts_seen, vec![2, 3, 4, 100]);
        // a cursor claiming more than was ever relayed is a protocol error
        assert!(ring.replay(&[9], &mut Vec::new()).is_err());
    }

    #[test]
    fn replay_ring_evicts_oldest_first_and_reports_gaps() {
        let one = fake_event_frame(0, 0).len();
        // budget for exactly 3 frames: pushing 5 evicts the oldest 2
        let mut ring = ReplayRing::new(3 * one);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        assert_eq!(ring.streams[0].start_seq, 2);
        assert_eq!(ring.streams[0].end_seq, 5);
        // a fresh cursor (0) fell below the window: gap of 2, then replay 3
        let mut out = Vec::new();
        let s = ring.replay(&[0], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (3, 2, 1));
        let (f, n) = frame::decode(&out).unwrap().unwrap();
        assert_eq!(
            f,
            Frame::ResumeGap { stream: 0, missed: 2 },
            "the gap precedes the replayed events"
        );
        let (f, _) = frame::decode(&out[n..]).unwrap().unwrap();
        let Frame::Event { event, .. } = f else { panic!("replay follows the gap") };
        assert_eq!(event.ts, 2, "replay starts at the oldest retained event");
        // a cursor inside the window replays gap-free
        let s = ring.replay(&[4], &mut Vec::new()).unwrap();
        assert_eq!((s.replayed, s.gaps), (1, 0));
    }

    #[test]
    fn write_all_vectored_advances_through_partial_and_single_buffer_writes() {
        // KillAfter's write ignores write_vectored batching (default
        // forwarding) and truncates at its budget — both paths the
        // helper must survive by re-slicing and continuing
        let mut sink = Vec::new();
        let bufs: Vec<Vec<u8>> = vec![vec![1; 5], vec![], vec![2; 7], vec![3; 3]];
        let slices: Vec<&[u8]> = bufs.iter().map(Vec::as_slice).collect();
        let n = write_all_vectored(&mut KillAfter::new(&mut sink, 1 << 20), &slices).unwrap();
        assert_eq!(n, 15);
        let mut expect = Vec::new();
        for b in &bufs {
            expect.extend_from_slice(b);
        }
        assert_eq!(sink, expect, "all bytes, in order, empties skipped");
        // and a mid-buffer failure surfaces as the error it is
        let mut sink = Vec::new();
        let err =
            write_all_vectored(&mut KillAfter::new(&mut sink, 6), &slices).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(sink.len(), 6, "exactly the budget reached the wire");
    }

    #[test]
    fn kill_after_passes_then_breaks_writes_mid_buffer() {
        let mut sink = Vec::new();
        let mut conn = KillAfter::new(&mut sink, 10);
        assert_eq!(conn.write(&[0u8; 8]).unwrap(), 8);
        // partial write up to the budget, then hard failure
        assert_eq!(conn.write(&[1u8; 8]).unwrap(), 2);
        let err = conn.write(&[2u8; 8]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(sink.len(), 10, "exactly the budget reached the wire");
    }

    #[test]
    fn fresh_epochs_are_nonzero() {
        assert_ne!(Publisher::fresh_epoch() & 1, 0, "low bit forced: never zero");
    }

    /// An in-memory subscriber: its scripted input (a Resume frame, or
    /// nothing) is all it ever says; everything the publisher writes
    /// lands in `output`.
    struct ScriptedConn {
        input: std::io::Cursor<Vec<u8>>,
        output: Vec<u8>,
    }

    impl ScriptedConn {
        fn resume(epoch: u64, cursors: &[u64]) -> ScriptedConn {
            let mut input = Vec::new();
            frame::encode(&Frame::Resume { epoch, cursors: cursors.to_vec() }, &mut input);
            ScriptedConn { input: std::io::Cursor::new(input), output: Vec::new() }
        }

        fn silent() -> ScriptedConn {
            ScriptedConn { input: std::io::Cursor::new(Vec::new()), output: Vec::new() }
        }
    }

    impl Read for ScriptedConn {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.input.read(buf)
        }
    }

    impl Write for ScriptedConn {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.output.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn broadcast_serves_the_full_stream_to_mixed_wire_subscribers() {
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2), msg(3), msg(4)]);
        hub.close_all();
        let bc = Broadcaster::new(hub.clone(), 9, 1 << 20);
        bc.pump();
        assert!(bc.finished());

        let mut v2 = ScriptedConn::resume(9, &[]);
        assert_eq!(bc.serve_connection(&mut v2, 2), ServeOutcome::Complete);
        let mut v3 = ScriptedConn::resume(9, &[]);
        assert_eq!(bc.serve_connection(&mut v3, 3), ServeOutcome::Complete);

        for (out, wire) in [(&v2.output, 2u32), (&v3.output, 3u32)] {
            let mut r = &out[..];
            assert_eq!(frame::read_preamble(&mut r).unwrap(), wire, "per-connection wire");
            let Frame::Hello { epoch, .. } = frame::read_frame(&mut r).unwrap() else {
                panic!("first frame must be Hello");
            };
            assert_eq!(epoch, 9, "broadcast sessions are resumable");
            assert_eq!(event_ts_of(out), vec![1, 2, 3, 4]);
        }
        // a mid-window Resume replays exactly past its cursors
        let mut resumed = ScriptedConn::resume(9, &[2]);
        assert_eq!(bc.serve_connection(&mut resumed, 3), ServeOutcome::Complete);
        assert_eq!(event_ts_of(&resumed.output), vec![3, 4]);

        let rows = bc.subscriber_stats();
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[0].wire, rows[0].forwarded, rows[0].lagged), (2, 4, 0));
        assert_eq!((rows[1].wire, rows[1].forwarded, rows[1].lagged), (3, 4, 0));
        assert_eq!((rows[2].forwarded, rows[2].lagged, rows[2].disconnects), (2, 0, 0));
        assert!(rows.iter().all(|r| r.demoted == 0 && r.error.is_none()));
        let agg = bc.stats();
        assert_eq!((agg.connections, agg.events, agg.gaps), (3, 10, 0));
    }

    #[test]
    fn broadcast_v3_rebatches_live_rounds_with_connection_dictionary() {
        // drive build_round directly: the first (replay) round forwards
        // ring frames verbatim, later v3 rounds re-batch them under the
        // connection's own dictionary
        let mut shared = BroadcastShared {
            ring: ReplayRing::new(1 << 20),
            board: StreamBoard::default(),
            finished: None,
            origins: Vec::new(),
            slots: vec![SubscriberSlot {
                cursors: vec![],
                entitled: true,
                gone: false,
                behind: 0,
                stats: SubscriberStats::default(),
            }],
        };
        shared.board.ensure(1);
        shared.ring.push(0, fake_event_frame(0, 1));
        let mut view = BoardView::new(1);
        let mut enc = EventEncoder::new(3);
        let mut round = SubscriberRound::default();
        Broadcaster::build_round(&mut shared, 0, &mut view, &mut enc, true, &mut round);
        assert_eq!(round.frames.len(), 1);
        let (f, _) = frame::decode(&round.frames[0]).unwrap().unwrap();
        assert!(matches!(f, Frame::Event { .. }), "the replay round is per-event frames");

        shared.ring.push(0, fake_event_frame(0, 2));
        shared.ring.push(0, fake_event_frame(0, 3));
        let mut round = SubscriberRound::default();
        Broadcaster::build_round(&mut shared, 0, &mut view, &mut enc, false, &mut round);
        assert_eq!(round.frames.len(), 1);
        let (f, _) = frame::decode(&round.frames[0]).unwrap().unwrap();
        let Frame::EventBatch { events, .. } = f else { panic!("live v3 rounds batch") };
        assert_eq!(events.len(), 2);
        assert!(
            matches!(events[0].key, BatchKey::Def { .. })
                && matches!(events[1].key, BatchKey::Ref(0)),
            "dictionary is connection state, started by the first batched event"
        );
        assert_eq!(shared.slots[0].stats.forwarded, 3);
        assert_eq!(shared.slots[0].cursors, vec![3]);
    }

    #[test]
    fn broadcast_demotes_laggard_under_pressure_and_books_the_exact_gap() {
        let one = fake_event_frame(0, 0).len();
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(1);
        let bc = Broadcaster::new(hub.clone(), 7, 3 * one).with_max_lag(one);
        // a subscriber stuck at cursor 0 while 10 events push through a
        // 3-frame ring: over the 1-frame lag budget it must demote, and
        // the ring must shed back to budget instead of pinning
        let id = bc.register(3);
        hub.push_batch(0, (0..10).map(msg).collect());
        hub.close_all();
        bc.pump();
        {
            let g = bc.shared.lock().unwrap();
            assert!(!g.slots[id].entitled, "over the lag budget: demoted");
            assert_eq!(g.slots[id].stats.demoted, 1, "demotion is sticky, counted once");
            assert_eq!(g.ring.total, 3 * one, "demotion unpinned the ring");
            assert_eq!(g.ring.streams[0].start_seq, 7);
        }
        // a fresh subscriber joining past the eviction gets the exact
        // gap plus the retained tail — lag, not demotion
        let mut late = ScriptedConn::resume(7, &[]);
        assert_eq!(bc.serve_connection(&mut late, 2), ServeOutcome::Complete);
        let mut r = &late.output[..];
        frame::read_preamble(&mut r).unwrap();
        frame::read_frame(&mut r).unwrap(); // Hello
        assert_eq!(
            frame::read_frame(&mut r).unwrap(),
            Frame::ResumeGap { stream: 0, missed: 7 },
            "the exact evicted span precedes the replay"
        );
        assert_eq!(event_ts_of(&late.output), vec![7, 8, 9]);
        let rows = bc.subscriber_stats();
        let row = rows.last().unwrap();
        assert_eq!((row.lagged, row.demoted), (7, 0), "joining past eviction is lag, not demotion");
    }

    #[test]
    fn dead_subscriber_unregisters_from_eviction_entitlement() {
        let one = fake_event_frame(0, 0).len();
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(1);
        let bc = Broadcaster::new(hub.clone(), 7, 3 * one); // no lag budget
        let id = bc.register(3);
        let tele = SubscriberTelemetry::register(hub.telemetry(), id);
        hub.push_batch(0, (0..10).map(msg).collect());
        hub.close_all();
        bc.pump();
        {
            let g = bc.shared.lock().unwrap();
            assert!(g.slots[id].entitled);
            assert_eq!(g.ring.total, 10 * one, "an entitled laggard pins the whole window");
            assert_eq!(g.ring.streams[0].start_seq, 0);
        }
        // the subscriber dies: the guard must unregister the slot and
        // shed the over-budget retention immediately — not at the next
        // push (there is none), and certainly not never
        drop(SlotGuard { bc: &bc, id, tele, completed: false });
        let g = bc.shared.lock().unwrap();
        assert!(!g.slots[id].entitled && g.slots[id].gone);
        assert_eq!(g.slots[id].stats.disconnects, 1);
        assert_eq!(g.ring.total, 3 * one, "dead slot no longer pins the ring");
        assert_eq!(g.ring.streams[0].start_seq, 7);
    }

    #[test]
    fn broadcast_handshake_death_is_recorded_and_isolated() {
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1)]);
        hub.close_all();
        let bc = Broadcaster::new(hub.clone(), 9, 1 << 20);
        bc.pump();
        // dies before sending Resume
        let mut dead = ScriptedConn::silent();
        assert!(matches!(bc.serve_connection(&mut dead, 3), ServeOutcome::Lost(_)));
        // a later subscriber is untouched
        let mut ok = ScriptedConn::resume(9, &[]);
        assert_eq!(bc.serve_connection(&mut ok, 3), ServeOutcome::Complete);
        assert_eq!(event_ts_of(&ok.output), vec![1]);
        let rows = bc.subscriber_stats();
        assert_eq!(rows[0].disconnects, 1);
        assert!(rows[0].error.is_some());
        assert_eq!((rows[1].disconnects, rows[1].forwarded), (0, 1));
    }
}
