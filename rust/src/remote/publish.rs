//! The publishing end of a remote-live connection (`iprof serve`).
//!
//! [`publish`] is the `lttng-relayd` analogue collapsed into the traced
//! process: it drains a [`LiveHub`]'s per-stream channels through
//! [`LiveHub::next_forward_batch`] and relays everything — events,
//! watermark beacons, drop counts, closes — as THRL frames over any
//! reliable byte stream, finishing with a clean [`Frame::Eos`]. It is
//! the one-shot, non-resumable path: Hello advertises epoch 0 and a
//! dropped connection ends the relay for good.
//!
//! # The hot path (v3)
//!
//! On a v3 wire the pump coalesces each forward round's events into
//! [`Frame::EventBatch`] frames — one per consecutive same-stream run,
//! capped at [`frame::MAX_BATCH_EVENTS`] — with delta timestamps and the
//! per-connection `(rank, tid, class_id)` dictionary
//! ([`frame::BatchDictEncoder`]), then flushes the whole round with one
//! vectored write (manual `IoSlice` batching over the `Write` sink)
//! instead of one `write` per frame. `iprof serve --wire 2` keeps the
//! exact per-event v2 byte stream for old subscribers; see
//! `docs/PROTOCOL.md` § Versioning for the fallback matrix.
//!
//! [`Publisher`] is the resumable flavor (`iprof serve --resume-buffer`):
//! it owns a session **epoch** and a byte-budgeted [replay ring] of the
//! event frames it has relayed, and serves a *sequence* of connections
//! over the same session. Each connection handshakes
//! `Hello(epoch) → Resume(epoch, cursors)`, replays every ringed event
//! past the subscriber's per-stream cursors (answering
//! [`Frame::ResumeGap`] where the ring already evicted them), resyncs
//! watermark/drop/close state, and then pumps live batches until the
//! next disconnect or the final [`Frame::Eos`]:
//!
//! ```text
//!            ┌───────────── one session (epoch E) ──────────────┐
//! subscriber │ conn 1            conn 2                conn 3   │
//!   ────────►│ Hello(E)          Hello(E)              Hello(E) │
//!   Resume ─►│ (E, [])           (E, cursors)          ...      │
//!   ◄──────  │ events...  ✂      ResumeGap? + replay + events...│──► Eos
//!            └──────────────────────────────────────────────────┘
//!                    ✂ = transport died; ring keeps the tail
//! ```
//!
//! The ring always stores **per-event v2 `Event` frames**, whatever the
//! live wire speaks: replayed frames are valid on both wire versions (v3
//! is a byte-superset of v2), and ring sequence numbers keep counting
//! *events*, so resume cursors, gap ledgers and drop accounting are
//! untouched by batching.
//!
//! The publisher inherits the hub's backpressure contract end to end: it
//! never pushes back on the tracing consumer. If the transport stalls
//! (slow subscriber, slow network), the hub's bounded channels fill and
//! the consumer's try-push **drops and counts**; the loss is then
//! reported to the subscriber through [`Frame::Drops`] / [`Frame::Eos`],
//! so both ends always agree on completeness. The traced application
//! never waits on a socket — and never waits on a *vanished* subscriber
//! either: between connections the hub keeps draining into the ring
//! exactly as fast as before.
//!
//! [replay ring]: Publisher#replay-ring-semantics

use super::frame::{self, BatchEvent, BatchKey, Frame, FrameError, WireEvent};
use crate::live::{ForwardCursor, LiveHub};
use crate::telemetry::Registry;
use crate::tracer::btf::generate_metadata;
use crate::tracer::encoder::FieldValue;
use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::sync::Arc;

/// What one [`publish`] call (or one whole [`Publisher`] session)
/// relayed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PublishStats {
    /// Frames written (preamble excluded).
    pub frames: u64,
    /// Events relayed live (replays excluded). Counts *events*, not
    /// frames: a v3 batch of n events adds n here and 1 to `frames`.
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Bytes written, preambles included.
    pub bytes: u64,
    /// Subscriber connections served (always 1 for [`publish`]).
    pub connections: u64,
    /// Event frames re-sent from the replay ring on resume.
    pub replayed: u64,
    /// Events a resuming subscriber asked for that the ring had already
    /// evicted (the sum of all [`Frame::ResumeGap`] `missed` counts) —
    /// each one is an event permanently absent from the remote view.
    pub gaps: u64,
    /// `EventBatch` frames written (0 on a v2 wire).
    pub batches: u64,
    /// Batch-dictionary definitions written: first sightings of a
    /// `(rank, tid, class_id)` triple on this connection (0 on v2).
    pub dict_defs: u64,
    /// Batch-dictionary references written: repeat sightings resolved to
    /// a dictionary index. `refs / (defs + refs)` is the dictionary hit
    /// rate the telemetry endpoint exposes.
    pub dict_refs: u64,
}

impl PublishStats {
    /// Mirror these cumulative wire statistics into the registry.
    /// Absolute values via [`crate::telemetry::Counter::store_max`]: the
    /// struct is single-writer monotone, so after every sync the
    /// registry series *equals* the struct — the scrape endpoint and the
    /// end-of-run `ServeReport` can never disagree, and a re-sync can
    /// never double-count a round.
    fn sync_telemetry(&self, reg: &Registry) {
        reg.publish_frames.store_max(self.frames);
        reg.publish_events.store_max(self.events);
        reg.publish_bytes.store_max(self.bytes);
        reg.publish_batches.store_max(self.batches);
        reg.publish_dict_defs.store_max(self.dict_defs);
        reg.publish_dict_refs.store_max(self.dict_refs);
        reg.publish_replayed.store_max(self.replayed);
        reg.publish_gap_events.store_max(self.gaps);
        reg.publish_connections.store_max(self.connections);
    }
}

/// Encode one event as its complete per-event v2 `Event` frame — the
/// ONE place event bytes of that shape are produced, so the one-shot,
/// offline-drain and live-resumable paths can never encode differently
/// (ring replay byte-identity depends on that).
fn encode_event_parts(
    stream: usize,
    ts: u64,
    rank: u32,
    tid: u32,
    class_id: u32,
    fields: Vec<FieldValue>,
) -> Vec<u8> {
    let f = Frame::Event {
        stream: stream as u32,
        event: WireEvent { ts, rank, tid, class_id, fields },
    };
    let mut buf = Vec::with_capacity(64);
    frame::encode(&f, &mut buf);
    buf
}

/// [`encode_event_parts`] straight from a hub message.
fn encode_event(stream: usize, msg: crate::analysis::EventMsg) -> Vec<u8> {
    encode_event_parts(stream, msg.ts, msg.rank, msg.tid, msg.class.id, msg.fields)
}

/// Encode one frame into its own buffer.
fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    frame::encode(f, &mut buf);
    buf
}

/// Write every buffer with as few calls as the sink allows: manual
/// `IoSlice` batching over `Write::write_vectored`, chunked to stay
/// under typical `IOV_MAX` limits, advancing through partial writes.
/// For sinks without real vectored I/O the default `write_vectored`
/// degrades to one plain write of the first slice per call — still
/// correct, just unbatched. Returns the total bytes written.
fn write_all_vectored(w: &mut impl Write, bufs: &[&[u8]]) -> io::Result<u64> {
    const MAX_SLICES: usize = 512;
    let mut total = 0u64;
    let mut i = 0usize; // first unfinished buffer
    let mut off = 0usize; // bytes of bufs[i] already written
    while i < bufs.len() {
        if off >= bufs[i].len() {
            i += 1;
            off = 0;
            continue;
        }
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(MAX_SLICES.min(bufs.len() - i));
        slices.push(IoSlice::new(&bufs[i][off..]));
        for b in bufs[i + 1..].iter().take(MAX_SLICES - 1) {
            slices.push(IoSlice::new(b));
        }
        let mut n = w.write_vectored(&slices)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::WriteZero, "failed to write frames"));
        }
        total += n as u64;
        while n > 0 {
            let left = bufs[i].len() - off;
            if n >= left {
                n -= left;
                i += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(total)
}

/// The per-connection event encoder: either the v2 per-event wire or
/// the v3 batched wire with its running dictionary.
enum EventEncoder {
    /// Per-event `Event` frames, exactly the v2 byte stream.
    PerEvent,
    /// `EventBatch` frames with the connection dictionary.
    Batched(frame::BatchDictEncoder),
}

impl EventEncoder {
    fn new(wire: u32) -> EventEncoder {
        if wire >= 3 {
            EventEncoder::Batched(frame::BatchDictEncoder::new())
        } else {
            EventEncoder::PerEvent
        }
    }

    /// Encode one forward round's events into wire frames (appended to
    /// `wire_frames`) and optionally ring entries (appended to
    /// `ring_frames` as `(stream, v2 event frame)` — the replay ring
    /// stores per-event frames whatever the wire speaks). Batched mode
    /// cuts one `EventBatch` per consecutive same-stream run, capped at
    /// [`frame::MAX_BATCH_EVENTS`].
    fn encode_events(
        &mut self,
        stats: &mut PublishStats,
        events: Vec<(usize, crate::analysis::EventMsg)>,
        wire_frames: &mut Vec<Vec<u8>>,
        mut ring_frames: Option<&mut Vec<(usize, Vec<u8>)>>,
    ) {
        match self {
            EventEncoder::PerEvent => {
                for (idx, msg) in events {
                    let buf = encode_event(idx, msg);
                    stats.frames = stats.frames.saturating_add(1);
                    stats.events = stats.events.saturating_add(1);
                    match ring_frames.as_deref_mut() {
                        // the identical bytes serve wire and ring; the
                        // round writer borrows them from the ring list
                        Some(ring) => ring.push((idx, buf)),
                        None => wire_frames.push(buf),
                    }
                }
            }
            EventEncoder::Batched(dict) => {
                let mut run_stream = usize::MAX;
                let mut run: Vec<BatchEvent> = Vec::new();
                let mut flush =
                    |stream: usize, run: &mut Vec<BatchEvent>, stats: &mut PublishStats| {
                        if run.is_empty() {
                            return;
                        }
                        let f = Frame::EventBatch {
                            stream: stream as u32,
                            events: std::mem::take(run),
                        };
                        wire_frames.push(encode_frame(&f));
                        stats.frames = stats.frames.saturating_add(1);
                        stats.batches = stats.batches.saturating_add(1);
                    };
                for (idx, mut msg) in events {
                    if idx != run_stream || run.len() >= frame::MAX_BATCH_EVENTS as usize {
                        flush(run_stream, &mut run, stats);
                        run_stream = idx;
                    }
                    if let Some(ring) = ring_frames.as_deref_mut() {
                        ring.push((
                            idx,
                            encode_event_parts(
                                idx,
                                msg.ts,
                                msg.rank,
                                msg.tid,
                                msg.class.id,
                                msg.fields.clone(),
                            ),
                        ));
                    }
                    let key = dict.key_for(msg.rank, msg.tid, msg.class.id);
                    match key {
                        BatchKey::Ref(_) => stats.dict_refs = stats.dict_refs.saturating_add(1),
                        BatchKey::Def { .. } => {
                            stats.dict_defs = stats.dict_defs.saturating_add(1)
                        }
                    }
                    run.push(BatchEvent {
                        ts: msg.ts,
                        key,
                        fields: std::mem::take(&mut msg.fields),
                    });
                    stats.events = stats.events.saturating_add(1);
                }
                flush(run_stream, &mut run, stats);
            }
        }
    }
}

/// One forward round, encoded and ready to hit the wire: control frames
/// in protocol order around the event frames. `write` flushes the whole
/// round with one vectored write.
#[derive(Default)]
struct EncodedRound {
    /// Frames that must precede the events (`Streams` growth).
    pre: Vec<Vec<u8>>,
    /// Event frames (v2 per-event or v3 batches). For a ringed v2 round
    /// this stays empty — the wire borrows `ring` instead.
    events: Vec<Vec<u8>>,
    /// `(stream, v2 event frame)` entries bound for the replay ring.
    ring: Vec<(usize, Vec<u8>)>,
    /// Does the wire borrow `ring` as its event bytes? (v2 + ring)
    wire_uses_ring: bool,
    /// Frames that follow the events (beacons, drops, closes).
    post: Vec<Vec<u8>>,
}

impl EncodedRound {
    /// Encode one forward batch. `ringed` selects whether per-event v2
    /// frames are produced for the replay ring.
    fn encode(
        stats: &mut PublishStats,
        enc: &mut EventEncoder,
        batch: crate::live::ForwardBatch,
        ringed: bool,
    ) -> EncodedRound {
        let mut round = EncodedRound {
            wire_uses_ring: ringed && matches!(enc, EventEncoder::PerEvent),
            ..Default::default()
        };
        if let Some(count) = batch.grown_to {
            round.pre.push(encode_frame(&Frame::Streams { count: count as u32 }));
            stats.frames = stats.frames.saturating_add(1);
        }
        enc.encode_events(
            stats,
            batch.events,
            &mut round.events,
            ringed.then_some(&mut round.ring),
        );
        for (idx, watermark) in batch.beacons {
            round.post.push(encode_frame(&Frame::Beacon { stream: idx as u32, watermark }));
            stats.frames = stats.frames.saturating_add(1);
            stats.beacons = stats.beacons.saturating_add(1);
        }
        for (idx, dropped) in batch.drops {
            round.post.push(encode_frame(&Frame::Drops { stream: idx as u32, dropped }));
            stats.frames = stats.frames.saturating_add(1);
        }
        for idx in batch.closed {
            round.post.push(encode_frame(&Frame::Close { stream: idx as u32 }));
            stats.frames = stats.frames.saturating_add(1);
        }
        round
    }

    /// One vectored write for the whole round.
    fn write(&self, w: &mut impl Write) -> io::Result<u64> {
        let mut bufs: Vec<&[u8]> =
            Vec::with_capacity(self.pre.len() + self.events.len() + self.ring.len() + self.post.len());
        bufs.extend(self.pre.iter().map(Vec::as_slice));
        if self.wire_uses_ring {
            bufs.extend(self.ring.iter().map(|(_, b)| b.as_slice()));
        } else {
            bufs.extend(self.events.iter().map(Vec::as_slice));
        }
        bufs.extend(self.post.iter().map(Vec::as_slice));
        write_all_vectored(w, &bufs)
    }
}

/// [`publish`] with an explicit wire version: 3 (the default) batches
/// events into [`Frame::EventBatch`] frames; 2 emits the exact legacy
/// per-event byte stream for v2-only subscribers (`iprof serve
/// --wire 2`). Panics on a version this build does not speak.
pub fn publish_with<W: Write>(hub: &LiveHub, mut conn: W, wire: u32) -> io::Result<PublishStats> {
    assert!(
        frame::SUPPORTED_VERSIONS.contains(&wire),
        "publisher wire version {wire} not in {:?}",
        frame::SUPPORTED_VERSIONS
    );
    let mut stats = PublishStats { connections: 1, ..Default::default() };
    let mut head = Vec::with_capacity(256);
    frame::write_preamble_version(&mut head, wire)?;
    frame::encode(
        &Frame::Hello {
            hostname: hub.hostname().to_string(),
            // The same registry-derived metadata a post-mortem `collect`
            // writes: the subscriber decodes class ids through the
            // identical descriptor path.
            metadata: generate_metadata(&[]),
            streams: hub.stats().channels as u32,
            // epoch 0 = not resumable: the subscriber must not send
            // Resume, and a dropped connection is a permanent end of feed
            epoch: 0,
        },
        &mut head,
    );
    conn.write_all(&head)?;
    conn.flush()?;
    stats.bytes = stats.bytes.saturating_add(head.len() as u64);
    stats.frames = stats.frames.saturating_add(1);
    let reg = hub.telemetry();
    reg.publish_rounds.inc(); // the handshake round
    stats.sync_telemetry(reg);

    let mut enc = EventEncoder::new(wire);
    let mut cursor = ForwardCursor::default();
    while let Some(batch) = hub.next_forward_batch(&mut cursor) {
        let round = EncodedRound::encode(&mut stats, &mut enc, batch, false);
        stats.bytes = stats.bytes.saturating_add(round.write(&mut conn)?);
        // One flush per round: frames reach the subscriber with
        // drain-round granularity (milliseconds), not buffer-fill
        // granularity.
        conn.flush()?;
        reg.publish_rounds.inc();
        stats.sync_telemetry(reg);
    }

    let totals = hub.stats();
    let eos = encode_frame(&Frame::Eos { received: totals.received, dropped: totals.dropped });
    conn.write_all(&eos)?;
    conn.flush()?;
    stats.bytes = stats.bytes.saturating_add(eos.len() as u64);
    stats.frames = stats.frames.saturating_add(1);
    stats.sync_telemetry(reg);
    Ok(stats)
}

/// Publish `hub` over `conn` until the hub seals and drains: preamble,
/// then [`Frame::Hello`] carrying the hostname and the full BTF metadata
/// text (the subscriber's class table), then forward batches as they
/// appear, then [`Frame::Eos`] with the hub's final received/dropped
/// totals. Speaks the default wire version ([`frame::VERSION`], batched);
/// see [`publish_with`] for the v2 fallback.
///
/// Blocks until end of stream; run it on its own thread next to the
/// workload (see [`crate::coordinator::run_serve`]). Returns an error as
/// soon as the transport fails — the traced session is unaffected, the
/// hub just stops being drained and its channels degrade to
/// drop-and-count.
pub fn publish<W: Write>(hub: &LiveHub, conn: W) -> io::Result<PublishStats> {
    publish_with(hub, conn, frame::VERSION)
}

// ---------------------------------------------------------------------------
// Replay ring: the bounded memory a resumable session keeps per stream
// ---------------------------------------------------------------------------

/// Per-stream retained window. `start_seq..end_seq` are the sequence
/// numbers of the encoded event frames currently held: `end_seq` counts
/// every event ever relayed on the stream, `start_seq` trails it by the
/// entries not yet evicted (`end_seq - start_seq == entries.len()`
/// always).
#[derive(Default)]
struct StreamRing {
    start_seq: u64,
    end_seq: u64,
    entries: VecDeque<Vec<u8>>,
}

/// What one [`ReplayRing::replay`] wrote.
#[derive(Debug, Default, PartialEq, Eq)]
struct ReplaySummary {
    /// Event frames re-sent.
    replayed: u64,
    /// Events irrecoverably lost (sum of all `ResumeGap.missed`).
    gaps: u64,
    /// `ResumeGap` frames written (streams with a gap).
    gap_frames: u64,
    /// Total bytes written.
    bytes: u64,
}

/// Byte-budgeted replay storage for a resumable session: every event
/// frame relayed to the subscriber is retained until the total retained
/// size exceeds the budget, then the globally oldest entries are evicted
/// first. Sequence numbers are per stream and *dense* — a subscriber's
/// cursor is simply its count of delivered events on that stream.
/// Entries are always per-event v2 `Event` frames (valid on both wire
/// versions), so one ring serves v2 and v3 connections alike and its
/// sequence numbers count events regardless of live-path batching.
struct ReplayRing {
    streams: Vec<StreamRing>,
    /// Streams in global push order: per-stream queues are FIFO, so the
    /// front of this queue always names the stream holding the globally
    /// oldest retained entry — O(1) eviction instead of an O(streams)
    /// scan per evicted event.
    evict_order: VecDeque<u32>,
    budget: usize,
    total: usize,
    /// Event frames evicted over the ring's lifetime (each one is a
    /// potential future resume gap). Saturating; mirrored to telemetry.
    evicted: u64,
}

impl ReplayRing {
    fn new(budget: usize) -> ReplayRing {
        ReplayRing {
            streams: Vec::new(),
            evict_order: VecDeque::new(),
            budget: budget.max(1),
            total: 0,
            evicted: 0,
        }
    }

    fn ensure(&mut self, n: usize) {
        while self.streams.len() < n {
            self.streams.push(StreamRing::default());
        }
    }

    /// Retain one relayed event frame, evicting oldest-first (across all
    /// streams) once over budget. Eviction moves the stream's
    /// `start_seq` forward: a later resume below it is a gap.
    fn push(&mut self, stream: usize, bytes: Vec<u8>) {
        self.ensure(stream + 1);
        self.total += bytes.len();
        let s = &mut self.streams[stream];
        s.entries.push_back(bytes);
        s.end_seq += 1;
        self.evict_order.push_back(stream as u32);
        while self.total > self.budget {
            let Some(idx) = self.evict_order.pop_front() else { break };
            let s = &mut self.streams[idx as usize];
            let evicted = s.entries.pop_front().expect("evict queue tracks live entries 1:1");
            self.total -= evicted.len();
            s.start_seq += 1;
            self.evicted = self.evicted.saturating_add(1);
        }
    }

    /// Replay everything past the subscriber's per-stream `cursors` into
    /// `w`, stream by stream: a [`Frame::ResumeGap`] for any stream
    /// whose cursor fell below the retained window, immediately followed
    /// by that stream's retained event frames in original order (the
    /// `stream-replay` production in `docs/PROTOCOL.md`).
    fn replay<W: Write>(&self, cursors: &[u64], w: &mut W) -> io::Result<ReplaySummary> {
        // cursors beyond the streams we ever relayed on can only be 0
        for (i, &c) in cursors.iter().enumerate() {
            let sent = self.streams.get(i).map(|s| s.end_seq).unwrap_or(0);
            if c > sent {
                return Err(FrameError::Malformed("resume cursor beyond relayed events").into());
            }
        }
        let mut out = ReplaySummary::default();
        for (i, s) in self.streams.iter().enumerate() {
            let c = cursors.get(i).copied().unwrap_or(0);
            if c < s.start_seq {
                let missed = s.start_seq - c;
                out.bytes +=
                    frame::write_frame(w, &Frame::ResumeGap { stream: i as u32, missed })? as u64;
                out.gaps += missed;
                out.gap_frames += 1;
            }
            let skip = c.saturating_sub(s.start_seq) as usize;
            for e in s.entries.iter().skip(skip) {
                w.write_all(e)?;
                out.bytes += e.len() as u64;
                out.replayed += 1;
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Resumable publisher
// ---------------------------------------------------------------------------

/// How one subscriber connection ended, from the publisher's side.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeOutcome {
    /// The session fully drained and [`Frame::Eos`] reached the wire:
    /// the publisher is done for good.
    Complete,
    /// The connection died (transport error, bad handshake, hostile
    /// subscriber) before Eos. The session state — replay ring, epoch,
    /// totals — is intact; accept another connection and call
    /// [`Publisher::serve_connection`] again to let the subscriber
    /// resume.
    Lost(String),
}

/// A resumable publishing session over a sequence of connections (see
/// the module docs for the wire lifecycle).
///
/// # Replay ring semantics
///
/// Every event relayed to the subscriber is also pushed into a
/// byte-budgeted ring (`--resume-buffer <bytes>`) as its per-event v2
/// `Event` frame, keyed by dense per-stream sequence numbers — the
/// subscriber's resume cursor for a stream is simply how many events it
/// has delivered there, batched or not. On resume the publisher replays
/// `ring[cursor..]` per stream; cursors that fell below the retained
/// window get a [`Frame::ResumeGap`] with the exact evicted count, which
/// the subscriber books into its drops ledger (the merged view is then
/// incomplete by exactly that many events and `--live-strict` fails).
/// Watermarks, cumulative drop counts and closes are *not* ringed: they
/// are monotone or idempotent, so each new connection just re-reports
/// the current values ([`ForwardCursor::resync`]).
pub struct Publisher {
    hub: Arc<LiveHub>,
    epoch: u64,
    ring: ReplayRing,
    cursor: ForwardCursor,
    stats: PublishStats,
    wire: u32,
}

impl Publisher {
    /// Create a resumable session over `hub` with a `resume_buffer`-byte
    /// replay ring. `epoch` must be nonzero (use
    /// [`Publisher::fresh_epoch`] outside of tests): epoch 0 on the wire
    /// means "not resumable". Speaks the default wire version; see
    /// [`Publisher::with_wire`].
    pub fn new(hub: Arc<LiveHub>, epoch: u64, resume_buffer: usize) -> Publisher {
        assert!(epoch != 0, "epoch 0 means non-resumable; pick a nonzero session epoch");
        Publisher {
            hub,
            epoch,
            ring: ReplayRing::new(resume_buffer),
            cursor: ForwardCursor::default(),
            stats: PublishStats::default(),
            wire: frame::VERSION,
        }
    }

    /// Select the wire version for every connection this session serves:
    /// 3 (default) batches events, 2 emits the legacy per-event stream
    /// for v2-only subscribers. Panics on a version this build does not
    /// speak. The replay ring is version-independent, so the choice only
    /// affects the live pump's framing.
    pub fn with_wire(mut self, wire: u32) -> Publisher {
        assert!(
            frame::SUPPORTED_VERSIONS.contains(&wire),
            "publisher wire version {wire} not in {:?}",
            frame::SUPPORTED_VERSIONS
        );
        self.wire = wire;
        self
    }

    /// A fresh, effectively unique nonzero session epoch (wall-clock
    /// nanoseconds mixed with the process id). Two session *instances*
    /// never share an epoch in practice, which is all resumption needs:
    /// a subscriber reconnecting to a restarted publisher must see a
    /// different epoch and know its cursors are meaningless.
    pub fn fresh_epoch() -> u64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        (nanos ^ ((std::process::id() as u64) << 48)) | 1
    }

    /// The session epoch advertised in every Hello.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Cumulative wire statistics across every connection served so far.
    pub fn stats(&self) -> PublishStats {
        self.stats.clone()
    }

    /// Drain whatever the hub holds *right now* into the replay ring,
    /// without a connection. A resumable serve loop calls this while no
    /// subscriber is attached, so a mid-run outage consumes ring budget
    /// instead of filling the hub's bounded channels (which would make
    /// the consumer drop-and-count — loss that resumption exists to
    /// avoid). Watermark/drop/close deltas need no recording: every new
    /// connection re-reports current state via
    /// [`ForwardCursor::resync`].
    pub fn drain_to_ring(&mut self) {
        while let Some(batch) = self.hub.try_forward_batch(&mut self.cursor) {
            for (idx, msg) in batch.events {
                self.ring.push(idx, encode_event(idx, msg));
            }
        }
        self.sync_ring_telemetry();
    }

    /// Mirror the ring's occupancy and lifetime evictions into the
    /// registry (occupancy is a gauge — it shrinks on eviction).
    fn sync_ring_telemetry(&self) {
        let reg = self.hub.telemetry();
        reg.ring_bytes.set(self.ring.total as u64);
        reg.ring_evicted_events.store_max(self.ring.evicted);
    }

    /// Serve one subscriber connection: handshake (preamble, Hello with
    /// this session's epoch, then the subscriber's [`Frame::Resume`]),
    /// replay past its cursors, resync state, pump live batches, and
    /// finish with [`Frame::Eos`] once the hub drains.
    ///
    /// Returns [`ServeOutcome::Lost`] on any error — the session
    /// survives, call again with the next accepted connection. A
    /// disconnect can race the final Eos; a subscriber that missed it
    /// reconnects and this method re-runs the (now trivial) pump to a
    /// clean Eos again.
    pub fn serve_connection<S: Read + Write>(&mut self, mut conn: S) -> ServeOutcome {
        self.stats.connections = self.stats.connections.saturating_add(1);
        match self.serve_inner(&mut conn) {
            Ok(()) => ServeOutcome::Complete,
            Err(e) => ServeOutcome::Lost(e.to_string()),
        }
    }

    fn serve_inner<S: Read + Write>(&mut self, conn: &mut S) -> io::Result<()> {
        // Handshake. The Hello goes out unbuffered so the subscriber can
        // answer; the streaming phase below writes whole rounds.
        let announced = self.hub.stats().channels;
        let mut head = Vec::with_capacity(256);
        frame::write_preamble_version(&mut head, self.wire)?;
        frame::encode(
            &Frame::Hello {
                hostname: self.hub.hostname().to_string(),
                metadata: generate_metadata(&[]),
                streams: announced as u32,
                epoch: self.epoch,
            },
            &mut head,
        );
        conn.write_all(&head)?;
        conn.flush()?;
        self.stats.bytes = self.stats.bytes.saturating_add(head.len() as u64);
        self.stats.frames = self.stats.frames.saturating_add(1);
        self.hub.telemetry().publish_rounds.inc(); // the handshake round
        self.stats.sync_telemetry(self.hub.telemetry());

        // The one subscriber→publisher frame: where to resume from.
        let Frame::Resume { epoch, cursors } = frame::read_frame(conn)? else {
            return Err(FrameError::Malformed("expected Resume after Hello").into());
        };
        if epoch != self.epoch {
            return Err(FrameError::Malformed("Resume epoch does not match this session").into());
        }

        // Replay is always per-event v2 frames straight from the ring —
        // valid on either wire version, cursors count events.
        let replay = self.ring.replay(&cursors, conn)?;
        self.stats.replayed = self.stats.replayed.saturating_add(replay.replayed);
        self.stats.gaps = self.stats.gaps.saturating_add(replay.gaps);
        self.stats.bytes = self.stats.bytes.saturating_add(replay.bytes);
        self.stats.frames = self
            .stats
            .frames
            .saturating_add(replay.replayed)
            .saturating_add(replay.gap_frames);
        self.stats.sync_telemetry(self.hub.telemetry());
        conn.flush()?;

        // Re-report current watermarks/drops/closes from scratch: all
        // monotone or idempotent on the subscriber, so a fresh delta
        // baseline resynchronizes everything that is not an event. The
        // batch dictionary is per-connection state on both ends, so it
        // starts empty here too.
        self.cursor.resync(announced);
        let mut enc = EventEncoder::new(self.wire);
        while let Some(batch) = self.hub.next_forward_batch(&mut self.cursor) {
            let round = EncodedRound::encode(&mut self.stats, &mut enc, batch, true);
            // Write the round, then ring EVERY popped event — even when
            // the wire just died mid-round: popped events exist nowhere
            // else, and the resuming subscriber's cursor decides which
            // ones it actually got.
            let wrote = round.write(conn);
            for (idx, buf) in round.ring {
                self.ring.push(idx, buf);
            }
            self.sync_ring_telemetry();
            match wrote {
                Ok(n) => self.stats.bytes = self.stats.bytes.saturating_add(n),
                Err(e) => return Err(e),
            }
            conn.flush()?;
            self.hub.telemetry().publish_rounds.inc();
            self.stats.sync_telemetry(self.hub.telemetry());
        }

        let totals = self.hub.stats();
        let eos = encode_frame(&Frame::Eos { received: totals.received, dropped: totals.dropped });
        conn.write_all(&eos)?;
        conn.flush()?;
        self.stats.bytes = self.stats.bytes.saturating_add(eos.len() as u64);
        self.stats.frames = self.stats.frames.saturating_add(1);
        self.stats.sync_telemetry(self.hub.telemetry());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Fault-injection wrapper for reconnect testing (`iprof serve
/// --kill-after <bytes>` and the CI reconnect-smoke job): reads pass
/// through untouched; writes fail with `BrokenPipe` once `budget` bytes
/// have gone through — from the subscriber's side the publisher dies
/// mid-stream, possibly mid-frame. Dropping the wrapper drops the inner
/// connection, so a TCP peer observes EOF. (Vectored writes funnel
/// through the same budget: the default `write_vectored` forwards to
/// `write`.)
pub struct KillAfter<S> {
    inner: S,
    remaining: usize,
}

impl<S> KillAfter<S> {
    /// Fail every write after `budget` bytes have been written.
    pub fn new(inner: S, budget: usize) -> KillAfter<S> {
        KillAfter { inner, remaining: budget }
    }
}

impl<S: Read> Read for KillAfter<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.inner.read(buf)
    }
}

impl<S: Write> Write for KillAfter<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.remaining == 0 {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected connection kill (--kill-after)",
            ));
        }
        let n = buf.len().min(self.remaining);
        let written = self.inner.write(&buf[..n])?;
        self.remaining -= written;
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::EventMsg;
    use crate::tracer::btf::DecodedClass;
    use std::sync::Arc;

    fn msg(ts: u64) -> EventMsg {
        EventMsg {
            ts,
            rank: 0,
            tid: 0,
            hostname: Arc::from("pubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    /// Every event timestamp in wire order, per-event and batched frames
    /// alike (one decoder dictionary per call = per connection).
    fn event_ts_of(wire: &[u8]) -> Vec<u64> {
        let mut r = wire;
        frame::read_preamble(&mut r).unwrap();
        let mut dict = frame::BatchDict::new();
        let mut ts_seen = Vec::new();
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Event { event, .. } => ts_seen.push(event.ts),
                Frame::EventBatch { events, .. } => {
                    for ev in events {
                        dict.resolve(ev.key).unwrap();
                        ts_seen.push(ev.ts);
                    }
                }
                Frame::Eos { .. } => return ts_seen,
                _ => {}
            }
        }
    }

    #[test]
    fn publish_emits_preamble_hello_events_and_eos() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.bytes as usize, wire.len());
        assert!(stats.batches >= 1, "v3 default coalesces events into batches");

        let mut r = &wire[..];
        assert_eq!(frame::read_preamble(&mut r).unwrap(), 3, "default wire is v3");
        let mut frames = Vec::new();
        // read until Eos (the protocol guarantees it terminates the stream)
        loop {
            let f = frame::read_frame(&mut r).unwrap();
            let done = matches!(f, Frame::Eos { .. });
            frames.push(f);
            if done {
                break;
            }
        }
        assert!(
            matches!(frames[0], Frame::Hello { epoch: 0, .. }),
            "one-shot publish advertises a non-resumable session (epoch 0)"
        );
        assert_eq!(event_ts_of(&wire), vec![1, 2], "per-stream event order is preserved");
        assert!(frames.iter().any(|f| matches!(f, Frame::Close { stream: 0 })));
        assert!(matches!(frames.last(), Some(Frame::Eos { received: 2, dropped: 0 })));
        assert!(r.is_empty(), "Eos is the final frame");
    }

    #[test]
    fn publish_with_wire2_emits_the_legacy_per_event_stream() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish_with(&hub, &mut wire, 2).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.batches, 0, "a v2 wire never batches");
        assert_eq!(stats.bytes as usize, wire.len());
        let mut r = &wire[..];
        assert_eq!(frame::read_preamble(&mut r).unwrap(), 2, "preamble announces the fallback");
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::EventBatch { .. } => panic!("EventBatch on a v2 wire"),
                Frame::Eos { .. } => break,
                _ => {}
            }
        }
        assert_eq!(event_ts_of(&wire), vec![1, 2]);
    }

    #[test]
    fn v3_batches_split_on_stream_change_and_share_one_dictionary() {
        let hub = LiveHub::new("pubtest", 64, false);
        hub.ensure_channels(2);
        // same (rank, tid, class) everywhere: the first batch defines the
        // triple, every later event refs it — across batch boundaries
        hub.push_batch(0, (0..10).map(msg).collect());
        hub.push_batch(1, (10..14).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 14);
        assert_eq!(stats.batches, 2, "one batch per consecutive same-stream run");
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut defs = 0;
        let mut refs = 0;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::EventBatch { events, .. } => {
                    for ev in &events {
                        match ev.key {
                            frame::BatchKey::Def { .. } => defs += 1,
                            frame::BatchKey::Ref(0) => refs += 1,
                            frame::BatchKey::Ref(_) => panic!("one triple, one index"),
                        }
                    }
                }
                Frame::Eos { .. } => break,
                _ => {}
            }
        }
        assert_eq!((defs, refs), (1, 13), "dictionary is connection state, not batch state");
    }

    #[test]
    fn publish_relays_drop_counts() {
        let hub = LiveHub::new("pubtest", 2, false);
        hub.ensure_channels(1);
        // depth 2: 3 of 5 messages drop at the hub
        hub.push_batch(0, (0..5).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut saw_drops = None;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Drops { stream: 0, dropped } => saw_drops = Some(dropped),
                Frame::Eos { received, dropped } => {
                    assert_eq!(received, 2);
                    assert_eq!(dropped, 3);
                    break;
                }
                _ => {}
            }
        }
        assert_eq!(saw_drops, Some(3), "per-stream cumulative drop count is relayed");
    }

    /// Encode one fake event frame of a known payload size.
    fn fake_event_frame(stream: u32, ts: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        frame::encode(
            &Frame::Event {
                stream,
                event: WireEvent { ts, rank: 0, tid: 0, class_id: 0, fields: vec![] },
            },
            &mut buf,
        );
        buf
    }

    #[test]
    fn replay_ring_replays_exactly_past_the_cursor() {
        let mut ring = ReplayRing::new(1 << 20);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        ring.push(1, fake_event_frame(1, 100));
        // cursor [2, 0]: replay stream 0 events 2..5 and all of stream 1
        let mut out = Vec::new();
        let s = ring.replay(&[2], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (4, 0, 0));
        assert_eq!(s.bytes as usize, out.len());
        let mut ts_seen = Vec::new();
        let mut off = 0;
        while off < out.len() {
            let (f, n) = frame::decode(&out[off..]).unwrap().unwrap();
            let Frame::Event { event, .. } = f else { panic!("only events replay") };
            ts_seen.push(event.ts);
            off += n;
        }
        assert_eq!(ts_seen, vec![2, 3, 4, 100]);
        // a cursor claiming more than was ever relayed is a protocol error
        assert!(ring.replay(&[9], &mut Vec::new()).is_err());
    }

    #[test]
    fn replay_ring_evicts_oldest_first_and_reports_gaps() {
        let one = fake_event_frame(0, 0).len();
        // budget for exactly 3 frames: pushing 5 evicts the oldest 2
        let mut ring = ReplayRing::new(3 * one);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        assert_eq!(ring.streams[0].start_seq, 2);
        assert_eq!(ring.streams[0].end_seq, 5);
        // a fresh cursor (0) fell below the window: gap of 2, then replay 3
        let mut out = Vec::new();
        let s = ring.replay(&[0], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (3, 2, 1));
        let (f, n) = frame::decode(&out).unwrap().unwrap();
        assert_eq!(
            f,
            Frame::ResumeGap { stream: 0, missed: 2 },
            "the gap precedes the replayed events"
        );
        let (f, _) = frame::decode(&out[n..]).unwrap().unwrap();
        let Frame::Event { event, .. } = f else { panic!("replay follows the gap") };
        assert_eq!(event.ts, 2, "replay starts at the oldest retained event");
        // a cursor inside the window replays gap-free
        let s = ring.replay(&[4], &mut Vec::new()).unwrap();
        assert_eq!((s.replayed, s.gaps), (1, 0));
    }

    #[test]
    fn write_all_vectored_advances_through_partial_and_single_buffer_writes() {
        // KillAfter's write ignores write_vectored batching (default
        // forwarding) and truncates at its budget — both paths the
        // helper must survive by re-slicing and continuing
        let mut sink = Vec::new();
        let bufs: Vec<Vec<u8>> = vec![vec![1; 5], vec![], vec![2; 7], vec![3; 3]];
        let slices: Vec<&[u8]> = bufs.iter().map(Vec::as_slice).collect();
        let n = write_all_vectored(&mut KillAfter::new(&mut sink, 1 << 20), &slices).unwrap();
        assert_eq!(n, 15);
        let mut expect = Vec::new();
        for b in &bufs {
            expect.extend_from_slice(b);
        }
        assert_eq!(sink, expect, "all bytes, in order, empties skipped");
        // and a mid-buffer failure surfaces as the error it is
        let mut sink = Vec::new();
        let err =
            write_all_vectored(&mut KillAfter::new(&mut sink, 6), &slices).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(sink.len(), 6, "exactly the budget reached the wire");
    }

    #[test]
    fn kill_after_passes_then_breaks_writes_mid_buffer() {
        let mut sink = Vec::new();
        let mut conn = KillAfter::new(&mut sink, 10);
        assert_eq!(conn.write(&[0u8; 8]).unwrap(), 8);
        // partial write up to the budget, then hard failure
        assert_eq!(conn.write(&[1u8; 8]).unwrap(), 2);
        let err = conn.write(&[2u8; 8]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(sink.len(), 10, "exactly the budget reached the wire");
    }

    #[test]
    fn fresh_epochs_are_nonzero() {
        assert_ne!(Publisher::fresh_epoch() & 1, 0, "low bit forced: never zero");
    }
}
