//! The publishing end of a remote-live connection (`iprof serve`).
//!
//! [`publish`] is the `lttng-relayd` analogue collapsed into the traced
//! process: it drains a [`LiveHub`]'s per-stream channels through
//! [`LiveHub::next_forward_batch`] and relays everything — events,
//! watermark beacons, drop counts, closes — as THRL frames over any
//! reliable byte stream, finishing with a clean [`Frame::Eos`]. It is
//! the one-shot, non-resumable path: Hello advertises epoch 0 and a
//! dropped connection ends the relay for good.
//!
//! [`Publisher`] is the resumable flavor (`iprof serve --resume-buffer`):
//! it owns a session **epoch** and a byte-budgeted [replay ring] of the
//! event frames it has relayed, and serves a *sequence* of connections
//! over the same session. Each connection handshakes
//! `Hello(epoch) → Resume(epoch, cursors)`, replays every ringed event
//! past the subscriber's per-stream cursors (answering
//! [`Frame::ResumeGap`] where the ring already evicted them), resyncs
//! watermark/drop/close state, and then pumps live batches until the
//! next disconnect or the final [`Frame::Eos`]:
//!
//! ```text
//!            ┌───────────── one session (epoch E) ──────────────┐
//! subscriber │ conn 1            conn 2                conn 3   │
//!   ────────►│ Hello(E)          Hello(E)              Hello(E) │
//!   Resume ─►│ (E, [])           (E, cursors)          ...      │
//!   ◄──────  │ events...  ✂      ResumeGap? + replay + events...│──► Eos
//!            └──────────────────────────────────────────────────┘
//!                    ✂ = transport died; ring keeps the tail
//! ```
//!
//! The publisher inherits the hub's backpressure contract end to end: it
//! never pushes back on the tracing consumer. If the transport stalls
//! (slow subscriber, slow network), the hub's bounded channels fill and
//! the consumer's try-push **drops and counts**; the loss is then
//! reported to the subscriber through [`Frame::Drops`] / [`Frame::Eos`],
//! so both ends always agree on completeness. The traced application
//! never waits on a socket — and never waits on a *vanished* subscriber
//! either: between connections the hub keeps draining into the ring
//! exactly as fast as before.
//!
//! [replay ring]: Publisher#replay-ring-semantics

use super::frame::{self, Frame, FrameError, WireEvent};
use crate::live::{ForwardCursor, LiveHub};
use crate::tracer::btf::generate_metadata;
use std::collections::VecDeque;
use std::io::{self, BufWriter, Read, Write};
use std::sync::Arc;

/// What one [`publish`] call (or one whole [`Publisher`] session)
/// relayed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PublishStats {
    /// Frames written (preamble excluded).
    pub frames: u64,
    /// Event frames among them (replays excluded).
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Bytes written, preambles included.
    pub bytes: u64,
    /// Subscriber connections served (always 1 for [`publish`]).
    pub connections: u64,
    /// Event frames re-sent from the replay ring on resume.
    pub replayed: u64,
    /// Events a resuming subscriber asked for that the ring had already
    /// evicted (the sum of all [`Frame::ResumeGap`] `missed` counts) —
    /// each one is an event permanently absent from the remote view.
    pub gaps: u64,
}

/// Encode one hub message as its complete wire `Event` frame — the ONE
/// place an [`EventMsg`](crate::analysis::EventMsg) becomes bytes, so
/// the one-shot, offline-drain and live-resumable paths can never
/// encode differently (ring replay byte-identity depends on that).
fn encode_event(stream: usize, msg: crate::analysis::EventMsg) -> Vec<u8> {
    let f = Frame::Event {
        stream: stream as u32,
        event: WireEvent {
            ts: msg.ts,
            rank: msg.rank,
            tid: msg.tid,
            class_id: msg.class.id,
            fields: msg.fields,
        },
    };
    let mut buf = Vec::with_capacity(64);
    frame::encode(&f, &mut buf);
    buf
}

/// Write one frame and account it in `stats` (bytes + frame count).
fn tracked_write(stats: &mut PublishStats, w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let n = frame::write_frame(w, frame)?;
    stats.bytes += n as u64;
    stats.frames += 1;
    Ok(())
}

/// Publish `hub` over `conn` until the hub seals and drains: preamble,
/// then [`Frame::Hello`] carrying the hostname and the full BTF metadata
/// text (the subscriber's class table), then forward batches as they
/// appear, then [`Frame::Eos`] with the hub's final received/dropped
/// totals.
///
/// Blocks until end of stream; run it on its own thread next to the
/// workload (see [`crate::coordinator::run_serve`]). Returns an error as
/// soon as the transport fails — the traced session is unaffected, the
/// hub just stops being drained and its channels degrade to
/// drop-and-count.
pub fn publish<W: Write>(hub: &LiveHub, conn: W) -> io::Result<PublishStats> {
    let mut w = BufWriter::new(conn);
    let mut stats = PublishStats { connections: 1, ..Default::default() };
    frame::write_preamble(&mut w)?;
    stats.bytes += 8;

    let hello = Frame::Hello {
        hostname: hub.hostname().to_string(),
        // The same registry-derived metadata a post-mortem `collect`
        // writes: the subscriber decodes class ids through the identical
        // descriptor path.
        metadata: generate_metadata(&[]),
        streams: hub.stats().channels as u32,
        // epoch 0 = not resumable: the subscriber must not send Resume,
        // and a dropped connection is a permanent end of feed
        epoch: 0,
    };
    stats.bytes += frame::write_frame(&mut w, &hello)? as u64;
    stats.frames += 1;
    w.flush()?;

    let mut cursor = ForwardCursor::default();
    while let Some(batch) = hub.next_forward_batch(&mut cursor) {
        if let Some(count) = batch.grown_to {
            stats.bytes += frame::write_frame(&mut w, &Frame::Streams { count: count as u32 })? as u64;
            stats.frames += 1;
        }
        for (idx, msg) in batch.events {
            let buf = encode_event(idx, msg);
            w.write_all(&buf)?;
            stats.bytes += buf.len() as u64;
            stats.frames += 1;
            stats.events += 1;
        }
        for (idx, watermark) in batch.beacons {
            let f = Frame::Beacon { stream: idx as u32, watermark };
            stats.bytes += frame::write_frame(&mut w, &f)? as u64;
            stats.frames += 1;
            stats.beacons += 1;
        }
        for (idx, dropped) in batch.drops {
            let f = Frame::Drops { stream: idx as u32, dropped };
            stats.bytes += frame::write_frame(&mut w, &f)? as u64;
            stats.frames += 1;
        }
        for idx in batch.closed {
            stats.bytes += frame::write_frame(&mut w, &Frame::Close { stream: idx as u32 })? as u64;
            stats.frames += 1;
        }
        // One flush per batch: frames reach the subscriber with drain-round
        // granularity (milliseconds), not buffer-fill granularity.
        w.flush()?;
    }

    let totals = hub.stats();
    let eos = Frame::Eos { received: totals.received, dropped: totals.dropped };
    stats.bytes += frame::write_frame(&mut w, &eos)? as u64;
    stats.frames += 1;
    w.flush()?;
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Replay ring: the bounded memory a resumable session keeps per stream
// ---------------------------------------------------------------------------

/// Per-stream retained window. `start_seq..end_seq` are the sequence
/// numbers of the encoded event frames currently held: `end_seq` counts
/// every event ever relayed on the stream, `start_seq` trails it by the
/// entries not yet evicted (`end_seq - start_seq == entries.len()`
/// always).
#[derive(Default)]
struct StreamRing {
    start_seq: u64,
    end_seq: u64,
    entries: VecDeque<Vec<u8>>,
}

/// What one [`ReplayRing::replay`] wrote.
#[derive(Debug, Default, PartialEq, Eq)]
struct ReplaySummary {
    /// Event frames re-sent.
    replayed: u64,
    /// Events irrecoverably lost (sum of all `ResumeGap.missed`).
    gaps: u64,
    /// `ResumeGap` frames written (streams with a gap).
    gap_frames: u64,
    /// Total bytes written.
    bytes: u64,
}

/// Byte-budgeted replay storage for a resumable session: every event
/// frame relayed to the subscriber is retained until the total retained
/// size exceeds the budget, then the globally oldest entries are evicted
/// first. Sequence numbers are per stream and *dense* — a subscriber's
/// cursor is simply its count of delivered events on that stream.
struct ReplayRing {
    streams: Vec<StreamRing>,
    /// Streams in global push order: per-stream queues are FIFO, so the
    /// front of this queue always names the stream holding the globally
    /// oldest retained entry — O(1) eviction instead of an O(streams)
    /// scan per evicted event.
    evict_order: VecDeque<u32>,
    budget: usize,
    total: usize,
}

impl ReplayRing {
    fn new(budget: usize) -> ReplayRing {
        ReplayRing {
            streams: Vec::new(),
            evict_order: VecDeque::new(),
            budget: budget.max(1),
            total: 0,
        }
    }

    fn ensure(&mut self, n: usize) {
        while self.streams.len() < n {
            self.streams.push(StreamRing::default());
        }
    }

    /// Retain one relayed event frame, evicting oldest-first (across all
    /// streams) once over budget. Eviction moves the stream's
    /// `start_seq` forward: a later resume below it is a gap.
    fn push(&mut self, stream: usize, bytes: Vec<u8>) {
        self.ensure(stream + 1);
        self.total += bytes.len();
        let s = &mut self.streams[stream];
        s.entries.push_back(bytes);
        s.end_seq += 1;
        self.evict_order.push_back(stream as u32);
        while self.total > self.budget {
            let Some(idx) = self.evict_order.pop_front() else { break };
            let s = &mut self.streams[idx as usize];
            let evicted = s.entries.pop_front().expect("evict queue tracks live entries 1:1");
            self.total -= evicted.len();
            s.start_seq += 1;
        }
    }

    /// Replay everything past the subscriber's per-stream `cursors` into
    /// `w`, stream by stream: a [`Frame::ResumeGap`] for any stream
    /// whose cursor fell below the retained window, immediately followed
    /// by that stream's retained event frames in original order (the
    /// `stream-replay` production in `docs/PROTOCOL.md`).
    fn replay<W: Write>(&self, cursors: &[u64], w: &mut W) -> io::Result<ReplaySummary> {
        // cursors beyond the streams we ever relayed on can only be 0
        for (i, &c) in cursors.iter().enumerate() {
            let sent = self.streams.get(i).map(|s| s.end_seq).unwrap_or(0);
            if c > sent {
                return Err(FrameError::Malformed("resume cursor beyond relayed events").into());
            }
        }
        let mut out = ReplaySummary::default();
        for (i, s) in self.streams.iter().enumerate() {
            let c = cursors.get(i).copied().unwrap_or(0);
            if c < s.start_seq {
                let missed = s.start_seq - c;
                out.bytes +=
                    frame::write_frame(w, &Frame::ResumeGap { stream: i as u32, missed })? as u64;
                out.gaps += missed;
                out.gap_frames += 1;
            }
            let skip = c.saturating_sub(s.start_seq) as usize;
            for e in s.entries.iter().skip(skip) {
                w.write_all(e)?;
                out.bytes += e.len() as u64;
                out.replayed += 1;
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Resumable publisher
// ---------------------------------------------------------------------------

/// How one subscriber connection ended, from the publisher's side.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeOutcome {
    /// The session fully drained and [`Frame::Eos`] reached the wire:
    /// the publisher is done for good.
    Complete,
    /// The connection died (transport error, bad handshake, hostile
    /// subscriber) before Eos. The session state — replay ring, epoch,
    /// totals — is intact; accept another connection and call
    /// [`Publisher::serve_connection`] again to let the subscriber
    /// resume.
    Lost(String),
}

/// A resumable publishing session over a sequence of connections (see
/// the module docs for the wire lifecycle).
///
/// # Replay ring semantics
///
/// Every event frame relayed to the subscriber is also pushed into a
/// byte-budgeted ring (`--resume-buffer <bytes>`), keyed by dense
/// per-stream sequence numbers — the subscriber's resume cursor for a
/// stream is simply how many events it has delivered there. On resume
/// the publisher replays `ring[cursor..]` per stream; cursors that fell
/// below the retained window get a [`Frame::ResumeGap`] with the exact
/// evicted count, which the subscriber books into its drops ledger (the
/// merged view is then incomplete by exactly that many events and
/// `--live-strict` fails). Watermarks, cumulative drop counts and closes
/// are *not* ringed: they are monotone or idempotent, so each new
/// connection just re-reports the current values
/// ([`ForwardCursor::resync`]).
pub struct Publisher {
    hub: Arc<LiveHub>,
    epoch: u64,
    ring: ReplayRing,
    cursor: ForwardCursor,
    stats: PublishStats,
}

impl Publisher {
    /// Create a resumable session over `hub` with a `resume_buffer`-byte
    /// replay ring. `epoch` must be nonzero (use
    /// [`Publisher::fresh_epoch`] outside of tests): epoch 0 on the wire
    /// means "not resumable".
    pub fn new(hub: Arc<LiveHub>, epoch: u64, resume_buffer: usize) -> Publisher {
        assert!(epoch != 0, "epoch 0 means non-resumable; pick a nonzero session epoch");
        Publisher {
            hub,
            epoch,
            ring: ReplayRing::new(resume_buffer),
            cursor: ForwardCursor::default(),
            stats: PublishStats::default(),
        }
    }

    /// A fresh, effectively unique nonzero session epoch (wall-clock
    /// nanoseconds mixed with the process id). Two session *instances*
    /// never share an epoch in practice, which is all resumption needs:
    /// a subscriber reconnecting to a restarted publisher must see a
    /// different epoch and know its cursors are meaningless.
    pub fn fresh_epoch() -> u64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        (nanos ^ ((std::process::id() as u64) << 48)) | 1
    }

    /// The session epoch advertised in every Hello.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Cumulative wire statistics across every connection served so far.
    pub fn stats(&self) -> PublishStats {
        self.stats.clone()
    }

    /// Drain whatever the hub holds *right now* into the replay ring,
    /// without a connection. A resumable serve loop calls this while no
    /// subscriber is attached, so a mid-run outage consumes ring budget
    /// instead of filling the hub's bounded channels (which would make
    /// the consumer drop-and-count — loss that resumption exists to
    /// avoid). Watermark/drop/close deltas need no recording: every new
    /// connection re-reports current state via
    /// [`ForwardCursor::resync`].
    pub fn drain_to_ring(&mut self) {
        while let Some(batch) = self.hub.try_forward_batch(&mut self.cursor) {
            for (idx, msg) in batch.events {
                self.ring.push(idx, encode_event(idx, msg));
            }
        }
    }

    /// Serve one subscriber connection: handshake (preamble, Hello with
    /// this session's epoch, then the subscriber's [`Frame::Resume`]),
    /// replay past its cursors, resync state, pump live batches, and
    /// finish with [`Frame::Eos`] once the hub drains.
    ///
    /// Returns [`ServeOutcome::Lost`] on any error — the session
    /// survives, call again with the next accepted connection. A
    /// disconnect can race the final Eos; a subscriber that missed it
    /// reconnects and this method re-runs the (now trivial) pump to a
    /// clean Eos again.
    pub fn serve_connection<S: Read + Write>(&mut self, mut conn: S) -> ServeOutcome {
        self.stats.connections += 1;
        match self.serve_inner(&mut conn) {
            Ok(()) => ServeOutcome::Complete,
            Err(e) => ServeOutcome::Lost(e.to_string()),
        }
    }

    fn serve_inner<S: Read + Write>(&mut self, conn: &mut S) -> io::Result<()> {
        // Handshake. The Hello goes out unbuffered so the subscriber can
        // answer; the streaming phase below buffers.
        let announced = self.hub.stats().channels;
        let mut head = Vec::with_capacity(256);
        frame::write_preamble(&mut head)?;
        frame::encode(
            &Frame::Hello {
                hostname: self.hub.hostname().to_string(),
                metadata: generate_metadata(&[]),
                streams: announced as u32,
                epoch: self.epoch,
            },
            &mut head,
        );
        conn.write_all(&head)?;
        conn.flush()?;
        self.stats.bytes += head.len() as u64;
        self.stats.frames += 1;

        // The one subscriber→publisher frame: where to resume from.
        let Frame::Resume { epoch, cursors } = frame::read_frame(conn)? else {
            return Err(FrameError::Malformed("expected Resume after Hello").into());
        };
        if epoch != self.epoch {
            return Err(FrameError::Malformed("Resume epoch does not match this session").into());
        }

        let mut w = BufWriter::new(conn);
        let replay = self.ring.replay(&cursors, &mut w)?;
        self.stats.replayed += replay.replayed;
        self.stats.gaps += replay.gaps;
        self.stats.bytes += replay.bytes;
        self.stats.frames += replay.replayed + replay.gap_frames;
        w.flush()?;

        // Re-report current watermarks/drops/closes from scratch: all
        // monotone or idempotent on the subscriber, so a fresh delta
        // baseline resynchronizes everything that is not an event.
        self.cursor.resync(announced);
        while let Some(batch) = self.hub.next_forward_batch(&mut self.cursor) {
            let mut io_err: Option<io::Error> = None;
            if let Some(count) = batch.grown_to {
                let f = Frame::Streams { count: count as u32 };
                io_err = tracked_write(&mut self.stats, &mut w, &f).err();
            }
            for (idx, msg) in batch.events {
                let buf = encode_event(idx, msg);
                if io_err.is_none() {
                    match w.write_all(&buf) {
                        Ok(()) => {
                            self.stats.bytes += buf.len() as u64;
                            self.stats.frames += 1;
                            self.stats.events += 1;
                        }
                        Err(e) => io_err = Some(e),
                    }
                }
                // Ring EVERY popped event, even after the wire just died
                // mid-batch: popped events exist nowhere else, and the
                // resuming subscriber's cursor decides which ones it
                // actually got.
                self.ring.push(idx, buf);
            }
            if io_err.is_none() {
                for (idx, watermark) in batch.beacons {
                    let f = Frame::Beacon { stream: idx as u32, watermark };
                    match tracked_write(&mut self.stats, &mut w, &f) {
                        Ok(()) => self.stats.beacons += 1,
                        Err(e) => {
                            io_err = Some(e);
                            break;
                        }
                    }
                }
            }
            if io_err.is_none() {
                for (idx, dropped) in batch.drops {
                    let f = Frame::Drops { stream: idx as u32, dropped };
                    if let Err(e) = tracked_write(&mut self.stats, &mut w, &f) {
                        io_err = Some(e);
                        break;
                    }
                }
            }
            if io_err.is_none() {
                for idx in batch.closed {
                    let f = Frame::Close { stream: idx as u32 };
                    if let Err(e) = tracked_write(&mut self.stats, &mut w, &f) {
                        io_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = io_err {
                return Err(e);
            }
            w.flush()?;
        }

        let totals = self.hub.stats();
        let eos = Frame::Eos { received: totals.received, dropped: totals.dropped };
        tracked_write(&mut self.stats, &mut w, &eos)?;
        w.flush()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Fault-injection wrapper for reconnect testing (`iprof serve
/// --kill-after <bytes>` and the CI reconnect-smoke job): reads pass
/// through untouched; writes fail with `BrokenPipe` once `budget` bytes
/// have gone through — from the subscriber's side the publisher dies
/// mid-stream, possibly mid-frame. Dropping the wrapper drops the inner
/// connection, so a TCP peer observes EOF.
pub struct KillAfter<S> {
    inner: S,
    remaining: usize,
}

impl<S> KillAfter<S> {
    /// Fail every write after `budget` bytes have been written.
    pub fn new(inner: S, budget: usize) -> KillAfter<S> {
        KillAfter { inner, remaining: budget }
    }
}

impl<S: Read> Read for KillAfter<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.inner.read(buf)
    }
}

impl<S: Write> Write for KillAfter<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.remaining == 0 {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected connection kill (--kill-after)",
            ));
        }
        let n = buf.len().min(self.remaining);
        let written = self.inner.write(&buf[..n])?;
        self.remaining -= written;
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::EventMsg;
    use crate::tracer::btf::DecodedClass;
    use std::sync::Arc;

    fn msg(ts: u64) -> EventMsg {
        EventMsg {
            ts,
            rank: 0,
            tid: 0,
            hostname: Arc::from("pubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn publish_emits_preamble_hello_events_and_eos() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.bytes as usize, wire.len());

        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut frames = Vec::new();
        // read until Eos (the protocol guarantees it terminates the stream)
        loop {
            let f = frame::read_frame(&mut r).unwrap();
            let done = matches!(f, Frame::Eos { .. });
            frames.push(f);
            if done {
                break;
            }
        }
        assert!(
            matches!(frames[0], Frame::Hello { epoch: 0, .. }),
            "one-shot publish advertises a non-resumable session (epoch 0)"
        );
        let events: Vec<u64> = frames
            .iter()
            .filter_map(|f| match f {
                Frame::Event { event, .. } => Some(event.ts),
                _ => None,
            })
            .collect();
        assert_eq!(events, vec![1, 2], "per-stream event order is preserved");
        assert!(frames.iter().any(|f| matches!(f, Frame::Close { stream: 0 })));
        assert!(matches!(frames.last(), Some(Frame::Eos { received: 2, dropped: 0 })));
        assert!(r.is_empty(), "Eos is the final frame");
    }

    #[test]
    fn publish_relays_drop_counts() {
        let hub = LiveHub::new("pubtest", 2, false);
        hub.ensure_channels(1);
        // depth 2: 3 of 5 messages drop at the hub
        hub.push_batch(0, (0..5).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut saw_drops = None;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Drops { stream: 0, dropped } => saw_drops = Some(dropped),
                Frame::Eos { received, dropped } => {
                    assert_eq!(received, 2);
                    assert_eq!(dropped, 3);
                    break;
                }
                _ => {}
            }
        }
        assert_eq!(saw_drops, Some(3), "per-stream cumulative drop count is relayed");
    }

    /// Encode one fake event frame of a known payload size.
    fn fake_event_frame(stream: u32, ts: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        frame::encode(
            &Frame::Event {
                stream,
                event: WireEvent { ts, rank: 0, tid: 0, class_id: 0, fields: vec![] },
            },
            &mut buf,
        );
        buf
    }

    #[test]
    fn replay_ring_replays_exactly_past_the_cursor() {
        let mut ring = ReplayRing::new(1 << 20);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        ring.push(1, fake_event_frame(1, 100));
        // cursor [2, 0]: replay stream 0 events 2..5 and all of stream 1
        let mut out = Vec::new();
        let s = ring.replay(&[2], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (4, 0, 0));
        assert_eq!(s.bytes as usize, out.len());
        let mut ts_seen = Vec::new();
        let mut off = 0;
        while off < out.len() {
            let (f, n) = frame::decode(&out[off..]).unwrap().unwrap();
            let Frame::Event { event, .. } = f else { panic!("only events replay") };
            ts_seen.push(event.ts);
            off += n;
        }
        assert_eq!(ts_seen, vec![2, 3, 4, 100]);
        // a cursor claiming more than was ever relayed is a protocol error
        assert!(ring.replay(&[9], &mut Vec::new()).is_err());
    }

    #[test]
    fn replay_ring_evicts_oldest_first_and_reports_gaps() {
        let one = fake_event_frame(0, 0).len();
        // budget for exactly 3 frames: pushing 5 evicts the oldest 2
        let mut ring = ReplayRing::new(3 * one);
        for ts in 0..5 {
            ring.push(0, fake_event_frame(0, ts));
        }
        assert_eq!(ring.streams[0].start_seq, 2);
        assert_eq!(ring.streams[0].end_seq, 5);
        // a fresh cursor (0) fell below the window: gap of 2, then replay 3
        let mut out = Vec::new();
        let s = ring.replay(&[0], &mut out).unwrap();
        assert_eq!((s.replayed, s.gaps, s.gap_frames), (3, 2, 1));
        let (f, n) = frame::decode(&out).unwrap().unwrap();
        assert_eq!(
            f,
            Frame::ResumeGap { stream: 0, missed: 2 },
            "the gap precedes the replayed events"
        );
        let (f, _) = frame::decode(&out[n..]).unwrap().unwrap();
        let Frame::Event { event, .. } = f else { panic!("replay follows the gap") };
        assert_eq!(event.ts, 2, "replay starts at the oldest retained event");
        // a cursor inside the window replays gap-free
        let s = ring.replay(&[4], &mut Vec::new()).unwrap();
        assert_eq!((s.replayed, s.gaps), (1, 0));
    }

    #[test]
    fn kill_after_passes_then_breaks_writes_mid_buffer() {
        let mut sink = Vec::new();
        let mut conn = KillAfter::new(&mut sink, 10);
        assert_eq!(conn.write(&[0u8; 8]).unwrap(), 8);
        // partial write up to the budget, then hard failure
        assert_eq!(conn.write(&[1u8; 8]).unwrap(), 2);
        let err = conn.write(&[2u8; 8]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(sink.len(), 10, "exactly the budget reached the wire");
    }

    #[test]
    fn fresh_epochs_are_nonzero() {
        assert_ne!(Publisher::fresh_epoch() & 1, 0, "low bit forced: never zero");
    }
}
