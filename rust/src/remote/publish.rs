//! The publishing end of a remote-live connection (`iprof serve`).
//!
//! [`publish`] is the `lttng-relayd` analogue collapsed into the traced
//! process: it drains a [`LiveHub`]'s per-stream channels through
//! [`LiveHub::next_forward_batch`] and relays everything — events,
//! watermark beacons, drop counts, closes — as THRL frames over any
//! reliable byte stream, finishing with a clean [`Frame::Eos`].
//!
//! The publisher inherits the hub's backpressure contract end to end: it
//! never pushes back on the tracing consumer. If the transport stalls
//! (slow subscriber, slow network), the hub's bounded channels fill and
//! the consumer's try-push **drops and counts**; the loss is then
//! reported to the subscriber through [`Frame::Drops`] / [`Frame::Eos`],
//! so both ends always agree on completeness. The traced application
//! never waits on a socket.

use super::frame::{self, Frame, WireEvent};
use crate::live::{ForwardCursor, LiveHub};
use crate::tracer::btf::generate_metadata;
use std::io::{self, BufWriter, Write};

/// What one [`publish`] call relayed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PublishStats {
    /// Frames written (preamble excluded).
    pub frames: u64,
    /// Event frames among them.
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Bytes written, preamble included.
    pub bytes: u64,
}

/// Publish `hub` over `conn` until the hub seals and drains: preamble,
/// then [`Frame::Hello`] carrying the hostname and the full BTF metadata
/// text (the subscriber's class table), then forward batches as they
/// appear, then [`Frame::Eos`] with the hub's final received/dropped
/// totals.
///
/// Blocks until end of stream; run it on its own thread next to the
/// workload (see [`crate::coordinator::run_serve`]). Returns an error as
/// soon as the transport fails — the traced session is unaffected, the
/// hub just stops being drained and its channels degrade to
/// drop-and-count.
pub fn publish<W: Write>(hub: &LiveHub, conn: W) -> io::Result<PublishStats> {
    let mut w = BufWriter::new(conn);
    let mut stats = PublishStats::default();
    frame::write_preamble(&mut w)?;
    stats.bytes += 8;

    let hello = Frame::Hello {
        hostname: hub.hostname().to_string(),
        // The same registry-derived metadata a post-mortem `collect`
        // writes: the subscriber decodes class ids through the identical
        // descriptor path.
        metadata: generate_metadata(&[]),
        streams: hub.stats().channels as u32,
    };
    stats.bytes += frame::write_frame(&mut w, &hello)? as u64;
    stats.frames += 1;
    w.flush()?;

    let mut cursor = ForwardCursor::default();
    while let Some(batch) = hub.next_forward_batch(&mut cursor) {
        if let Some(count) = batch.grown_to {
            stats.bytes += frame::write_frame(&mut w, &Frame::Streams { count: count as u32 })? as u64;
            stats.frames += 1;
        }
        for (idx, msg) in batch.events {
            let f = Frame::Event {
                stream: idx as u32,
                event: WireEvent {
                    ts: msg.ts,
                    rank: msg.rank,
                    tid: msg.tid,
                    class_id: msg.class.id,
                    fields: msg.fields,
                },
            };
            stats.bytes += frame::write_frame(&mut w, &f)? as u64;
            stats.frames += 1;
            stats.events += 1;
        }
        for (idx, watermark) in batch.beacons {
            let f = Frame::Beacon { stream: idx as u32, watermark };
            stats.bytes += frame::write_frame(&mut w, &f)? as u64;
            stats.frames += 1;
            stats.beacons += 1;
        }
        for (idx, dropped) in batch.drops {
            let f = Frame::Drops { stream: idx as u32, dropped };
            stats.bytes += frame::write_frame(&mut w, &f)? as u64;
            stats.frames += 1;
        }
        for idx in batch.closed {
            stats.bytes += frame::write_frame(&mut w, &Frame::Close { stream: idx as u32 })? as u64;
            stats.frames += 1;
        }
        // One flush per batch: frames reach the subscriber with drain-round
        // granularity (milliseconds), not buffer-fill granularity.
        w.flush()?;
    }

    let totals = hub.stats();
    let eos = Frame::Eos { received: totals.received, dropped: totals.dropped };
    stats.bytes += frame::write_frame(&mut w, &eos)? as u64;
    stats.frames += 1;
    w.flush()?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::EventMsg;
    use crate::tracer::btf::DecodedClass;
    use std::sync::Arc;

    fn msg(ts: u64) -> EventMsg {
        EventMsg {
            ts,
            rank: 0,
            tid: 0,
            hostname: Arc::from("pubtest"),
            class: Arc::new(DecodedClass {
                id: 0,
                name: "lttng_ust_ze:zeInit_entry".into(),
                api: "ZE".into(),
                flags: "h".into(),
                fields: vec![],
            }),
            fields: vec![],
        }
    }

    #[test]
    fn publish_emits_preamble_hello_events_and_eos() {
        let hub = LiveHub::new("pubtest", 8, false);
        hub.ensure_channels(1);
        hub.push_batch(0, vec![msg(1), msg(2)]);
        hub.close_all();

        let mut wire = Vec::new();
        let stats = publish(&hub, &mut wire).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.bytes as usize, wire.len());

        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut frames = Vec::new();
        // read until Eos (the protocol guarantees it terminates the stream)
        loop {
            let f = frame::read_frame(&mut r).unwrap();
            let done = matches!(f, Frame::Eos { .. });
            frames.push(f);
            if done {
                break;
            }
        }
        assert!(matches!(frames[0], Frame::Hello { .. }));
        let events: Vec<u64> = frames
            .iter()
            .filter_map(|f| match f {
                Frame::Event { event, .. } => Some(event.ts),
                _ => None,
            })
            .collect();
        assert_eq!(events, vec![1, 2], "per-stream event order is preserved");
        assert!(frames.iter().any(|f| matches!(f, Frame::Close { stream: 0 })));
        assert!(matches!(frames.last(), Some(Frame::Eos { received: 2, dropped: 0 })));
        assert!(r.is_empty(), "Eos is the final frame");
    }

    #[test]
    fn publish_relays_drop_counts() {
        let hub = LiveHub::new("pubtest", 2, false);
        hub.ensure_channels(1);
        // depth 2: 3 of 5 messages drop at the hub
        hub.push_batch(0, (0..5).map(msg).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        let mut r = &wire[..];
        frame::read_preamble(&mut r).unwrap();
        let mut saw_drops = None;
        loop {
            match frame::read_frame(&mut r).unwrap() {
                Frame::Drops { stream: 0, dropped } => saw_drops = Some(dropped),
                Frame::Eos { received, dropped } => {
                    assert_eq!(received, 2);
                    assert_eq!(dropped, 3);
                    break;
                }
                _ => {}
            }
        }
        assert_eq!(saw_drops, Some(3), "per-stream cumulative drop count is relayed");
    }
}
