//! The THRL wire codec: versioned, length-prefixed binary frames.
//!
//! Everything on a remote-live connection after the fixed
//! [`MAGIC`]+[`VERSION`] preamble is a sequence of frames, each
//!
//! ```text
//! len:  u32 LE   — byte length of what follows (type + body)
//! type: u8       — frame discriminator
//! body: len-1 B  — type-specific payload
//! ```
//!
//! The codec is a pure function of its input: [`encode`] appends exactly
//! one frame to a buffer, [`decode`] parses exactly one frame back (or
//! reports "incomplete" so a reader can buffer), and
//! `decode(encode(f)) == f` for every representable frame — pinned by a
//! property test over randomized frames in `rust/tests/remote.rs`. No
//! clocks, no process state, no platform-dependent layout: two builds of
//! this module always agree on the bytes.
//!
//! The full grammar, field encodings and semantics (beacon contract, drop
//! accounting, EOS) are specified in `docs/PROTOCOL.md`; this module is
//! the reference implementation.

use crate::tracer::encoder::FieldValue;
use std::io::{self, Read, Write};

/// Connection preamble magic: "THRL" (THapi Remote Live).
pub const MAGIC: [u8; 4] = *b"THRL";

/// Protocol version spoken by this build. The preamble carries it; a
/// subscriber must reject any version it does not implement.
///
/// Version 2 added session resumption: [`Frame::Hello`] grew a trailing
/// session `epoch`, and the [`Frame::Resume`] / [`Frame::ResumeGap`]
/// pair lets a reconnecting subscriber continue a session from its
/// last-delivered per-stream cursors (see `docs/PROTOCOL.md` § Session
/// resumption). v2 changed the Hello layout, so v1 and v2 are mutually
/// unintelligible past the preamble — negotiation stays
/// reject-on-mismatch.
pub const VERSION: u32 = 2;

/// Every protocol version this build can speak. Version negotiation
/// ([`read_preamble`]) accepts exactly these; anything else is a
/// [`FrameError::BadVersion`]. v1 (no epochs, no resumption) is
/// deliberately absent: its Hello layout is a strict prefix of v2's and
/// decoding it under v2 rules would mis-parse, so a v2 build rejects v1
/// peers outright instead of guessing.
pub const SUPPORTED_VERSIONS: [u32; 1] = [VERSION];

/// Upper bound on `len` (type + body bytes). Frames beyond this are a
/// protocol error, never an allocation request — a corrupt or hostile
/// length prefix cannot make a reader allocate gigabytes.
pub const MAX_FRAME_LEN: usize = 16 << 20;

/// Upper bound on stream counts and stream indices a subscriber will
/// honor (one channel per traced thread; 2^20 is far beyond any real
/// session). Same rationale as [`MAX_FRAME_LEN`]: a corrupt or hostile
/// `Streams`/`Event` frame must never translate into a multi-gigabyte
/// channel-table allocation.
pub const MAX_STREAMS: u32 = 1 << 20;

// Frame type discriminators (u8 on the wire).
const T_HELLO: u8 = 0x01;
const T_STREAMS: u8 = 0x02;
const T_EVENT: u8 = 0x03;
const T_BEACON: u8 = 0x04;
const T_DROPS: u8 = 0x05;
const T_CLOSE: u8 = 0x06;
const T_EOS: u8 = 0x07;
const T_RESUME: u8 = 0x08;
const T_RESUME_GAP: u8 = 0x09;

// Field value tags inside Event frames.
const F_U64: u8 = 0;
const F_I64: u8 = 1;
const F_F64: u8 = 2;
const F_PTR: u8 = 3;
const F_STR: u8 = 4;

/// One decoded event as carried on the wire: the stream-independent parts
/// of an [`EventMsg`](crate::analysis::EventMsg). The class is referenced
/// by id — the subscriber resolves it against the class table shipped in
/// the [`Frame::Hello`] metadata, exactly how post-mortem analysis
/// resolves record ids against `metadata.btf`.
#[derive(Debug, Clone, PartialEq)]
pub struct WireEvent {
    /// Timestamp (trace-clock ns).
    pub ts: u64,
    /// Producing rank.
    pub rank: u32,
    /// Producing thread.
    pub tid: u32,
    /// Event-class id (resolved via the Hello metadata).
    pub class_id: u32,
    /// Decoded field values, self-describing (tag + value) so the codec
    /// round-trips without a class table.
    pub fields: Vec<FieldValue>,
}

/// One protocol frame. See `docs/PROTOCOL.md` for the normative grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// First frame on every connection: who is publishing and how to
    /// decode it. `metadata` is the full BTF metadata text (the stream
    /// registry's class table); `streams` is the channel count known at
    /// connect time (may grow via [`Frame::Streams`]).
    Hello {
        /// Publisher hostname (stamped on every reconstructed message).
        hostname: String,
        /// BTF metadata text: the event-class registry.
        metadata: String,
        /// Channels existing at connect time.
        streams: u32,
        /// Session epoch. `0` means the session is NOT resumable (the
        /// publisher streams immediately and never reads from the
        /// connection — the whole v1 flow). Any nonzero value
        /// identifies one session *instance*: the publisher keeps a
        /// replay ring and waits for a [`Frame::Resume`] echoing this
        /// epoch before streaming. A subscriber that reconnects and
        /// sees a *different* nonzero epoch knows the publisher
        /// restarted into a new session — its cursors are meaningless
        /// there and it must not send them.
        epoch: u64,
    },
    /// The per-stream channel set grew to `count` (late-registering
    /// threads). Idempotent; counts never shrink.
    Streams {
        /// New total channel count.
        count: u32,
    },
    /// One decoded event on channel `stream`. Per-stream frame order is
    /// the stream's event order; cross-stream order is unspecified (the
    /// subscriber re-merges).
    Event {
        /// Channel index (== session stream registration index).
        stream: u32,
        /// The event payload.
        event: WireEvent,
    },
    /// Watermark promise: every future `Event` on `stream` has
    /// `ts >= watermark`. Monotone per stream.
    Beacon {
        /// Channel index.
        stream: u32,
        /// Timestamp lower bound for all future events of this stream.
        watermark: u64,
    },
    /// Cumulative count of messages the publisher dropped on `stream`
    /// (bounded-channel backpressure). Monotone per stream; the latest
    /// value is the total.
    Drops {
        /// Channel index.
        stream: u32,
        /// Cumulative dropped-message count for this stream.
        dropped: u64,
    },
    /// No further events or beacons will ever arrive on `stream`.
    Close {
        /// Channel index.
        stream: u32,
    },
    /// Clean end of session; always the final frame. Carries the
    /// publisher's hub totals so both ends agree on completeness.
    Eos {
        /// Messages the publisher's channels accepted in total.
        received: u64,
        /// Messages the publisher's channels dropped in total.
        dropped: u64,
    },
    /// The only subscriber→publisher frame: sent once per connection to
    /// a *resumable* publisher (Hello `epoch != 0`), immediately after
    /// the subscriber validates the Hello. `cursors[i]` is the number
    /// of [`Frame::Event`]s the subscriber has fully delivered on
    /// remote stream `i` — a fresh attach sends an empty cursor list
    /// (deliver from the beginning). The publisher replays every event
    /// past each cursor from its replay ring, answering
    /// [`Frame::ResumeGap`] per stream whose cursor fell out of the
    /// ring.
    Resume {
        /// Echo of the Hello epoch (the publisher rejects mismatches).
        epoch: u64,
        /// Per-remote-stream delivered-event counts, indexed by the
        /// publisher's own stream ids. Streams beyond the list resume
        /// from 0.
        cursors: Vec<u64>,
    },
    /// Publisher→subscriber resumption verdict for one stream: `missed`
    /// events between the subscriber's cursor and the oldest event
    /// still in the replay ring were evicted and cannot be replayed.
    /// The subscriber books them into its per-origin drops ledger (the
    /// live view is incomplete by exactly `missed` events on this
    /// stream; `--live-strict` fails) and advances its cursor past the
    /// gap so later replays stay aligned.
    ResumeGap {
        /// Channel index (publisher's stream id).
        stream: u32,
        /// Events irrecoverably lost from the ring for this stream.
        missed: u64,
    },
}

/// Codec errors. `Incomplete` is not among them: [`decode`] signals a
/// partial frame with `Ok(None)` so buffering readers can distinguish
/// "need more bytes" from "stream is corrupt".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The connection preamble did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The publisher speaks a protocol version this build does not.
    BadVersion(u32),
    /// Unknown frame type discriminator.
    BadFrameType(u8),
    /// Unknown field-value tag inside an Event frame.
    BadFieldTag(u8),
    /// The length prefix exceeds [`MAX_FRAME_LEN`] (or is zero).
    BadLength(usize),
    /// A frame body ended early or carried trailing bytes.
    Malformed(&'static str),
    /// A string field was not valid UTF-8.
    BadUtf8,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad preamble magic {m:02x?} (expected THRL)"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            FrameError::BadFrameType(t) => write!(f, "unknown frame type {t:#04x}"),
            FrameError::BadFieldTag(t) => write!(f, "unknown field tag {t:#04x}"),
            FrameError::BadLength(n) => write!(f, "frame length {n} out of bounds"),
            FrameError::Malformed(what) => write!(f, "malformed frame: {what}"),
            FrameError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<FrameError> for io::Error {
    fn from(e: FrameError) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, e)
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// u16 length + UTF-8 bytes (hostnames, string fields). Strings longer
/// than 64 KiB are truncated on a char boundary — the wire stays valid
/// UTF-8 (decoding never fails), at the cost of losing the tail of such
/// a string; event string fields are capped at 4 KiB upstream, so this
/// is unreachable in practice.
fn put_str16(out: &mut Vec<u8>, s: &str) {
    let mut n = s.len().min(u16::MAX as usize);
    while n > 0 && !s.is_char_boundary(n) {
        n -= 1;
    }
    put_u16(out, n as u16);
    out.extend_from_slice(&s.as_bytes()[..n]);
}

/// u32 length + UTF-8 bytes (metadata text).
fn put_str32(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

fn put_field(out: &mut Vec<u8>, v: &FieldValue) {
    match v {
        FieldValue::U64(x) => {
            out.push(F_U64);
            put_u64(out, *x);
        }
        FieldValue::I64(x) => {
            out.push(F_I64);
            put_u64(out, *x as u64);
        }
        FieldValue::F64(x) => {
            out.push(F_F64);
            put_u64(out, x.to_bits());
        }
        FieldValue::Ptr(x) => {
            out.push(F_PTR);
            put_u64(out, *x);
        }
        FieldValue::Str(s) => {
            out.push(F_STR);
            put_str16(out, s);
        }
    }
}

/// Append one length-prefixed frame to `out`. Deterministic: equal frames
/// always produce equal bytes.
pub fn encode(frame: &Frame, out: &mut Vec<u8>) {
    let len_at = out.len();
    put_u32(out, 0); // length backpatched below
    match frame {
        Frame::Hello { hostname, metadata, streams, epoch } => {
            out.push(T_HELLO);
            put_str16(out, hostname);
            put_str32(out, metadata);
            put_u32(out, *streams);
            put_u64(out, *epoch);
        }
        Frame::Streams { count } => {
            out.push(T_STREAMS);
            put_u32(out, *count);
        }
        Frame::Event { stream, event } => {
            out.push(T_EVENT);
            put_u32(out, *stream);
            put_u64(out, event.ts);
            put_u32(out, event.rank);
            put_u32(out, event.tid);
            put_u32(out, event.class_id);
            let nfields = event.fields.len().min(u16::MAX as usize);
            put_u16(out, nfields as u16);
            for f in &event.fields[..nfields] {
                put_field(out, f);
            }
        }
        Frame::Beacon { stream, watermark } => {
            out.push(T_BEACON);
            put_u32(out, *stream);
            put_u64(out, *watermark);
        }
        Frame::Drops { stream, dropped } => {
            out.push(T_DROPS);
            put_u32(out, *stream);
            put_u64(out, *dropped);
        }
        Frame::Close { stream } => {
            out.push(T_CLOSE);
            put_u32(out, *stream);
        }
        Frame::Eos { received, dropped } => {
            out.push(T_EOS);
            put_u64(out, *received);
            put_u64(out, *dropped);
        }
        Frame::Resume { epoch, cursors } => {
            out.push(T_RESUME);
            put_u64(out, *epoch);
            let n = cursors.len().min(MAX_STREAMS as usize);
            put_u32(out, n as u32);
            for c in &cursors[..n] {
                put_u64(out, *c);
            }
        }
        Frame::ResumeGap { stream, missed } => {
            out.push(T_RESUME_GAP);
            put_u32(out, *stream);
            put_u64(out, *missed);
        }
    }
    let body_len = (out.len() - len_at - 4) as u32;
    out[len_at..len_at + 4].copy_from_slice(&body_len.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Little bounds-checked reader over a frame body.
struct Body<'a> {
    buf: &'a [u8],
}

impl<'a> Body<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.buf.len() < n {
            return Err(FrameError::Malformed("body ended early"));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str16(&mut self) -> Result<String, FrameError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| FrameError::BadUtf8)
    }

    fn str32(&mut self) -> Result<String, FrameError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| FrameError::BadUtf8)
    }

    fn field(&mut self) -> Result<FieldValue, FrameError> {
        let tag = self.u8()?;
        Ok(match tag {
            F_U64 => FieldValue::U64(self.u64()?),
            F_I64 => FieldValue::I64(self.u64()? as i64),
            F_F64 => FieldValue::F64(f64::from_bits(self.u64()?)),
            F_PTR => FieldValue::Ptr(self.u64()?),
            F_STR => FieldValue::Str(self.str16()?),
            other => return Err(FrameError::BadFieldTag(other)),
        })
    }

    fn finish(self) -> Result<(), FrameError> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(FrameError::Malformed("trailing bytes in body"))
        }
    }
}

/// Decode one frame from the front of `buf`.
///
/// Returns `Ok(Some((frame, consumed)))` for a complete frame,
/// `Ok(None)` when `buf` holds only a prefix of a frame (read more and
/// retry), and `Err` for protocol violations. `consumed` covers the
/// length prefix too, so `&buf[consumed..]` starts the next frame.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(FrameError::BadLength(len));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let frame = decode_body(&buf[4..4 + len])?;
    Ok(Some((frame, 4 + len)))
}

/// Decode a frame body (everything after the length prefix). The body
/// must contain exactly one frame: early EOF and trailing bytes are both
/// errors.
pub fn decode_body(body: &[u8]) -> Result<Frame, FrameError> {
    let mut b = Body { buf: body };
    let ty = b.u8()?;
    let frame = match ty {
        T_HELLO => Frame::Hello {
            hostname: b.str16()?,
            metadata: b.str32()?,
            streams: b.u32()?,
            epoch: b.u64()?,
        },
        T_STREAMS => Frame::Streams { count: b.u32()? },
        T_EVENT => {
            let stream = b.u32()?;
            let ts = b.u64()?;
            let rank = b.u32()?;
            let tid = b.u32()?;
            let class_id = b.u32()?;
            let nfields = b.u16()? as usize;
            let mut fields = Vec::with_capacity(nfields.min(256));
            for _ in 0..nfields {
                fields.push(b.field()?);
            }
            Frame::Event { stream, event: WireEvent { ts, rank, tid, class_id, fields } }
        }
        T_BEACON => Frame::Beacon { stream: b.u32()?, watermark: b.u64()? },
        T_DROPS => Frame::Drops { stream: b.u32()?, dropped: b.u64()? },
        T_CLOSE => Frame::Close { stream: b.u32()? },
        T_EOS => Frame::Eos { received: b.u64()?, dropped: b.u64()? },
        T_RESUME => {
            let epoch = b.u64()?;
            let n = b.u32()?;
            if n > MAX_STREAMS {
                // same rationale as MAX_STREAMS everywhere: a corrupt
                // count must never become a multi-GB cursor table
                return Err(FrameError::Malformed("resume cursor count exceeds MAX_STREAMS"));
            }
            let n = n as usize;
            let mut cursors = Vec::with_capacity(n.min(256));
            for _ in 0..n {
                cursors.push(b.u64()?);
            }
            Frame::Resume { epoch, cursors }
        }
        T_RESUME_GAP => Frame::ResumeGap { stream: b.u32()?, missed: b.u64()? },
        other => return Err(FrameError::BadFrameType(other)),
    };
    b.finish()?;
    Ok(frame)
}

// ---------------------------------------------------------------------------
// Blocking I/O helpers
// ---------------------------------------------------------------------------

/// Write the connection preamble (magic + version). The publisher sends
/// this once, immediately after accepting the subscriber.
pub fn write_preamble(w: &mut impl Write) -> io::Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())
}

/// Read and verify the connection preamble, returning the negotiated
/// version. Errors on wrong magic or any version outside
/// [`SUPPORTED_VERSIONS`] — the entire version negotiation is
/// reject-on-mismatch (see `docs/PROTOCOL.md` § Versioning); in
/// particular v1 preambles are rejected here, before any frame is read,
/// because the v1 Hello layout would mis-parse under v2 rules.
pub fn read_preamble(r: &mut impl Read) -> io::Result<u32> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic).into());
    }
    let mut v = [0u8; 4];
    r.read_exact(&mut v)?;
    let version = u32::from_le_bytes(v);
    if !SUPPORTED_VERSIONS.contains(&version) {
        return Err(FrameError::BadVersion(version).into());
    }
    Ok(version)
}

/// Encode and write one frame; returns the bytes written.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<usize> {
    let mut buf = Vec::with_capacity(64);
    encode(frame, &mut buf);
    w.write_all(&buf)?;
    Ok(buf.len())
}

/// Read exactly one frame. An EOF at a frame boundary is reported as
/// `UnexpectedEof` — the protocol ends with [`Frame::Eos`], never by the
/// transport closing, so any EOF here is abnormal.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut lenbuf = [0u8; 4];
    r.read_exact(&mut lenbuf)?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(FrameError::BadLength(len).into());
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(decode_body(&body)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        encode(&f, &mut buf);
        let (back, consumed) = decode(&buf).unwrap().unwrap();
        assert_eq!(back, f);
        assert_eq!(consumed, buf.len());
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        roundtrip(Frame::Hello {
            hostname: "node0".into(),
            metadata: "btf_version: 1\nevents:\n".into(),
            streams: 3,
            epoch: 0x0123_4567_89ab_cdef,
        });
        roundtrip(Frame::Streams { count: 7 });
        roundtrip(Frame::Event {
            stream: 2,
            event: WireEvent {
                ts: u64::MAX,
                rank: 1,
                tid: 42,
                class_id: 9,
                fields: vec![
                    FieldValue::U64(7),
                    FieldValue::I64(-3),
                    FieldValue::F64(2.5),
                    FieldValue::Ptr(0xff00_0000_dead_beef),
                    FieldValue::Str("kernel".into()),
                ],
            },
        });
        roundtrip(Frame::Beacon { stream: 0, watermark: 123_456 });
        roundtrip(Frame::Drops { stream: 5, dropped: 99 });
        roundtrip(Frame::Close { stream: 1 });
        roundtrip(Frame::Eos { received: 1000, dropped: 4 });
        roundtrip(Frame::Resume { epoch: 0x0123_4567_89ab_cdef, cursors: vec![7, 0, 42] });
        roundtrip(Frame::Resume { epoch: 1, cursors: vec![] });
        roundtrip(Frame::ResumeGap { stream: 2, missed: 17 });
    }

    #[test]
    fn hostile_resume_cursor_counts_are_rejected_not_allocated() {
        // a 17-byte Resume frame claiming u32::MAX cursors must fail on
        // the missing bytes, never pre-allocate the claimed table
        let mut body = vec![0x08u8];
        body.extend_from_slice(&1u64.to_le_bytes()); // epoch
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // cursor-count lie
        assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn incomplete_prefix_is_not_an_error() {
        let mut buf = Vec::new();
        encode(&Frame::Streams { count: 1 }, &mut buf);
        for cut in 0..buf.len() {
            assert_eq!(decode(&buf[..cut]).unwrap(), None, "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_inputs_are_rejected_not_misread() {
        // zero-length frame
        assert!(matches!(decode(&[0, 0, 0, 0, 0]), Err(FrameError::BadLength(0))));
        // absurd length prefix must not allocate
        let huge = (MAX_FRAME_LEN as u32 + 1).to_le_bytes();
        assert!(matches!(decode(&huge), Err(FrameError::BadLength(_))));
        // unknown frame type
        let mut buf = Vec::new();
        encode(&Frame::Close { stream: 0 }, &mut buf);
        buf[4] = 0x7f;
        assert!(matches!(decode(&buf), Err(FrameError::BadFrameType(0x7f))));
        // trailing garbage inside the declared body length
        let mut buf = Vec::new();
        encode(&Frame::Close { stream: 0 }, &mut buf);
        buf.push(0xee);
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) + 1;
        buf[..4].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(decode(&buf), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn preamble_roundtrip_and_rejection() {
        let mut buf = Vec::new();
        write_preamble(&mut buf).unwrap();
        assert_eq!(read_preamble(&mut &buf[..]).unwrap(), VERSION);

        let mut bad = buf.clone();
        bad[0] = b'X';
        let err = read_preamble(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // neither the retired v1 nor a future v3 is accepted: the Hello
        // layout changed in v2, so cross-version guessing would mis-parse
        for unsupported in [1u32, 3] {
            let mut other = buf.clone();
            other[4..8].copy_from_slice(&unsupported.to_le_bytes());
            let err = read_preamble(&mut &other[..]).unwrap_err();
            assert!(err.to_string().contains(&format!("version {unsupported}")), "{err}");
        }
    }

    #[test]
    fn back_to_back_frames_decode_sequentially() {
        let mut buf = Vec::new();
        let frames = vec![
            Frame::Streams { count: 2 },
            Frame::Beacon { stream: 1, watermark: 10 },
            Frame::Eos { received: 5, dropped: 0 },
        ];
        for f in &frames {
            encode(f, &mut buf);
        }
        let mut off = 0;
        let mut got = Vec::new();
        while off < buf.len() {
            let (f, n) = decode(&buf[off..]).unwrap().unwrap();
            got.push(f);
            off += n;
        }
        assert_eq!(got, frames);
    }

    #[test]
    fn oversized_strings_truncate_on_char_boundaries() {
        // 'é' is 2 bytes; an odd-length cut must step back to a boundary
        let big: String = "é".repeat(40_000); // 80_000 bytes > u16::MAX
        let mut buf = Vec::new();
        encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 0,
                    rank: 0,
                    tid: 0,
                    class_id: 0,
                    fields: vec![FieldValue::Str(big)],
                },
            },
            &mut buf,
        );
        // the truncated wire must still decode as valid UTF-8
        let (back, _) = decode(&buf).unwrap().unwrap();
        let Frame::Event { event, .. } = back else { panic!("wrong frame") };
        let FieldValue::Str(s) = &event.fields[0] else { panic!("wrong field") };
        assert!(s.len() <= u16::MAX as usize);
        assert!(s.chars().all(|c| c == 'é'), "no mangled tail character");
    }

    #[test]
    fn nan_payloads_survive_by_bits() {
        let mut buf = Vec::new();
        encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 1,
                    rank: 0,
                    tid: 0,
                    class_id: 0,
                    fields: vec![FieldValue::F64(f64::NAN)],
                },
            },
            &mut buf,
        );
        let (back, _) = decode(&buf).unwrap().unwrap();
        let Frame::Event { event, .. } = back else { panic!("wrong frame") };
        let FieldValue::F64(v) = event.fields[0] else { panic!("wrong field") };
        assert_eq!(v.to_bits(), f64::NAN.to_bits(), "NaN must round-trip bit-exactly");
    }
}
