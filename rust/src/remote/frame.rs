//! The THRL wire codec: versioned, length-prefixed binary frames.
//!
//! Everything on a remote-live connection after the fixed
//! [`MAGIC`]+[`VERSION`] preamble is a sequence of frames, each
//!
//! ```text
//! len:  u32 LE   — byte length of what follows (type + body)
//! type: u8       — frame discriminator
//! body: len-1 B  — type-specific payload
//! ```
//!
//! The codec is a pure function of its input: [`encode`] appends exactly
//! one frame to a buffer, [`decode`] parses exactly one frame back (or
//! reports "incomplete" so a reader can buffer), and
//! `decode(encode(f)) == f` for every representable frame — pinned by a
//! property test over randomized frames in `rust/tests/remote.rs`. No
//! clocks, no process state, no platform-dependent layout: two builds of
//! this module always agree on the bytes.
//!
//! The full grammar, field encodings and semantics (beacon contract, drop
//! accounting, EOS) are specified in `docs/PROTOCOL.md`; this module is
//! the reference implementation.

use crate::tracer::encoder::FieldValue;
use std::io::{self, Read, Write};

/// Connection preamble magic: "THRL" (THapi Remote Live).
pub const MAGIC: [u8; 4] = *b"THRL";

/// Protocol version spoken by this build's *publisher* by default. The
/// preamble carries it; a subscriber must reject any version it does
/// not implement.
///
/// Version 2 added session resumption: [`Frame::Hello`] grew a trailing
/// session `epoch`, and the [`Frame::Resume`] / [`Frame::ResumeGap`]
/// pair lets a reconnecting subscriber continue a session from its
/// last-delivered per-stream cursors (see `docs/PROTOCOL.md` § Session
/// resumption). v2 changed the Hello layout, so v1 and v2 are mutually
/// unintelligible past the preamble — negotiation stays
/// reject-on-mismatch.
///
/// Version 3 is a strict **byte-superset** of v2: every v2 frame keeps
/// its exact bytes and semantics, and one new frame type joins —
/// [`Frame::EventBatch`], which carries many events of one stream per
/// length-prefixed frame with delta-encoded timestamps, varint ids and
/// a per-connection `(rank, tid, class_id)` dictionary. A v3 subscriber
/// therefore accepts v2 publishers unchanged; a v3 publisher talks to a
/// v2 subscriber by emitting the v2 preamble and per-event frames only
/// (`iprof serve --wire 2`) — v2 subscribers hard-reject any preamble
/// version they do not speak, so the fallback is chosen on the
/// publisher, never negotiated mid-stream.
pub const VERSION: u32 = 3;

/// Every protocol version this build can speak. Version negotiation
/// ([`read_preamble`]) accepts exactly these; anything else is a
/// [`FrameError::BadVersion`]. v1 (no epochs, no resumption) is
/// deliberately absent: its Hello layout is a strict prefix of v2's and
/// decoding it under v2 rules would mis-parse, so this build rejects v1
/// peers outright instead of guessing. v2 stays supported because v3 is
/// a byte-superset: a connection whose preamble says 2 simply never
/// carries an [`Frame::EventBatch`].
pub const SUPPORTED_VERSIONS: [u32; 2] = [2, VERSION];

/// Upper bound on `len` (type + body bytes). Frames beyond this are a
/// protocol error, never an allocation request — a corrupt or hostile
/// length prefix cannot make a reader allocate gigabytes.
pub const MAX_FRAME_LEN: usize = 16 << 20;

/// Upper bound on stream counts and stream indices a subscriber will
/// honor (one channel per traced thread; 2^20 is far beyond any real
/// session). Same rationale as [`MAX_FRAME_LEN`]: a corrupt or hostile
/// `Streams`/`Event` frame must never translate into a multi-gigabyte
/// channel-table allocation.
pub const MAX_STREAMS: u32 = 1 << 20;

/// Upper bound on the event count one [`Frame::EventBatch`] may claim.
/// Same rationale as [`MAX_FRAME_LEN`]: a corrupt or hostile count must
/// fail as a protocol error before it becomes an allocation request.
/// (The frame length guard already bounds real batches well below this —
/// 64 Ki events cannot fit in 16 MiB unless most are dictionary-
/// compressed two-byte events, which is exactly the intended regime.)
pub const MAX_BATCH_EVENTS: u32 = 1 << 16;

/// Upper bound on entries in the per-connection `(rank, tid, class_id)`
/// batch dictionary. Encoder and decoder share this constant so their
/// index spaces stay aligned: both sides stop *recording* new triples at
/// the cap (the encoder keeps emitting inline definitions for triples
/// beyond it, and the decoder ignores definitions past the cap for
/// recording purposes while still decoding the event itself).
pub const MAX_DICT_ENTRIES: u32 = 1 << 16;

// Frame type discriminators (u8 on the wire). Public so out-of-band
// wire observers — the chaos testkit's kill-at-frame-kind scanner,
// conformance fixtures — can name kinds without re-deriving the
// PROTOCOL.md table.
/// `Hello` discriminator.
pub const T_HELLO: u8 = 0x01;
/// `Streams` discriminator.
pub const T_STREAMS: u8 = 0x02;
/// `Event` discriminator.
pub const T_EVENT: u8 = 0x03;
/// `Beacon` discriminator.
pub const T_BEACON: u8 = 0x04;
/// `Drops` discriminator.
pub const T_DROPS: u8 = 0x05;
/// `Close` discriminator.
pub const T_CLOSE: u8 = 0x06;
/// `Eos` discriminator.
pub const T_EOS: u8 = 0x07;
/// `Resume` discriminator.
pub const T_RESUME: u8 = 0x08;
/// `ResumeGap` discriminator.
pub const T_RESUME_GAP: u8 = 0x09;
/// `EventBatch` discriminator (v3 only).
pub const T_EVENT_BATCH: u8 = 0x0a;
/// `Origin` discriminator (v3 only, emitted by relays).
pub const T_ORIGIN: u8 = 0x0b;

// Field value tags inside Event frames.
const F_U64: u8 = 0;
const F_I64: u8 = 1;
const F_F64: u8 = 2;
const F_PTR: u8 = 3;
const F_STR: u8 = 4;

/// One decoded event as carried on the wire: the stream-independent parts
/// of an [`EventMsg`](crate::analysis::EventMsg). The class is referenced
/// by id — the subscriber resolves it against the class table shipped in
/// the [`Frame::Hello`] metadata, exactly how post-mortem analysis
/// resolves record ids against `metadata.btf`.
#[derive(Debug, Clone, PartialEq)]
pub struct WireEvent {
    /// Timestamp (trace-clock ns).
    pub ts: u64,
    /// Producing rank.
    pub rank: u32,
    /// Producing thread.
    pub tid: u32,
    /// Event-class id (resolved via the Hello metadata).
    pub class_id: u32,
    /// Decoded field values, self-describing (tag + value) so the codec
    /// round-trips without a class table.
    pub fields: Vec<FieldValue>,
}

/// How one event inside a [`Frame::EventBatch`] names its
/// `(rank, tid, class_id)` triple (v3). The first time a triple appears
/// on a connection the publisher spells it out inline (`Def`), which
/// *also* assigns it the next free index in the per-connection batch
/// dictionary (dense, in definition order, capped at
/// [`MAX_DICT_ENTRIES`]); every later event referencing the same triple
/// is a one- or two-byte `Ref` into that dictionary.
///
/// The dictionary is **connection state**, not frame state: it persists
/// across batches of one connection and resets on (re)connect. The codec
/// itself stays a pure function of the frame — `Def`/`Ref` is explicit
/// in the decoded value, and resolving a `Ref` against the running
/// dictionary happens one layer up (see [`BatchDict`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchKey {
    /// Inline triple; also appends to the connection dictionary (if it
    /// is not yet at [`MAX_DICT_ENTRIES`]).
    Def {
        /// Producing rank.
        rank: u32,
        /// Producing thread.
        tid: u32,
        /// Event-class id (resolved via the Hello metadata).
        class_id: u32,
    },
    /// Index into the connection dictionary, in definition order.
    Ref(u32),
}

/// One event inside a [`Frame::EventBatch`] (v3). The timestamp is
/// absolute in the decoded form; on the wire it is a zigzag-varint delta
/// against the previous event in the same batch (starting from 0), so
/// non-monotone timestamps cost a few bytes instead of overflowing.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchEvent {
    /// Timestamp (trace-clock ns), absolute.
    pub ts: u64,
    /// The `(rank, tid, class_id)` naming — inline or dictionary ref.
    pub key: BatchKey,
    /// Decoded field values, self-describing exactly as in
    /// [`WireEvent::fields`].
    pub fields: Vec<FieldValue>,
}

/// One protocol frame. See `docs/PROTOCOL.md` for the normative grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// First frame on every connection: who is publishing and how to
    /// decode it. `metadata` is the full BTF metadata text (the stream
    /// registry's class table); `streams` is the channel count known at
    /// connect time (may grow via [`Frame::Streams`]).
    Hello {
        /// Publisher hostname (stamped on every reconstructed message).
        hostname: String,
        /// BTF metadata text: the event-class registry.
        metadata: String,
        /// Channels existing at connect time.
        streams: u32,
        /// Session epoch. `0` means the session is NOT resumable (the
        /// publisher streams immediately and never reads from the
        /// connection — the whole v1 flow). Any nonzero value
        /// identifies one session *instance*: the publisher keeps a
        /// replay ring and waits for a [`Frame::Resume`] echoing this
        /// epoch before streaming. A subscriber that reconnects and
        /// sees a *different* nonzero epoch knows the publisher
        /// restarted into a new session — its cursors are meaningless
        /// there and it must not send them.
        epoch: u64,
    },
    /// The per-stream channel set grew to `count` (late-registering
    /// threads). Idempotent; counts never shrink.
    Streams {
        /// New total channel count.
        count: u32,
    },
    /// One decoded event on channel `stream`. Per-stream frame order is
    /// the stream's event order; cross-stream order is unspecified (the
    /// subscriber re-merges).
    Event {
        /// Channel index (== session stream registration index).
        stream: u32,
        /// The event payload.
        event: WireEvent,
    },
    /// Watermark promise: every future `Event` on `stream` has
    /// `ts >= watermark`. Monotone per stream.
    Beacon {
        /// Channel index.
        stream: u32,
        /// Timestamp lower bound for all future events of this stream.
        watermark: u64,
    },
    /// Cumulative count of messages the publisher dropped on `stream`
    /// (bounded-channel backpressure). Monotone per stream; the latest
    /// value is the total.
    Drops {
        /// Channel index.
        stream: u32,
        /// Cumulative dropped-message count for this stream.
        dropped: u64,
    },
    /// No further events or beacons will ever arrive on `stream`.
    Close {
        /// Channel index.
        stream: u32,
    },
    /// Clean end of session; always the final frame. Carries the
    /// publisher's hub totals so both ends agree on completeness.
    Eos {
        /// Messages the publisher's channels accepted in total.
        received: u64,
        /// Messages the publisher's channels dropped in total.
        dropped: u64,
    },
    /// The only subscriber→publisher frame: sent once per connection to
    /// a *resumable* publisher (Hello `epoch != 0`), immediately after
    /// the subscriber validates the Hello. `cursors[i]` is the number
    /// of [`Frame::Event`]s the subscriber has fully delivered on
    /// remote stream `i` — a fresh attach sends an empty cursor list
    /// (deliver from the beginning). The publisher replays every event
    /// past each cursor from its replay ring, answering
    /// [`Frame::ResumeGap`] per stream whose cursor fell out of the
    /// ring.
    Resume {
        /// Echo of the Hello epoch (the publisher rejects mismatches).
        epoch: u64,
        /// Per-remote-stream delivered-event counts, indexed by the
        /// publisher's own stream ids. Streams beyond the list resume
        /// from 0.
        cursors: Vec<u64>,
    },
    /// Many events of one stream in one length-prefixed frame (v3 only;
    /// never sent on a connection whose preamble negotiated v2). Wire
    /// form: `stream:u32 LE`, `count:varint`, then per event a zigzag-
    /// varint timestamp delta, a varint key (`0` = inline definition of
    /// rank/tid/class_id as varints, `k>0` = dictionary ref `k-1`), a
    /// varint field count, and the same self-describing tagged fields as
    /// [`Frame::Event`]. Per-stream event order inside and across
    /// batches is the stream's event order, exactly as for per-event
    /// frames; a batch of `n` events advances resume cursors and drop
    /// ledgers by `n` *events* — batching never changes accounting.
    EventBatch {
        /// Channel index (== session stream registration index).
        stream: u32,
        /// The events, in stream order.
        events: Vec<BatchEvent>,
    },
    /// Publisher→subscriber resumption verdict for one stream: `missed`
    /// events between the subscriber's cursor and the oldest event
    /// still in the replay ring were evicted and cannot be replayed.
    /// The subscriber books them into its per-origin drops ledger (the
    /// live view is incomplete by exactly `missed` events on this
    /// stream; `--live-strict` fails) and advances its cursor past the
    /// gap so later replays stay aligned.
    ResumeGap {
        /// Channel index (publisher's stream id).
        stream: u32,
        /// Events irrecoverably lost from the ring for this stream.
        missed: u64,
    },
    /// Per-leaf accounting for one origin the sender aggregates (v3
    /// only; emitted by relays, `iprof relay`). The sender's own
    /// identity travels in its Hello; each Origin frame describes one
    /// *downstream* publisher whose streams are folded into the
    /// sender's stream space, so per-leaf drop/eos/gap ledgers survive
    /// aggregation instead of collapsing into the relay's totals.
    ///
    /// `path` is the hierarchical origin id (`docs/PROTOCOL.md`
    /// § Hierarchical origin ids): the sender's local
    /// `<index>:<label>` origin name, extended with `/`-separated
    /// segments for origins the downstream node was itself relaying.
    /// All counters are cumulative and monotone — the frame is re-sent
    /// whenever a value changes and the receiver max-merges, exactly
    /// like [`Frame::Drops`].
    Origin {
        /// Hierarchical origin id, unique among the sender's frames.
        path: String,
        /// The leaf publisher's hostname (stamped on its messages).
        hostname: String,
        /// Sender stream ids that carry this origin's events.
        streams: Vec<u32>,
        /// Cumulative publisher-side drops attributed to this origin.
        dropped: u64,
        /// Cumulative events this origin lost to resume gaps.
        resume_gaps: u64,
        /// The origin's own Eos totals `(received, dropped)`, once it
        /// ended cleanly; `None` while it is live (or if it died).
        eos: Option<(u64, u64)>,
    },
}

/// Codec errors. `Incomplete` is not among them: [`decode`] signals a
/// partial frame with `Ok(None)` so buffering readers can distinguish
/// "need more bytes" from "stream is corrupt".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The connection preamble did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The publisher speaks a protocol version this build does not.
    BadVersion(u32),
    /// Unknown frame type discriminator.
    BadFrameType(u8),
    /// Unknown field-value tag inside an Event frame.
    BadFieldTag(u8),
    /// The length prefix exceeds [`MAX_FRAME_LEN`] (or is zero).
    BadLength(usize),
    /// A frame body ended early or carried trailing bytes.
    Malformed(&'static str),
    /// A string field was not valid UTF-8.
    BadUtf8,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad preamble magic {m:02x?} (expected THRL)"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            FrameError::BadFrameType(t) => write!(f, "unknown frame type {t:#04x}"),
            FrameError::BadFieldTag(t) => write!(f, "unknown field tag {t:#04x}"),
            FrameError::BadLength(n) => write!(f, "frame length {n} out of bounds"),
            FrameError::Malformed(what) => write!(f, "malformed frame: {what}"),
            FrameError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<FrameError> for io::Error {
    fn from(e: FrameError) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, e)
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// u16 length + UTF-8 bytes (hostnames, string fields). Strings longer
/// than 64 KiB are truncated on a char boundary — the wire stays valid
/// UTF-8 (decoding never fails), at the cost of losing the tail of such
/// a string; event string fields are capped at 4 KiB upstream, so this
/// is unreachable in practice.
fn put_str16(out: &mut Vec<u8>, s: &str) {
    let mut n = s.len().min(u16::MAX as usize);
    while n > 0 && !s.is_char_boundary(n) {
        n -= 1;
    }
    put_u16(out, n as u16);
    out.extend_from_slice(&s.as_bytes()[..n]);
}

/// u32 length + UTF-8 bytes (metadata text).
fn put_str32(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

fn put_field(out: &mut Vec<u8>, v: &FieldValue) {
    match v {
        FieldValue::U64(x) => {
            out.push(F_U64);
            put_u64(out, *x);
        }
        FieldValue::I64(x) => {
            out.push(F_I64);
            put_u64(out, *x as u64);
        }
        FieldValue::F64(x) => {
            out.push(F_F64);
            put_u64(out, x.to_bits());
        }
        FieldValue::Ptr(x) => {
            out.push(F_PTR);
            put_u64(out, *x);
        }
        FieldValue::Str(s) => {
            out.push(F_STR);
            put_str16(out, s);
        }
    }
}

/// LEB128 varint: 7 payload bits per byte, continuation bit 0x80, at
/// most 10 bytes for a full u64. Small numbers — stream-local ids,
/// deltas, counts — collapse to one byte, which is where the v3 batch
/// format gets most of its density.
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Zigzag-map a signed delta onto an unsigned varint payload so small
/// *negative* deltas (non-monotone timestamps: late flushes, clock
/// steps) stay small on the wire instead of becoming ten 0xff bytes.
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

fn put_batch_event(out: &mut Vec<u8>, prev_ts: u64, ev: &BatchEvent) {
    // delta against the previous event of the batch; wrapping arithmetic
    // makes every (prev, ts) pair representable, including u64 extremes
    put_varint(out, zigzag(ev.ts.wrapping_sub(prev_ts) as i64));
    match ev.key {
        BatchKey::Def { rank, tid, class_id } => {
            put_varint(out, 0);
            put_varint(out, rank as u64);
            put_varint(out, tid as u64);
            put_varint(out, class_id as u64);
        }
        BatchKey::Ref(idx) => put_varint(out, idx as u64 + 1),
    }
    let nfields = ev.fields.len().min(u16::MAX as usize);
    put_varint(out, nfields as u64);
    for f in &ev.fields[..nfields] {
        put_field(out, f);
    }
}

/// Append one length-prefixed frame to `out`. Deterministic: equal frames
/// always produce equal bytes.
pub fn encode(frame: &Frame, out: &mut Vec<u8>) {
    let len_at = out.len();
    put_u32(out, 0); // length backpatched below
    match frame {
        Frame::Hello { hostname, metadata, streams, epoch } => {
            out.push(T_HELLO);
            put_str16(out, hostname);
            put_str32(out, metadata);
            put_u32(out, *streams);
            put_u64(out, *epoch);
        }
        Frame::Streams { count } => {
            out.push(T_STREAMS);
            put_u32(out, *count);
        }
        Frame::Event { stream, event } => {
            out.push(T_EVENT);
            put_u32(out, *stream);
            put_u64(out, event.ts);
            put_u32(out, event.rank);
            put_u32(out, event.tid);
            put_u32(out, event.class_id);
            let nfields = event.fields.len().min(u16::MAX as usize);
            put_u16(out, nfields as u16);
            for f in &event.fields[..nfields] {
                put_field(out, f);
            }
        }
        Frame::Beacon { stream, watermark } => {
            out.push(T_BEACON);
            put_u32(out, *stream);
            put_u64(out, *watermark);
        }
        Frame::Drops { stream, dropped } => {
            out.push(T_DROPS);
            put_u32(out, *stream);
            put_u64(out, *dropped);
        }
        Frame::Close { stream } => {
            out.push(T_CLOSE);
            put_u32(out, *stream);
        }
        Frame::Eos { received, dropped } => {
            out.push(T_EOS);
            put_u64(out, *received);
            put_u64(out, *dropped);
        }
        Frame::Resume { epoch, cursors } => {
            out.push(T_RESUME);
            put_u64(out, *epoch);
            let n = cursors.len().min(MAX_STREAMS as usize);
            put_u32(out, n as u32);
            for c in &cursors[..n] {
                put_u64(out, *c);
            }
        }
        Frame::ResumeGap { stream, missed } => {
            out.push(T_RESUME_GAP);
            put_u32(out, *stream);
            put_u64(out, *missed);
        }
        Frame::EventBatch { stream, events } => {
            out.push(T_EVENT_BATCH);
            put_u32(out, *stream);
            let n = events.len().min(MAX_BATCH_EVENTS as usize);
            put_varint(out, n as u64);
            let mut prev_ts = 0u64;
            for ev in &events[..n] {
                put_batch_event(out, prev_ts, ev);
                prev_ts = ev.ts;
            }
        }
        Frame::Origin { path, hostname, streams, dropped, resume_gaps, eos } => {
            out.push(T_ORIGIN);
            put_str16(out, path);
            put_str16(out, hostname);
            let n = streams.len().min(MAX_STREAMS as usize);
            put_u32(out, n as u32);
            for s in &streams[..n] {
                put_u32(out, *s);
            }
            put_u64(out, *dropped);
            put_u64(out, *resume_gaps);
            match eos {
                Some((received, eos_dropped)) => {
                    out.push(1);
                    put_u64(out, *received);
                    put_u64(out, *eos_dropped);
                }
                None => out.push(0),
            }
        }
    }
    let body_len = (out.len() - len_at - 4) as u32;
    out[len_at..len_at + 4].copy_from_slice(&body_len.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Little bounds-checked reader over a frame body.
struct Body<'a> {
    buf: &'a [u8],
}

impl<'a> Body<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.buf.len() < n {
            return Err(FrameError::Malformed("body ended early"));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str16(&mut self) -> Result<String, FrameError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| FrameError::BadUtf8)
    }

    fn str32(&mut self) -> Result<String, FrameError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| FrameError::BadUtf8)
    }

    /// LEB128 varint, bounded at 10 bytes; the tenth byte may only carry
    /// the final high bit of a u64, so anything past that — or a
    /// continuation bit on byte ten — is malformed, not silently
    /// truncated.
    fn varint(&mut self) -> Result<u64, FrameError> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift == 63 && byte > 1 {
                return Err(FrameError::Malformed("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(FrameError::Malformed("varint overflows u64"));
            }
        }
    }

    /// A varint that must fit a u32 (ids, counts, dictionary indices).
    fn varint32(&mut self, what: &'static str) -> Result<u32, FrameError> {
        let v = self.varint()?;
        u32::try_from(v).map_err(|_| FrameError::Malformed(what))
    }

    fn batch_event(&mut self, prev_ts: u64) -> Result<BatchEvent, FrameError> {
        let ts = prev_ts.wrapping_add(unzigzag(self.varint()?) as u64);
        let key = match self.varint()? {
            0 => BatchKey::Def {
                rank: self.varint32("batch rank exceeds u32")?,
                tid: self.varint32("batch tid exceeds u32")?,
                class_id: self.varint32("batch class id exceeds u32")?,
            },
            k => {
                let idx = k - 1;
                if idx >= u64::from(MAX_DICT_ENTRIES) {
                    return Err(FrameError::Malformed("batch dictionary ref out of range"));
                }
                BatchKey::Ref(idx as u32)
            }
        };
        let nfields = self.varint()? as usize;
        if nfields > u16::MAX as usize {
            return Err(FrameError::Malformed("batch field count exceeds u16"));
        }
        let mut fields = Vec::with_capacity(nfields.min(256));
        for _ in 0..nfields {
            fields.push(self.field()?);
        }
        Ok(BatchEvent { ts, key, fields })
    }

    fn field(&mut self) -> Result<FieldValue, FrameError> {
        let tag = self.u8()?;
        Ok(match tag {
            F_U64 => FieldValue::U64(self.u64()?),
            F_I64 => FieldValue::I64(self.u64()? as i64),
            F_F64 => FieldValue::F64(f64::from_bits(self.u64()?)),
            F_PTR => FieldValue::Ptr(self.u64()?),
            F_STR => FieldValue::Str(self.str16()?),
            other => return Err(FrameError::BadFieldTag(other)),
        })
    }

    fn finish(self) -> Result<(), FrameError> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(FrameError::Malformed("trailing bytes in body"))
        }
    }
}

/// Decode one frame from the front of `buf`.
///
/// Returns `Ok(Some((frame, consumed)))` for a complete frame,
/// `Ok(None)` when `buf` holds only a prefix of a frame (read more and
/// retry), and `Err` for protocol violations. `consumed` covers the
/// length prefix too, so `&buf[consumed..]` starts the next frame.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(FrameError::BadLength(len));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let frame = decode_body(&buf[4..4 + len])?;
    Ok(Some((frame, 4 + len)))
}

/// Decode a frame body (everything after the length prefix). The body
/// must contain exactly one frame: early EOF and trailing bytes are both
/// errors.
pub fn decode_body(body: &[u8]) -> Result<Frame, FrameError> {
    let mut b = Body { buf: body };
    let ty = b.u8()?;
    let frame = match ty {
        T_HELLO => Frame::Hello {
            hostname: b.str16()?,
            metadata: b.str32()?,
            streams: b.u32()?,
            epoch: b.u64()?,
        },
        T_STREAMS => Frame::Streams { count: b.u32()? },
        T_EVENT => {
            let stream = b.u32()?;
            let ts = b.u64()?;
            let rank = b.u32()?;
            let tid = b.u32()?;
            let class_id = b.u32()?;
            let nfields = b.u16()? as usize;
            let mut fields = Vec::with_capacity(nfields.min(256));
            for _ in 0..nfields {
                fields.push(b.field()?);
            }
            Frame::Event { stream, event: WireEvent { ts, rank, tid, class_id, fields } }
        }
        T_BEACON => Frame::Beacon { stream: b.u32()?, watermark: b.u64()? },
        T_DROPS => Frame::Drops { stream: b.u32()?, dropped: b.u64()? },
        T_CLOSE => Frame::Close { stream: b.u32()? },
        T_EOS => Frame::Eos { received: b.u64()?, dropped: b.u64()? },
        T_RESUME => {
            let epoch = b.u64()?;
            let n = b.u32()?;
            if n > MAX_STREAMS {
                // same rationale as MAX_STREAMS everywhere: a corrupt
                // count must never become a multi-GB cursor table
                return Err(FrameError::Malformed("resume cursor count exceeds MAX_STREAMS"));
            }
            let n = n as usize;
            let mut cursors = Vec::with_capacity(n.min(256));
            for _ in 0..n {
                cursors.push(b.u64()?);
            }
            Frame::Resume { epoch, cursors }
        }
        T_RESUME_GAP => Frame::ResumeGap { stream: b.u32()?, missed: b.u64()? },
        T_EVENT_BATCH => {
            let stream = b.u32()?;
            let n = b.varint()?;
            if n > u64::from(MAX_BATCH_EVENTS) {
                // a corrupt count fails before it becomes an allocation
                return Err(FrameError::Malformed("batch event count exceeds MAX_BATCH_EVENTS"));
            }
            let n = n as usize;
            let mut events = Vec::with_capacity(n.min(256));
            let mut prev_ts = 0u64;
            for _ in 0..n {
                let ev = b.batch_event(prev_ts)?;
                prev_ts = ev.ts;
                events.push(ev);
            }
            Frame::EventBatch { stream, events }
        }
        T_ORIGIN => {
            let path = b.str16()?;
            let hostname = b.str16()?;
            let n = b.u32()?;
            if n > MAX_STREAMS {
                return Err(FrameError::Malformed("origin stream count exceeds MAX_STREAMS"));
            }
            let n = n as usize;
            let mut streams = Vec::with_capacity(n.min(256));
            for _ in 0..n {
                streams.push(b.u32()?);
            }
            let dropped = b.u64()?;
            let resume_gaps = b.u64()?;
            let eos = match b.u8()? {
                0 => None,
                1 => Some((b.u64()?, b.u64()?)),
                _ => return Err(FrameError::Malformed("origin eos flag must be 0 or 1")),
            };
            Frame::Origin { path, hostname, streams, dropped, resume_gaps, eos }
        }
        other => return Err(FrameError::BadFrameType(other)),
    };
    b.finish()?;
    Ok(frame)
}

// ---------------------------------------------------------------------------
// Batch dictionary (v3 connection state)
// ---------------------------------------------------------------------------

/// Encoder side of the per-connection batch dictionary: assigns dense
/// indices to `(rank, tid, class_id)` triples in first-use order. One
/// instance lives per outgoing connection and is dropped with it; a
/// reconnect starts an empty dictionary on both ends by construction.
#[derive(Debug, Default)]
pub struct BatchDictEncoder {
    map: std::collections::HashMap<(u32, u32, u32), u32>,
}

impl BatchDictEncoder {
    /// Fresh, empty dictionary (connection start).
    pub fn new() -> Self {
        Self::default()
    }

    /// The wire key for a triple: `Ref` if it has been defined on this
    /// connection, else `Def` — which also records it, unless the
    /// dictionary is at [`MAX_DICT_ENTRIES`] (then every later first-use
    /// stays an inline `Def` forever, keeping both index spaces
    /// identical without any eviction protocol).
    pub fn key_for(&mut self, rank: u32, tid: u32, class_id: u32) -> BatchKey {
        if let Some(&idx) = self.map.get(&(rank, tid, class_id)) {
            return BatchKey::Ref(idx);
        }
        let next = self.map.len() as u32;
        if next < MAX_DICT_ENTRIES {
            self.map.insert((rank, tid, class_id), next);
        }
        BatchKey::Def { rank, tid, class_id }
    }

    /// Number of recorded triples (diagnostics).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing has been defined yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Decoder side of the per-connection batch dictionary: triples in
/// definition order. Mirrors [`BatchDictEncoder`] — same cap, same
/// recording rule — so index `i` means the same triple on both ends.
/// One instance lives per incoming connection; [`BatchDict::clear`] on
/// reconnect.
#[derive(Debug, Default)]
pub struct BatchDict {
    entries: Vec<(u32, u32, u32)>,
}

impl BatchDict {
    /// Fresh, empty dictionary (connection start).
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset for a new connection (resume/reconnect).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Resolve a [`BatchKey`] to its triple, recording definitions.
    pub fn resolve(&mut self, key: BatchKey) -> Result<(u32, u32, u32), FrameError> {
        match key {
            BatchKey::Def { rank, tid, class_id } => {
                if self.entries.len() < MAX_DICT_ENTRIES as usize {
                    self.entries.push((rank, tid, class_id));
                }
                Ok((rank, tid, class_id))
            }
            BatchKey::Ref(idx) => self
                .entries
                .get(idx as usize)
                .copied()
                .ok_or(FrameError::Malformed("batch dictionary ref out of range")),
        }
    }

    /// Number of recorded triples (diagnostics).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been defined yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// True when a raw frame body (as filled by [`read_frame_into`]) is an
/// [`Frame::EventBatch`] — the hot-path discriminator check that lets a
/// subscriber route batches through [`decode_batch_into`] without
/// materializing a [`Frame`].
pub fn is_event_batch(body: &[u8]) -> bool {
    body.first() == Some(&T_EVENT_BATCH)
}

/// Peek the stream id of a raw [`Frame::EventBatch`] body without
/// decoding any events — `None` when `body` is not a complete batch
/// header. A fan-in pump uses this to pick the per-stream hostname
/// override *before* the zero-copy batch decode runs (the decode only
/// yields the stream id on return, after every event was emitted).
pub fn batch_stream(body: &[u8]) -> Option<u32> {
    if !is_event_batch(body) || body.len() < 5 {
        return None;
    }
    Some(u32::from_le_bytes(body[1..5].try_into().unwrap()))
}

/// Decode an [`Frame::EventBatch`] body directly into a consumer, with
/// no per-event [`BatchEvent`] or empty-`Vec` allocation: `emit` is
/// called once per event with the absolute timestamp, the dictionary-
/// resolved `(rank, tid, class_id)`, and a scratch field buffer the
/// consumer may `mem::take` (only when it actually holds fields — the
/// fixed-field fast path hands the same empty buffer around the whole
/// batch). Returns `(stream, event_count)`.
///
/// `body` is a full frame body including the leading type byte (see
/// [`is_event_batch`]); `dict` is the connection's running dictionary.
/// Errors mirror [`decode_body`]'s for the same bytes.
pub fn decode_batch_into<F>(
    body: &[u8],
    dict: &mut BatchDict,
    mut emit: F,
) -> Result<(u32, usize), FrameError>
where
    F: FnMut(u64, u32, u32, u32, &mut Vec<FieldValue>),
{
    let mut b = Body { buf: body };
    if b.u8()? != T_EVENT_BATCH {
        return Err(FrameError::Malformed("not an EventBatch frame"));
    }
    let stream = b.u32()?;
    let n = b.varint()?;
    if n > u64::from(MAX_BATCH_EVENTS) {
        return Err(FrameError::Malformed("batch event count exceeds MAX_BATCH_EVENTS"));
    }
    let n = n as usize;
    let mut prev_ts = 0u64;
    let mut scratch: Vec<FieldValue> = Vec::new();
    for _ in 0..n {
        let ts = prev_ts.wrapping_add(unzigzag(b.varint()?) as u64);
        prev_ts = ts;
        let key = match b.varint()? {
            0 => BatchKey::Def {
                rank: b.varint32("batch rank exceeds u32")?,
                tid: b.varint32("batch tid exceeds u32")?,
                class_id: b.varint32("batch class id exceeds u32")?,
            },
            k => {
                let idx = k - 1;
                if idx >= u64::from(MAX_DICT_ENTRIES) {
                    return Err(FrameError::Malformed("batch dictionary ref out of range"));
                }
                BatchKey::Ref(idx as u32)
            }
        };
        let (rank, tid, class_id) = dict.resolve(key)?;
        let nfields = b.varint()? as usize;
        if nfields > u16::MAX as usize {
            return Err(FrameError::Malformed("batch field count exceeds u16"));
        }
        scratch.clear();
        scratch.reserve(nfields.min(256));
        for _ in 0..nfields {
            scratch.push(b.field()?);
        }
        emit(ts, rank, tid, class_id, &mut scratch);
    }
    b.finish()?;
    Ok((stream, n))
}

// ---------------------------------------------------------------------------
// Blocking I/O helpers
// ---------------------------------------------------------------------------

/// Write the connection preamble (magic + version). The publisher sends
/// this once, immediately after accepting the subscriber. Writes this
/// build's default version ([`VERSION`]); a publisher downgrading for
/// v2-only subscribers uses [`write_preamble_version`].
pub fn write_preamble(w: &mut impl Write) -> io::Result<()> {
    write_preamble_version(w, VERSION)
}

/// Write the connection preamble for an explicit protocol version. The
/// version chosen here is a *promise about the publisher's own output*:
/// announcing 2 commits the publisher to the exact v2 frame set (no
/// [`Frame::EventBatch`]), which is how a v3 build keeps v2 subscribers
/// working — they hard-reject any preamble version they do not speak,
/// so the downgrade must be chosen publisher-side (`iprof serve
/// --wire 2`), not negotiated.
pub fn write_preamble_version(w: &mut impl Write, version: u32) -> io::Result<()> {
    debug_assert!(SUPPORTED_VERSIONS.contains(&version));
    w.write_all(&MAGIC)?;
    w.write_all(&version.to_le_bytes())
}

/// Read and verify the connection preamble, returning the negotiated
/// version. Errors on wrong magic or any version outside
/// [`SUPPORTED_VERSIONS`] — the entire version negotiation is
/// reject-on-mismatch (see `docs/PROTOCOL.md` § Versioning); in
/// particular v1 preambles are rejected here, before any frame is read,
/// because the v1 Hello layout would mis-parse under v2 rules.
pub fn read_preamble(r: &mut impl Read) -> io::Result<u32> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic).into());
    }
    let mut v = [0u8; 4];
    r.read_exact(&mut v)?;
    let version = u32::from_le_bytes(v);
    if !SUPPORTED_VERSIONS.contains(&version) {
        return Err(FrameError::BadVersion(version).into());
    }
    Ok(version)
}

/// Encode and write one frame; returns the bytes written.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<usize> {
    let mut buf = Vec::with_capacity(64);
    encode(frame, &mut buf);
    w.write_all(&buf)?;
    Ok(buf.len())
}

/// Read exactly one frame. An EOF at a frame boundary is reported as
/// `UnexpectedEof` — the protocol ends with [`Frame::Eos`], never by the
/// transport closing, so any EOF here is abnormal.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut body = Vec::new();
    read_frame_into(r, &mut body)?;
    Ok(decode_body(&body)?)
}

/// Read one raw frame body (type byte + payload, no length prefix) into
/// `buf`, reusing its capacity. This is the subscriber hot path: the
/// caller checks [`is_event_batch`] and routes batches through
/// [`decode_batch_into`] — one buffer serves the whole connection
/// instead of one allocation per frame. EOF semantics match
/// [`read_frame`].
pub fn read_frame_into(r: &mut impl Read, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut lenbuf = [0u8; 4];
    r.read_exact(&mut lenbuf)?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(FrameError::BadLength(len).into());
    }
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        encode(&f, &mut buf);
        let (back, consumed) = decode(&buf).unwrap().unwrap();
        assert_eq!(back, f);
        assert_eq!(consumed, buf.len());
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        roundtrip(Frame::Hello {
            hostname: "node0".into(),
            metadata: "btf_version: 1\nevents:\n".into(),
            streams: 3,
            epoch: 0x0123_4567_89ab_cdef,
        });
        roundtrip(Frame::Streams { count: 7 });
        roundtrip(Frame::Event {
            stream: 2,
            event: WireEvent {
                ts: u64::MAX,
                rank: 1,
                tid: 42,
                class_id: 9,
                fields: vec![
                    FieldValue::U64(7),
                    FieldValue::I64(-3),
                    FieldValue::F64(2.5),
                    FieldValue::Ptr(0xff00_0000_dead_beef),
                    FieldValue::Str("kernel".into()),
                ],
            },
        });
        roundtrip(Frame::Beacon { stream: 0, watermark: 123_456 });
        roundtrip(Frame::Drops { stream: 5, dropped: 99 });
        roundtrip(Frame::Close { stream: 1 });
        roundtrip(Frame::Eos { received: 1000, dropped: 4 });
        roundtrip(Frame::Resume { epoch: 0x0123_4567_89ab_cdef, cursors: vec![7, 0, 42] });
        roundtrip(Frame::Resume { epoch: 1, cursors: vec![] });
        roundtrip(Frame::ResumeGap { stream: 2, missed: 17 });
        roundtrip(Frame::EventBatch { stream: 3, events: vec![] });
        roundtrip(Frame::EventBatch {
            stream: 2,
            events: vec![
                BatchEvent {
                    ts: 1000,
                    key: BatchKey::Def { rank: 1, tid: 42, class_id: 9 },
                    fields: vec![FieldValue::U64(7), FieldValue::Str("kernel".into())],
                },
                // non-monotone: ts goes backwards, zigzag keeps it small
                BatchEvent { ts: 999, key: BatchKey::Ref(0), fields: vec![] },
                BatchEvent { ts: u64::MAX, key: BatchKey::Ref(0), fields: vec![] },
                BatchEvent { ts: 0, key: BatchKey::Ref(0), fields: vec![] },
            ],
        });
    }

    #[test]
    fn varints_roundtrip_across_the_full_u64_range() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX / 2, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert!(buf.len() <= 10);
            let mut b = Body { buf: &buf };
            assert_eq!(b.varint().unwrap(), v);
            b.finish().unwrap();
        }
        for d in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(d)), d);
        }
        // small magnitudes of either sign stay one byte
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn overlong_varints_are_malformed_not_truncated() {
        // eleven continuation bytes can never be a u64
        let buf = [0xffu8; 11];
        let mut b = Body { buf: &buf };
        assert!(matches!(b.varint(), Err(FrameError::Malformed(_))));
        // ten bytes whose last carries more than u64 bit 63
        let buf = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x02];
        let mut b = Body { buf: &buf };
        assert!(matches!(b.varint(), Err(FrameError::Malformed(_))));
        // ...while the canonical u64::MAX encoding is fine
        let buf = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x01];
        let mut b = Body { buf: &buf };
        assert_eq!(b.varint().unwrap(), u64::MAX);
    }

    #[test]
    fn hostile_batch_event_counts_are_rejected_not_allocated() {
        // a tiny EventBatch body claiming MAX_BATCH_EVENTS+1 events must
        // fail on the count guard, never pre-allocate the claimed table
        let mut body = vec![T_EVENT_BATCH];
        body.extend_from_slice(&0u32.to_le_bytes()); // stream
        put_varint(&mut body, u64::from(MAX_BATCH_EVENTS) + 1);
        assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));
        let mut dict = BatchDict::new();
        assert!(matches!(decode_batch_into(&body, &mut dict, |_, _, _, _, _| ()), Err(_)));
        // an in-range count with missing bytes fails on the bytes
        let mut body = vec![T_EVENT_BATCH];
        body.extend_from_slice(&0u32.to_le_bytes());
        put_varint(&mut body, 1000);
        assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn batch_dictionary_encoder_and_decoder_agree() {
        let mut enc = BatchDictEncoder::new();
        let mut dec = BatchDict::new();
        // first use defines, second use refs, distinct triples get
        // distinct dense indices
        let k0 = enc.key_for(0, 10, 5);
        assert_eq!(k0, BatchKey::Def { rank: 0, tid: 10, class_id: 5 });
        assert_eq!(enc.key_for(0, 10, 5), BatchKey::Ref(0));
        assert_eq!(enc.key_for(1, 11, 5), BatchKey::Def { rank: 1, tid: 11, class_id: 5 });
        assert_eq!(enc.key_for(1, 11, 5), BatchKey::Ref(1));
        assert_eq!(dec.resolve(k0).unwrap(), (0, 10, 5));
        assert_eq!(dec.resolve(BatchKey::Ref(0)).unwrap(), (0, 10, 5));
        assert_eq!(dec.resolve(BatchKey::Def { rank: 1, tid: 11, class_id: 5 }).unwrap(), (1, 11, 5));
        assert_eq!(dec.resolve(BatchKey::Ref(1)).unwrap(), (1, 11, 5));
        // an undefined ref is a structured error, not a panic
        assert!(matches!(dec.resolve(BatchKey::Ref(7)), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn decode_batch_into_matches_decode_body_and_reuses_its_scratch() {
        let frame = Frame::EventBatch {
            stream: 4,
            events: vec![
                BatchEvent {
                    ts: 50,
                    key: BatchKey::Def { rank: 0, tid: 7, class_id: 3 },
                    fields: vec![FieldValue::Ptr(0xdead), FieldValue::U64(2)],
                },
                BatchEvent { ts: 49, key: BatchKey::Ref(0), fields: vec![] },
                BatchEvent { ts: 60, key: BatchKey::Ref(0), fields: vec![FieldValue::I64(-5)] },
            ],
        };
        let mut wire = Vec::new();
        encode(&frame, &mut wire);
        let body = &wire[4..];
        assert!(is_event_batch(body));

        let mut dict = BatchDict::new();
        let mut seen = Vec::new();
        let (stream, n) = decode_batch_into(body, &mut dict, |ts, rank, tid, class_id, fields| {
            seen.push((ts, rank, tid, class_id, fields.clone()));
        })
        .unwrap();
        assert_eq!((stream, n), (4, 3));
        assert_eq!(
            seen,
            vec![
                (50, 0, 7, 3, vec![FieldValue::Ptr(0xdead), FieldValue::U64(2)]),
                (49, 0, 7, 3, vec![]),
                (60, 0, 7, 3, vec![FieldValue::I64(-5)]),
            ]
        );
        // and the generic decoder agrees on the same bytes
        assert_eq!(decode_body(body).unwrap(), frame);
    }

    #[test]
    fn hostile_resume_cursor_counts_are_rejected_not_allocated() {
        // a 17-byte Resume frame claiming u32::MAX cursors must fail on
        // the missing bytes, never pre-allocate the claimed table
        let mut body = vec![0x08u8];
        body.extend_from_slice(&1u64.to_le_bytes()); // epoch
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // cursor-count lie
        assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn incomplete_prefix_is_not_an_error() {
        let mut buf = Vec::new();
        encode(&Frame::Streams { count: 1 }, &mut buf);
        for cut in 0..buf.len() {
            assert_eq!(decode(&buf[..cut]).unwrap(), None, "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_inputs_are_rejected_not_misread() {
        // zero-length frame
        assert!(matches!(decode(&[0, 0, 0, 0, 0]), Err(FrameError::BadLength(0))));
        // absurd length prefix must not allocate
        let huge = (MAX_FRAME_LEN as u32 + 1).to_le_bytes();
        assert!(matches!(decode(&huge), Err(FrameError::BadLength(_))));
        // unknown frame type
        let mut buf = Vec::new();
        encode(&Frame::Close { stream: 0 }, &mut buf);
        buf[4] = 0x7f;
        assert!(matches!(decode(&buf), Err(FrameError::BadFrameType(0x7f))));
        // trailing garbage inside the declared body length
        let mut buf = Vec::new();
        encode(&Frame::Close { stream: 0 }, &mut buf);
        buf.push(0xee);
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) + 1;
        buf[..4].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(decode(&buf), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn preamble_roundtrip_and_rejection() {
        let mut buf = Vec::new();
        write_preamble(&mut buf).unwrap();
        assert_eq!(read_preamble(&mut &buf[..]).unwrap(), VERSION);

        // the explicit-version writer covers the v2 downgrade path
        let mut v2 = Vec::new();
        write_preamble_version(&mut v2, 2).unwrap();
        assert_eq!(read_preamble(&mut &v2[..]).unwrap(), 2);

        let mut bad = buf.clone();
        bad[0] = b'X';
        let err = read_preamble(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // neither the retired v1 nor a future v4 is accepted: cross-
        // version guessing past a layout change would mis-parse
        for unsupported in [1u32, 4] {
            let mut other = buf.clone();
            other[4..8].copy_from_slice(&unsupported.to_le_bytes());
            let err = read_preamble(&mut &other[..]).unwrap_err();
            assert!(err.to_string().contains(&format!("version {unsupported}")), "{err}");
        }
    }

    #[test]
    fn back_to_back_frames_decode_sequentially() {
        let mut buf = Vec::new();
        let frames = vec![
            Frame::Streams { count: 2 },
            Frame::Beacon { stream: 1, watermark: 10 },
            Frame::Eos { received: 5, dropped: 0 },
        ];
        for f in &frames {
            encode(f, &mut buf);
        }
        let mut off = 0;
        let mut got = Vec::new();
        while off < buf.len() {
            let (f, n) = decode(&buf[off..]).unwrap().unwrap();
            got.push(f);
            off += n;
        }
        assert_eq!(got, frames);
    }

    #[test]
    fn oversized_strings_truncate_on_char_boundaries() {
        // 'é' is 2 bytes; an odd-length cut must step back to a boundary
        let big: String = "é".repeat(40_000); // 80_000 bytes > u16::MAX
        let mut buf = Vec::new();
        encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 0,
                    rank: 0,
                    tid: 0,
                    class_id: 0,
                    fields: vec![FieldValue::Str(big)],
                },
            },
            &mut buf,
        );
        // the truncated wire must still decode as valid UTF-8
        let (back, _) = decode(&buf).unwrap().unwrap();
        let Frame::Event { event, .. } = back else { panic!("wrong frame") };
        let FieldValue::Str(s) = &event.fields[0] else { panic!("wrong field") };
        assert!(s.len() <= u16::MAX as usize);
        assert!(s.chars().all(|c| c == 'é'), "no mangled tail character");
    }

    #[test]
    fn nan_payloads_survive_by_bits() {
        let mut buf = Vec::new();
        encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 1,
                    rank: 0,
                    tid: 0,
                    class_id: 0,
                    fields: vec![FieldValue::F64(f64::NAN)],
                },
            },
            &mut buf,
        );
        let (back, _) = decode(&buf).unwrap().unwrap();
        let Frame::Event { event, .. } = back else { panic!("wrong frame") };
        let FieldValue::F64(v) = event.fields[0] else { panic!("wrong field") };
        assert_eq!(v.to_bits(), f64::NAN.to_bits(), "NaN must round-trip bit-exactly");
    }
}
