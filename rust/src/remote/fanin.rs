//! Multi-publisher fan-in: one subscriber merging several nodes' hubs
//! (`iprof attach <addr> <addr>...`).
//!
//! [`FanIn::open`] handshakes N independent THRL connections (preamble +
//! [`Frame::Hello`], each publisher shipping its own BTF class registry),
//! registers each publisher as an **origin** of one shared mirror
//! [`LiveHub`], and spawns one reader thread per connection. Readers
//! translate every per-publisher stream id through the origin's map
//! before touching the hub — events feed the translated channel
//! losslessly, **watermark beacons move the translated channel's
//! watermark**, closes close it — so the release predicate the merge
//! runs is exactly the shared one over the union of all publishers'
//! channels, and the **unmodified** [`LiveSource`] k-way merge drains
//! the union in one globally consistent order.
//!
//! Two properties carry the design (pinned by `rust/tests/fanin.rs`):
//!
//! 1. **Concatenation byte-identity.** Origin blocks are allocated in
//!    connection order at handshake time, so shared channel index order
//!    is the concatenation of the publishers' stream sets. For lossless
//!    feeds, attaching to N publishers produces sink output
//!    byte-identical to a single local `--live` run over that
//!    concatenated stream set — equal-timestamp ties break by
//!    (connection order, per-publisher stream index, arrival order),
//!    independent of network interleaving.
//! 2. **Failure isolation.** A publisher that dies (EOF or protocol
//!    error before [`Frame::Eos`]) has *only its own* origin's channels
//!    closed ([`LiveHub::close_origin`]); every other feed keeps
//!    flowing, and the analysis completes over everything received —
//!    partial but correct, with the error recorded in that publisher's
//!    [`RemoteStats`]. The last reader to finish seals the whole hub so
//!    the merge terminates exactly once.
//!
//! Single-publisher [`Attachment`](super::attach::Attachment) is the
//! N = 1 special case and delegates here.

use super::frame::{self, Frame, FrameError};
use crate::analysis::EventMsg;
use crate::live::{LiveHub, LiveSource};
use crate::tracer::btf::{parse_metadata, DecodedClass};
use std::collections::HashMap;
use std::io::{self, BufReader, Read};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// What one reader thread observed over its whole connection.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Frames received (Hello included).
    pub frames: u64,
    /// Event frames among them.
    pub events: u64,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Events skipped because their class id was not in the Hello
    /// metadata (same skip-unknown policy as `parse_trace`).
    pub unknown_classes: u64,
    /// Publisher-side total accepted messages (from Eos).
    pub server_received: u64,
    /// Publisher-side total dropped messages (from Eos) — the remote
    /// end of the drop accounting: nonzero means the on-line view is
    /// incomplete and says by exactly how much.
    pub server_dropped: u64,
    /// Transport/protocol error that ended the stream before a clean
    /// Eos, if any. Only this publisher's channels are closed on error,
    /// so everything received up to the cut is still merged and
    /// analyzed — and, in a fan-in, every *other* publisher's feed
    /// keeps flowing.
    pub error: Option<String>,
}

/// Per-connection aggregate of a whole fan-in run, in connection order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FanInStats {
    /// One entry per publisher, in [`FanIn::open`] connection order.
    pub per: Vec<RemoteStats>,
}

impl FanInStats {
    /// Sum of publisher-side accepted totals (saturating).
    pub fn server_received(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.server_received))
    }

    /// Sum of publisher-side dropped totals (saturating). Zero certifies
    /// the union analysis covers every event every publisher decoded.
    pub fn server_dropped(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.server_dropped))
    }

    /// Publishers that ended without a clean Eos.
    pub fn failed(&self) -> usize {
        self.per.iter().filter(|s| s.error.is_some()).count()
    }
}

/// Post-handshake state of one connection, before its reader spawns.
struct Pending<R: Read> {
    r: BufReader<R>,
    hostname: String,
    classes: HashMap<u32, Arc<DecodedClass>>,
}

/// A live fan-in over N remote publishers (see module docs).
pub struct FanIn {
    hub: Arc<LiveHub>,
    readers: Vec<JoinHandle<RemoteStats>>,
    /// Hostname announced by each publisher's Hello, in connection order.
    pub hostnames: Vec<String>,
}

impl FanIn {
    /// Handshake every connection and start mirroring them all into one
    /// shared hub.
    ///
    /// Handshakes run synchronously in connection order, so bad magic,
    /// an unsupported version, a missing Hello or a hostile stream count
    /// on *any* connection fails here, before anything starts. Origin
    /// channel blocks are allocated in the same order, which fixes the
    /// merge tie-break to the concatenated stream layout. `depth` bounds
    /// the readers' shared soft cap exactly as it does for a single
    /// [`Attachment`](super::attach::Attachment): `depth × (total shared
    /// channels)`, computed union-wide so K readers throttle at the same
    /// backlog one would (see [`LiveHub::feed_remote`]).
    pub fn open<R: Read + Send + 'static>(conns: Vec<R>, depth: usize) -> io::Result<FanIn> {
        if conns.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "fan-in needs at least one connection",
            ));
        }
        let mut pending = Vec::with_capacity(conns.len());
        let mut announced = Vec::with_capacity(conns.len());
        for conn in conns {
            let mut r = BufReader::new(conn);
            frame::read_preamble(&mut r)?;
            let hello = frame::read_frame(&mut r)?;
            let Frame::Hello { hostname, metadata, streams } = hello else {
                return Err(FrameError::Malformed("first frame must be Hello").into());
            };
            if streams > frame::MAX_STREAMS {
                return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
            }
            let md = parse_metadata(&metadata)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
            let classes: HashMap<u32, Arc<DecodedClass>> =
                md.classes.into_iter().map(|(id, c)| (id, Arc::new(c))).collect();
            pending.push(Pending { r, hostname, classes });
            announced.push(streams as usize);
        }

        // One shared mirror hub; every origin's Hello-announced block is
        // allocated BEFORE any reader runs, in connection order — the
        // shared channel layout is the concatenation of the publishers'
        // stream sets, which is the whole byte-identity story.
        let hub = LiveHub::new(&pending[0].hostname, depth, false);
        let origins: Vec<usize> = pending
            .iter()
            .zip(&announced)
            .map(|(p, &n)| {
                let o = hub.register_origin(&p.hostname);
                hub.ensure_origin_channels(o, n);
                o
            })
            .collect();

        let depth = depth.max(1);
        let remaining = Arc::new(AtomicUsize::new(pending.len()));
        let mut readers = Vec::with_capacity(pending.len());
        let mut hostnames = Vec::with_capacity(pending.len());
        for (i, p) in pending.into_iter().enumerate() {
            let origin = origins[i];
            hostnames.push(p.hostname.clone());
            let host_arc: Arc<str> = Arc::from(p.hostname.as_str());
            let hub2 = hub.clone();
            let remaining2 = remaining.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("thapi-fanin-{i}"))
                .spawn(move || {
                    let Pending { mut r, classes, .. } = p;
                    let mut stats = RemoteStats { frames: 1, ..Default::default() };
                    let mut map = hub2.origin_map(origin);
                    let res = pump(
                        &mut r, &hub2, origin, &classes, &host_arc, depth, &mut map, &mut stats,
                    );
                    // Always end THIS origin's channels — also on
                    // transport errors — so the union merge never waits
                    // on a dead publisher; the other feeds keep flowing.
                    // The last reader out seals the whole hub so the
                    // merge terminates.
                    hub2.close_origin(origin);
                    if remaining2.fetch_sub(1, Ordering::AcqRel) == 1 {
                        hub2.close_all();
                    }
                    if let Err(e) = res {
                        stats.error = Some(e.to_string());
                    }
                    stats
                });
            match spawned {
                Ok(handle) => readers.push(handle),
                Err(e) => {
                    // Thread creation failed mid-loop (resource pressure):
                    // already-spawned readers cannot be cancelled, but the
                    // hub must stay consistent for them — close every
                    // origin that will never get a reader and retire their
                    // countdown slots so the last LIVE reader still seals
                    // the hub instead of waiting on ghosts.
                    for &o in &origins[i..] {
                        hub.close_origin(o);
                    }
                    let unspawned = origins.len() - i;
                    if remaining.fetch_sub(unspawned, Ordering::AcqRel) == unspawned {
                        hub.close_all();
                    }
                    return Err(e);
                }
            }
        }
        Ok(FanIn { hub, readers, hostnames })
    }

    /// The shared mirror hub (e.g. for [`LiveHub::stats`] /
    /// [`LiveHub::origin_stats`] after the run).
    pub fn hub(&self) -> &Arc<LiveHub> {
        &self.hub
    }

    /// Open the merge over the shared mirror hub: one [`LiveSource`]
    /// drains the union of every publisher's channels.
    pub fn source(&self) -> LiveSource {
        LiveSource::new(self.hub.clone())
    }

    /// Join every reader and return the per-publisher connection totals,
    /// in connection order. Call after the merge has drained. A
    /// publisher that died keeps its partial accounting with
    /// [`RemoteStats::error`] set, rather than poisoning the rest.
    pub fn finish(self) -> io::Result<FanInStats> {
        let mut per = Vec::with_capacity(self.readers.len());
        for handle in self.readers {
            let stats = handle.join().map_err(|_| {
                io::Error::new(io::ErrorKind::Other, "fan-in reader thread panicked")
            })?;
            per.push(stats);
        }
        Ok(FanInStats { per })
    }
}

/// Frame pump for one origin: apply every frame to the shared hub —
/// through the origin's stream-id translation — until Eos.
///
/// `map` is the reader's cache of its origin's remote→shared channel
/// map, so the hot Event path takes no extra hub lock; only this reader
/// grows its own origin, so the cache never goes stale. Stream counts
/// and indices are bounded by [`frame::MAX_STREAMS`]: a corrupt frame
/// is a protocol error, never a giant allocation.
#[allow(clippy::too_many_arguments)]
fn pump(
    r: &mut impl Read,
    hub: &LiveHub,
    origin: usize,
    classes: &HashMap<u32, Arc<DecodedClass>>,
    hostname: &Arc<str>,
    depth: usize,
    map: &mut Vec<usize>,
    stats: &mut RemoteStats,
) -> io::Result<()> {
    fn translate(
        hub: &LiveHub,
        origin: usize,
        map: &mut Vec<usize>,
        remote: u32,
    ) -> io::Result<usize> {
        if remote >= frame::MAX_STREAMS {
            return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
        }
        let remote = remote as usize;
        if remote >= map.len() {
            hub.ensure_origin_channels(origin, remote + 1);
            *map = hub.origin_map(origin);
        }
        Ok(map[remote])
    }

    loop {
        let f = frame::read_frame(r)?;
        stats.frames += 1;
        match f {
            Frame::Hello { .. } => {
                return Err(FrameError::Malformed("duplicate Hello").into());
            }
            Frame::Streams { count } => {
                if count > frame::MAX_STREAMS {
                    return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
                }
                if count as usize > map.len() {
                    hub.ensure_origin_channels(origin, count as usize);
                    *map = hub.origin_map(origin);
                }
            }
            Frame::Event { stream, event } => {
                let idx = translate(hub, origin, map, stream)?;
                stats.events += 1;
                match classes.get(&event.class_id) {
                    Some(class) => {
                        let msg = EventMsg {
                            ts: event.ts,
                            rank: event.rank,
                            tid: event.tid,
                            hostname: hostname.clone(),
                            class: class.clone(),
                            fields: event.fields,
                        };
                        hub.feed_remote(idx, msg, depth);
                    }
                    None => stats.unknown_classes += 1,
                }
            }
            Frame::Beacon { stream, watermark } => {
                // The watermark promise travels WITH the stream into its
                // shared channel: the merge's release predicate stays
                // exactly the shared one over the whole union.
                let idx = translate(hub, origin, map, stream)?;
                hub.beacon(idx, watermark);
                stats.beacons += 1;
            }
            Frame::Drops { stream, dropped } => {
                if stream >= frame::MAX_STREAMS {
                    return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
                }
                // Cumulative per-stream publisher-side counts: keep the
                // per-origin ledger (saturating) so the fan-in summary
                // can attribute loss to the node that suffered it.
                hub.record_origin_drops(origin, stream as usize, dropped);
            }
            Frame::Close { stream } => {
                let idx = translate(hub, origin, map, stream)?;
                hub.close(idx);
            }
            Frame::Eos { received, dropped } => {
                stats.server_received = received;
                stats.server_dropped = dropped;
                hub.record_origin_eos(origin, received, dropped);
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::LiveHub;
    use crate::remote::publish::publish;

    fn sample_msg(hub: &LiveHub, ts: u64, rank: u32) -> EventMsg {
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        hub.decode(rank, 7, class.id, ts, &0u64.to_le_bytes()).unwrap()
    }

    /// Publish a tiny 1-stream hub to a wire, tagging events with `rank`.
    fn wire_for(rank: u32, timestamps: &[u64]) -> Vec<u8> {
        let hub = LiveHub::new("fan", 64, false);
        hub.ensure_channels(1);
        hub.push_batch(0, timestamps.iter().map(|&t| sample_msg(&hub, t, rank)).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        wire
    }

    #[test]
    fn two_publishers_merge_into_one_ordered_union() {
        let a = wire_for(0, &[5, 10]);
        let b = wire_for(1, &[7, 12]);
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        assert_eq!(fan.hostnames, vec!["fan".to_string(), "fan".to_string()]);
        let merged: Vec<(u64, u32)> = fan.source().map(|m| (m.ts, m.rank)).collect();
        assert_eq!(merged, vec![(5, 0), (7, 1), (10, 0), (12, 1)]);
        let stats = fan.finish().unwrap();
        assert_eq!(stats.per.len(), 2);
        assert_eq!(stats.per[0].events, 2);
        assert_eq!(stats.per[1].events, 2);
        assert_eq!(stats.server_received(), 4);
        assert_eq!(stats.server_dropped(), 0);
        assert_eq!(stats.failed(), 0);
    }

    #[test]
    fn equal_timestamps_break_ties_by_connection_order() {
        // both publishers call their stream "0" and collide on ts too:
        // namespacing must keep both events and order them by origin
        let a = wire_for(0, &[100]);
        let b = wire_for(1, &[100]);
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        let merged: Vec<(u64, u32)> = fan.source().map(|m| (m.ts, m.rank)).collect();
        assert_eq!(merged, vec![(100, 0), (100, 1)], "no aliasing, origin-order ties");
        let origins = fan.hub().origin_stats();
        assert_eq!(origins.len(), 2);
        assert_eq!(origins[0].received, 1);
        assert_eq!(origins[1].received, 1);
        fan.finish().unwrap();
    }

    #[test]
    fn empty_connection_list_is_rejected() {
        let err = FanIn::open(Vec::<std::io::Cursor<Vec<u8>>>::new(), 8).unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
    }

    #[test]
    fn bad_handshake_on_any_connection_fails_synchronously() {
        let good = wire_for(0, &[1]);
        let mut bad = Vec::new();
        bad.extend_from_slice(&frame::MAGIC);
        bad.extend_from_slice(&99u32.to_le_bytes());
        let err = FanIn::open(
            vec![std::io::Cursor::new(good), std::io::Cursor::new(bad)],
            8,
        )
        .unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn dead_publisher_closes_only_its_origin() {
        let a = wire_for(0, &[1, 2, 3]);
        let mut b = wire_for(1, &[4, 5, 6]);
        b.truncate(b.len().saturating_sub(10)); // kill B before Eos
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        let merged = fan.source().count();
        assert!(merged >= 3, "all of A must survive B's death (got {merged})");
        let stats = fan.finish().unwrap();
        assert!(stats.per[0].error.is_none());
        assert!(stats.per[1].error.is_some(), "{:?}", stats.per[1]);
        assert_eq!(stats.failed(), 1);
        assert_eq!(stats.per[0].server_received, 3, "A's Eos accounting intact");
    }
}
