//! Multi-publisher fan-in: one subscriber merging several nodes' hubs
//! (`iprof attach <addr> <addr>...`).
//!
//! [`FanIn::open`] handshakes N independent THRL connections (preamble +
//! [`Frame::Hello`], each publisher shipping its own BTF class registry),
//! registers each publisher as an **origin** of one shared mirror
//! [`LiveHub`], and spawns one reader thread per connection. Readers
//! translate every per-publisher stream id through the origin's map
//! before touching the hub — events feed the translated channel
//! losslessly, **watermark beacons move the translated channel's
//! watermark**, closes close it — so the release predicate the merge
//! runs is exactly the shared one over the union of all publishers'
//! channels, and the **unmodified** [`LiveSource`] k-way merge drains
//! the union in one globally consistent order.
//!
//! Three properties carry the design (pinned by `rust/tests/fanin.rs`):
//!
//! 1. **Concatenation byte-identity.** Origin blocks are allocated in
//!    connection order at handshake time, so shared channel index order
//!    is the concatenation of the publishers' stream sets. For lossless
//!    feeds, attaching to N publishers produces sink output
//!    byte-identical to a single local `--live` run over that
//!    concatenated stream set — equal-timestamp ties break by
//!    (connection order, per-publisher stream index, arrival order),
//!    independent of network interleaving.
//! 2. **Failure isolation.** A publisher that dies (EOF or protocol
//!    error before [`Frame::Eos`]) has *only its own* origin's channels
//!    closed ([`LiveHub::close_origin`]); every other feed keeps
//!    flowing, and the analysis completes over everything received —
//!    partial but correct, with the error recorded in that publisher's
//!    [`RemoteStats`]. The last reader to finish seals the whole hub so
//!    the merge terminates exactly once.
//! 3. **Reconnect/resume.** With [`FanIn::open_resumable`] a dropped
//!    connection to a *resumable* publisher (session epoch ≠ 0, see
//!    `docs/PROTOCOL.md` § Session resumption) is not a death: the
//!    origin's reader redials with exponential backoff, validates the
//!    epoch, and sends a [`Frame::Resume`] carrying its per-stream
//!    delivered cursors; the publisher replays the lost tail from its
//!    ring so the merged output stays **byte-identical to an
//!    uninterrupted run**. During the outage the origin's channels stay
//!    open — the union merge holds, exactly as it would for a quiet
//!    publisher, which is what preserves byte-identity. A cursor that
//!    fell out of the ring arrives back as [`Frame::ResumeGap`] and is
//!    booked into the origin's drops ledger
//!    ([`LiveHub::record_origin_gap`]) instead of killing the feed.
//!
//! Single-publisher [`Attachment`](super::attach::Attachment) is the
//! N = 1 special case and delegates here.

use super::frame::{self, Frame, FrameError};
use crate::analysis::EventMsg;
use crate::live::{LiveHub, LiveSource};
use crate::telemetry::{origin_series_label, Counter, Registry};
use crate::tracer::btf::{parse_metadata, DecodedClass};
use std::collections::HashMap;
use std::io::{self, BufReader, Read, Write};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// What one reader thread observed over its whole connection.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Frames received (Hello included).
    pub frames: u64,
    /// Events among them — *events*, not frames: a v3 batch of n events
    /// adds n here and 1 to both `frames` and `batches`.
    pub events: u64,
    /// `EventBatch` frames received. 0 on a v2 connection — together
    /// with `wire_version` this is the per-origin negotiation outcome
    /// (batched v3 vs per-event fallback) the attach summary reports.
    pub batches: u64,
    /// Wire version the publisher's preamble announced (the publisher
    /// picks; see `docs/PROTOCOL.md` § Versioning).
    pub wire_version: u32,
    /// Beacon frames among them.
    pub beacons: u64,
    /// Events skipped because their class id was not in the Hello
    /// metadata (same skip-unknown policy as `parse_trace`).
    pub unknown_classes: u64,
    /// Publisher-side total accepted messages (from Eos).
    pub server_received: u64,
    /// Publisher-side total dropped messages (from Eos) — the remote
    /// end of the drop accounting: nonzero means the on-line view is
    /// incomplete and says by exactly how much.
    pub server_dropped: u64,
    /// Successful session resumes on this connection (each one is a
    /// redial + epoch check + [`Frame::Resume`] handshake that worked).
    pub reconnects: u64,
    /// Events lost to resume gaps: the publisher's replay ring evicted
    /// them before this subscriber reconnected ([`Frame::ResumeGap`]
    /// totals; also booked per origin in the hub's drops ledger).
    pub resume_gap: u64,
    /// Transport/protocol error that ended the stream before a clean
    /// Eos, if any — after any reconnect budget was exhausted. Only
    /// this publisher's channels are closed on error, so everything
    /// received up to the cut is still merged and analyzed — and, in a
    /// fan-in, every *other* publisher's feed keeps flowing.
    pub error: Option<String>,
}

/// When and how hard a fan-in reader tries to re-join a resumable
/// publisher after its connection drops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReconnectPolicy {
    /// Redial attempts per disconnect (0 = never reconnect — every
    /// drop is final, the pre-resume behaviour). A successful resume
    /// refills the budget, so a long-lived flapping publisher gets
    /// `attempts` tries at every new outage.
    pub attempts: u32,
    /// Delay before the first redial of an outage; doubles per failed
    /// attempt, capped at 5 s.
    pub backoff: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy { attempts: 0, backoff: Duration::from_millis(250) }
    }
}

impl ReconnectPolicy {
    /// Never reconnect (every disconnect is final).
    pub fn none() -> Self {
        Self::default()
    }

    /// Backoff before redial `attempt` (0-based): exponential doubling
    /// from [`ReconnectPolicy::backoff`], capped at 5 s.
    pub fn delay(&self, attempt: u32) -> Duration {
        let factor = 1u32 << attempt.min(16);
        self.backoff.saturating_mul(factor).min(Duration::from_secs(5))
    }
}

/// Per-connection aggregate of a whole fan-in run, in connection order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FanInStats {
    /// One entry per publisher, in [`FanIn::open`] connection order.
    pub per: Vec<RemoteStats>,
}

impl FanInStats {
    /// Sum of publisher-side accepted totals (saturating).
    pub fn server_received(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.server_received))
    }

    /// Sum of publisher-side dropped totals (saturating). Zero certifies
    /// the union analysis covers every event every publisher decoded.
    pub fn server_dropped(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.server_dropped))
    }

    /// Publishers that ended without a clean Eos.
    pub fn failed(&self) -> usize {
        self.per.iter().filter(|s| s.error.is_some()).count()
    }

    /// Successful session resumes across every connection.
    pub fn reconnects(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.reconnects))
    }

    /// Events lost to resume gaps across every connection (saturating).
    pub fn resume_gaps(&self) -> u64 {
        self.per.iter().fold(0u64, |a, s| a.saturating_add(s.resume_gap))
    }
}

/// Wraps a read-only transport so the shared fan-in machinery can hold
/// every connection as `Read + Write`. Only a *resumable* publisher
/// (epoch ≠ 0) ever provokes a write — against a read-only transport
/// that surfaces as a clean `Unsupported` error at handshake time,
/// pointing at [`FanIn::open_resumable`].
struct ReadOnly<R>(R);

impl<R: Read> Read for ReadOnly<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.0.read(buf)
    }
}

impl<R> Write for ReadOnly<R> {
    fn write(&mut self, _buf: &[u8]) -> io::Result<usize> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "resumable publisher needs a writable connection (use FanIn::open_resumable)",
        ))
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Post-handshake state of one connection, before its reader spawns.
struct Pending<S: Read + Write, C> {
    r: BufReader<S>,
    /// Redials the same publisher (resumable attach); `None` for fixed
    /// transports.
    connector: Option<C>,
    /// Session epoch from the Hello (0 = not resumable).
    epoch: u64,
    /// Wire version the preamble announced (publisher-selected).
    wire: u32,
    hostname: String,
    classes: HashMap<u32, Arc<DecodedClass>>,
}

/// Preamble + Hello on a fresh connection; a *resumable* publisher
/// (epoch ≠ 0) is answered with a [`Frame::Resume`] carrying `cursors`
/// (empty = deliver from the beginning). Returns the buffered reader
/// positioned at the first item frame plus the Hello contents and the
/// preamble's wire version.
fn handshake<S: Read + Write>(
    conn: S,
    cursors: &[u64],
) -> io::Result<(BufReader<S>, String, String, u32, u64, u32)> {
    let mut r = BufReader::new(conn);
    let wire = frame::read_preamble(&mut r)?;
    let hello = frame::read_frame(&mut r)?;
    let Frame::Hello { hostname, metadata, streams, epoch } = hello else {
        return Err(FrameError::Malformed("first frame must be Hello").into());
    };
    if streams > frame::MAX_STREAMS {
        return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
    }
    if epoch != 0 {
        frame::write_frame(r.get_mut(), &Frame::Resume { epoch, cursors: cursors.to_vec() })?;
        r.get_mut().flush()?;
    }
    Ok((r, hostname, metadata, streams, epoch, wire))
}

/// Type of one fully prepared connection: buffered reader positioned at
/// the first item frame, publisher hostname, its parsed class table,
/// the Hello-announced stream count, the session epoch, and the wire
/// version.
type Prepared<S> = (BufReader<S>, String, HashMap<u32, Arc<DecodedClass>>, usize, u64, u32);

/// [`handshake`] a fresh connection (empty cursors — deliver from the
/// beginning) and parse the publisher's BTF metadata into its class
/// table.
fn prepare<S: Read + Write>(conn: S) -> io::Result<Prepared<S>> {
    let (r, hostname, metadata, streams, epoch, wire) = handshake(conn, &[])?;
    let md = parse_metadata(&metadata)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    let classes: HashMap<u32, Arc<DecodedClass>> =
        md.classes.into_iter().map(|(id, c)| (id, Arc::new(c))).collect();
    Ok((r, hostname, classes, streams as usize, epoch, wire))
}

/// Pre-registered per-origin telemetry series one reader thread keeps
/// hot. Registered once at spawn (same index-prefixed label as the
/// hub's own origin mirrors — see [`origin_series_label`]); the pump
/// then mirrors its single-writer [`RemoteStats`] into them with
/// `store_max`, so a scrape always equals the reader's own accounting.
struct ReaderTelemetry {
    events: Arc<Counter>,
    frames: Arc<Counter>,
    reconnects: Arc<Counter>,
}

impl ReaderTelemetry {
    fn register(reg: &Registry, origin: usize, label: &str) -> ReaderTelemetry {
        let label = origin_series_label(origin, label);
        ReaderTelemetry {
            events: reg.origin_events.with_label(&label),
            frames: reg.origin_frames.with_label(&label),
            reconnects: reg.origin_reconnects.with_label(&label),
        }
    }
}

/// A live fan-in over N remote publishers (see module docs).
pub struct FanIn {
    hub: Arc<LiveHub>,
    readers: Vec<JoinHandle<RemoteStats>>,
    /// Hostname announced by each publisher's Hello, in connection order.
    pub hostnames: Vec<String>,
}

impl FanIn {
    /// Handshake every connection and start mirroring them all into one
    /// shared hub.
    ///
    /// Handshakes run synchronously in connection order, so bad magic,
    /// an unsupported version, a missing Hello or a hostile stream count
    /// on *any* connection fails here, before anything starts. Origin
    /// channel blocks are allocated in the same order, which fixes the
    /// merge tie-break to the concatenated stream layout. `depth` bounds
    /// the readers' shared soft cap exactly as it does for a single
    /// [`Attachment`](super::attach::Attachment): `depth × (total shared
    /// channels)`, computed union-wide so K readers throttle at the same
    /// backlog one would (see [`LiveHub::feed_remote`]).
    pub fn open<R: Read + Send + 'static>(conns: Vec<R>, depth: usize) -> io::Result<FanIn> {
        type NoDial<R> = fn() -> io::Result<ReadOnly<R>>;
        let mut pending: Vec<Pending<ReadOnly<R>, NoDial<R>>> = Vec::with_capacity(conns.len());
        let mut announced = Vec::with_capacity(conns.len());
        for conn in conns {
            let (r, hostname, classes, streams, epoch, wire) = prepare(ReadOnly(conn))?;
            pending.push(Pending { r, connector: None, epoch, wire, hostname, classes });
            announced.push(streams);
        }
        Self::finish_open(pending, announced, depth, ReconnectPolicy::none(), None)
    }

    /// Like [`FanIn::open`], but every connection comes from a
    /// `connector` that can redial its publisher, and a dropped
    /// connection to a resumable publisher is resumed under `policy`
    /// instead of being final (module docs, property 3). Each connector
    /// is dialed here for the synchronous handshake — in connection
    /// order, so the origin layout is identical to [`FanIn::open`] —
    /// and kept for redials. The reconnect budget covers this initial
    /// dial+handshake too (with the same backoff), so a publisher that
    /// is still starting up, or whose first connection dies mid-Hello,
    /// does not fail the whole attach.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// # fn main() -> std::io::Result<()> {
    /// use thapi::remote::{FanIn, ReconnectPolicy};
    /// use std::net::TcpStream;
    /// use std::time::Duration;
    ///
    /// let addrs = ["10.0.0.1:7007", "10.0.0.2:7007"];
    /// let connectors: Vec<_> = addrs
    ///     .iter()
    ///     .map(|a| move || TcpStream::connect(*a))
    ///     .collect();
    /// let policy = ReconnectPolicy { attempts: 5, backoff: Duration::from_millis(250) };
    /// let fan = FanIn::open_resumable(connectors, 1024, policy)?;
    /// for _msg in fan.source() {
    ///     // every publisher's events, one globally consistent order
    /// }
    /// let _stats = fan.finish()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn open_resumable<S, C>(
        connectors: Vec<C>,
        depth: usize,
        policy: ReconnectPolicy,
    ) -> io::Result<FanIn>
    where
        S: Read + Write + Send + 'static,
        C: FnMut() -> io::Result<S> + Send + 'static,
    {
        Self::open_resumable_labeled(connectors, depth, policy, None)
    }

    /// [`FanIn::open_resumable`] with an explicit label for the shared
    /// mirror hub (its hostname, hence the Hello identity of anything
    /// re-publishing this hub — `iprof relay --label`). `None` keeps
    /// the default: the first publisher's hostname.
    pub fn open_resumable_labeled<S, C>(
        connectors: Vec<C>,
        depth: usize,
        policy: ReconnectPolicy,
        label: Option<&str>,
    ) -> io::Result<FanIn>
    where
        S: Read + Write + Send + 'static,
        C: FnMut() -> io::Result<S> + Send + 'static,
    {
        let mut pending = Vec::with_capacity(connectors.len());
        let mut announced = Vec::with_capacity(connectors.len());
        for mut dial in connectors {
            let mut attempt = 0u32;
            let (r, hostname, classes, streams, epoch, wire) = loop {
                match dial().and_then(prepare) {
                    Ok(ok) => break ok,
                    Err(_) if attempt < policy.attempts => {
                        std::thread::sleep(policy.delay(attempt));
                        attempt += 1;
                    }
                    Err(e) => return Err(e),
                }
            };
            pending.push(Pending { r, connector: Some(dial), epoch, wire, hostname, classes });
            announced.push(streams);
        }
        Self::finish_open(pending, announced, depth, policy, label)
    }

    fn finish_open<S, C>(
        pending: Vec<Pending<S, C>>,
        announced: Vec<usize>,
        depth: usize,
        policy: ReconnectPolicy,
        label: Option<&str>,
    ) -> io::Result<FanIn>
    where
        S: Read + Write + Send + 'static,
        C: FnMut() -> io::Result<S> + Send + 'static,
    {
        if pending.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "fan-in needs at least one connection",
            ));
        }

        // One shared mirror hub; every origin's Hello-announced block is
        // allocated BEFORE any reader runs, in connection order — the
        // shared channel layout is the concatenation of the publishers'
        // stream sets, which is the whole byte-identity story.
        let hub = LiveHub::new(label.unwrap_or(&pending[0].hostname), depth, false);
        let origins: Vec<usize> = pending
            .iter()
            .zip(&announced)
            .map(|(p, &n)| {
                let o = hub.register_origin(&p.hostname);
                hub.ensure_origin_channels(o, n);
                o
            })
            .collect();

        let depth = depth.max(1);
        let remaining = Arc::new(AtomicUsize::new(pending.len()));
        let mut readers = Vec::with_capacity(pending.len());
        let mut hostnames = Vec::with_capacity(pending.len());
        for (i, p) in pending.into_iter().enumerate() {
            let origin = origins[i];
            hostnames.push(p.hostname.clone());
            let host_arc: Arc<str> = Arc::from(p.hostname.as_str());
            let hub2 = hub.clone();
            let remaining2 = remaining.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("thapi-fanin-{i}"))
                .spawn(move || {
                    let Pending { mut r, mut connector, epoch, wire, classes, .. } = p;
                    let mut stats =
                        RemoteStats { frames: 1, wire_version: wire, ..Default::default() };
                    hub2.record_origin_wire(origin, wire);
                    let tele = ReaderTelemetry::register(hub2.telemetry(), origin, &host_arc);
                    tele.frames.store_max(stats.frames);
                    let mut map = hub2.origin_map(origin);
                    let mut delivered: Vec<u64> = Vec::new();
                    // The batch dictionary is connection state on both
                    // ends: it resets on every resumed connection.
                    let mut dict = frame::BatchDict::new();
                    // Leaf-hostname stamps learned from a relay's Origin
                    // frames, per remote stream. Session state, not
                    // connection state: a resumed relay re-sends its
                    // entries anyway (monotone), and the mapping can only
                    // be refined, never invalidated.
                    let mut overrides: HashMap<u32, (usize, Arc<str>)> = HashMap::new();
                    // Progress bound: each successful resume refills the
                    // per-outage dial budget, so a pathological publisher
                    // that always completes the handshake and then dies
                    // without ever delivering a frame could spin forever.
                    // Count consecutive *barren* resumed connections (no
                    // frame received) and give up once they exceed the
                    // policy's own attempt budget.
                    let mut frames_checkpoint = stats.frames;
                    let mut barren = 0u32;
                    let res = loop {
                        match pump(
                            &mut r, &hub2, origin, &classes, &host_arc, depth, &mut map,
                            &mut dict, &mut overrides, &mut stats, &mut delivered, &tele,
                        ) {
                            Ok(()) => break Ok(()),
                            Err(e) => {
                                if stats.frames > frames_checkpoint {
                                    barren = 0;
                                } else {
                                    barren += 1;
                                }
                                frames_checkpoint = stats.frames;
                                if barren > policy.attempts {
                                    break Err(io::Error::new(
                                        e.kind(),
                                        format!(
                                            "{e} (gave up: {barren} consecutive resumed \
                                             connections delivered nothing)"
                                        ),
                                    ));
                                }
                                // A drop is final only once resume is off
                                // the table: non-resumable publisher, no
                                // redialer, epoch changed, or the retry
                                // budget ran dry. While we redial, the
                                // origin's channels stay OPEN: the union
                                // merge holds exactly as it would for a
                                // quiet publisher, which is what keeps a
                                // resumed run byte-identical to an
                                // uninterrupted one.
                                match try_resume(
                                    &mut connector, epoch, policy, &delivered, &mut stats,
                                ) {
                                    Ok((newr, wire)) => {
                                        // replayed events re-join the SAME
                                        // origin block; re-admit it in case
                                        // an earlier teardown closed it
                                        hub2.reopen_origin(origin);
                                        hub2.record_origin_wire(origin, wire);
                                        stats.wire_version = wire;
                                        tele.reconnects.store_max(stats.reconnects);
                                        dict.clear();
                                        r = newr;
                                    }
                                    Err(reason) => {
                                        break Err(io::Error::new(
                                            e.kind(),
                                            format!("{e} ({reason})"),
                                        ));
                                    }
                                }
                            }
                        }
                    };
                    // Always end THIS origin's channels — also on
                    // transport errors — so the union merge never waits
                    // on a dead publisher; the other feeds keep flowing.
                    // The last reader out seals the whole hub so the
                    // merge terminates.
                    hub2.close_origin(origin);
                    if remaining2.fetch_sub(1, Ordering::AcqRel) == 1 {
                        hub2.close_all();
                    }
                    if let Err(e) = res {
                        stats.error = Some(e.to_string());
                    }
                    stats
                });
            match spawned {
                Ok(handle) => readers.push(handle),
                Err(e) => {
                    // Thread creation failed mid-loop (resource pressure):
                    // already-spawned readers cannot be cancelled, but the
                    // hub must stay consistent for them — close every
                    // origin that will never get a reader and retire their
                    // countdown slots so the last LIVE reader still seals
                    // the hub instead of waiting on ghosts.
                    for &o in &origins[i..] {
                        hub.close_origin(o);
                    }
                    let unspawned = origins.len() - i;
                    if remaining.fetch_sub(unspawned, Ordering::AcqRel) == unspawned {
                        hub.close_all();
                    }
                    return Err(e);
                }
            }
        }
        Ok(FanIn { hub, readers, hostnames })
    }

    /// The shared mirror hub (e.g. for [`LiveHub::stats`] /
    /// [`LiveHub::origin_stats`] after the run).
    pub fn hub(&self) -> &Arc<LiveHub> {
        &self.hub
    }

    /// Open the merge over the shared mirror hub: one [`LiveSource`]
    /// drains the union of every publisher's channels.
    pub fn source(&self) -> LiveSource {
        LiveSource::new(self.hub.clone())
    }

    /// Join every reader and return the per-publisher connection totals,
    /// in connection order. Call after the merge has drained. A
    /// publisher that died keeps its partial accounting with
    /// [`RemoteStats::error`] set, rather than poisoning the rest.
    pub fn finish(self) -> io::Result<FanInStats> {
        let mut per = Vec::with_capacity(self.readers.len());
        for handle in self.readers {
            let stats = handle.join().map_err(|_| {
                io::Error::new(io::ErrorKind::Other, "fan-in reader thread panicked")
            })?;
            per.push(stats);
        }
        Ok(FanInStats { per })
    }
}

/// Redial and resume one origin after a disconnect: sleep out the
/// backoff, dial, re-handshake, verify the session epoch, and send a
/// [`Frame::Resume`] with our per-stream `delivered` cursors. `Ok`
/// hands back a freshly handshaken reader positioned right before the
/// publisher's replay; `Err(reason)` means the outage is final (no
/// redialer, non-resumable publisher, retries disabled or exhausted, or
/// the publisher restarted into a different epoch — where our cursors
/// would be meaningless, so they are never sent).
fn try_resume<S, C>(
    connector: &mut Option<C>,
    epoch: u64,
    policy: ReconnectPolicy,
    delivered: &[u64],
    stats: &mut RemoteStats,
) -> Result<(BufReader<S>, u32), String>
where
    S: Read + Write,
    C: FnMut() -> io::Result<S>,
{
    let Some(dial) = connector.as_mut() else {
        return Err("transport is not redialable".into());
    };
    if epoch == 0 {
        return Err("publisher is not resumable (session epoch 0)".into());
    }
    if policy.attempts == 0 {
        return Err("reconnect disabled".into());
    }
    for attempt in 0..policy.attempts {
        std::thread::sleep(policy.delay(attempt));
        let redialed = (|| -> io::Result<(BufReader<S>, u64, u32)> {
            let mut r = BufReader::new(dial()?);
            // The publisher picks the wire version per connection, so a
            // resumed connection re-learns it from the fresh preamble.
            let wire = frame::read_preamble(&mut r)?;
            let Frame::Hello { epoch: seen, streams, .. } = frame::read_frame(&mut r)? else {
                return Err(FrameError::Malformed("first frame must be Hello").into());
            };
            if streams > frame::MAX_STREAMS {
                return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
            }
            Ok((r, seen, wire))
        })();
        if let Ok((mut r, seen, wire)) = redialed {
            if seen != epoch {
                return Err(format!(
                    "session epoch changed ({epoch:#x} -> {seen:#x}): publisher restarted"
                ));
            }
            let resume = Frame::Resume { epoch, cursors: delivered.to_vec() };
            let sent = frame::write_frame(r.get_mut(), &resume).and(r.get_mut().flush());
            if sent.is_ok() {
                stats.reconnects = stats.reconnects.saturating_add(1);
                return Ok((r, wire));
            }
        }
        // transport-level failure: the publisher may still be coming
        // back — burn an attempt and back off harder
    }
    Err(format!("gave up after {} reconnect attempt(s)", policy.attempts))
}

/// Frame pump for one origin: apply every frame to the shared hub —
/// through the origin's stream-id translation — until Eos.
///
/// `map` is the reader's cache of its origin's remote→shared channel
/// map, so the hot Event path takes no extra hub lock; only this reader
/// grows its own origin, so the cache never goes stale. Stream counts
/// and indices are bounded by [`frame::MAX_STREAMS`]: a corrupt frame
/// is a protocol error, never a giant allocation.
///
/// `delivered[i]` counts the *events* fully processed per remote stream
/// — the resume cursors. A v3 batch advances it by its event count (the
/// publisher's ring sequence numbers count events, not frames), and
/// resume gaps advance it too: the publisher's sequence numbers cover
/// the evicted events, so a cursor that did not skip the gap would
/// misalign every later replay.
///
/// The hot path never materializes a [`Frame`]: [`frame::read_frame_into`]
/// reuses one body buffer, [`frame::is_event_batch`] routes batches to
/// [`frame::decode_batch_into`], and the decoded events go to the hub as
/// one [`LiveHub::feed_remote_batch`] push (one shard lock per batch).
#[allow(clippy::too_many_arguments)]
fn pump(
    r: &mut impl Read,
    hub: &LiveHub,
    origin: usize,
    classes: &HashMap<u32, Arc<DecodedClass>>,
    hostname: &Arc<str>,
    depth: usize,
    map: &mut Vec<usize>,
    dict: &mut frame::BatchDict,
    overrides: &mut HashMap<u32, (usize, Arc<str>)>,
    stats: &mut RemoteStats,
    delivered: &mut Vec<u64>,
    tele: &ReaderTelemetry,
) -> io::Result<()> {
    fn translate(
        hub: &LiveHub,
        origin: usize,
        map: &mut Vec<usize>,
        remote: u32,
    ) -> io::Result<usize> {
        if remote >= frame::MAX_STREAMS {
            return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
        }
        let remote = remote as usize;
        if remote >= map.len() {
            hub.ensure_origin_channels(origin, remote + 1);
            *map = hub.origin_map(origin);
        }
        Ok(map[remote])
    }

    let mut body: Vec<u8> = Vec::new();
    let mut batch: Vec<EventMsg> = Vec::new();
    loop {
        frame::read_frame_into(r, &mut body)?;
        stats.frames = stats.frames.saturating_add(1);
        tele.frames.store_max(stats.frames);
        if frame::is_event_batch(&body) {
            let mut unknown = 0u64;
            batch.clear();
            // Stamp with the leaf hostname when a relay's Origin frame
            // claimed this stream; the connection's Hello hostname
            // otherwise. A batch is single-stream, so one peek decides
            // the stamp for every event in it.
            let stamp = frame::batch_stream(&body)
                .and_then(|s| overrides.get(&s))
                .map_or_else(|| hostname.clone(), |(_, h)| h.clone());
            let (stream, n) =
                frame::decode_batch_into(&body, dict, |ts, rank, tid, class_id, fields| {
                    match classes.get(&class_id) {
                        Some(class) => batch.push(EventMsg {
                            ts,
                            rank,
                            tid,
                            hostname: stamp.clone(),
                            class: class.clone(),
                            fields: std::mem::take(fields),
                        }),
                        // same skip-unknown policy as the Event arm; the
                        // scratch buffer is simply reused for the next event
                        None => unknown += 1,
                    }
                })?;
            let idx = translate(hub, origin, map, stream)?;
            stats.events = stats.events.saturating_add(n as u64);
            stats.unknown_classes = stats.unknown_classes.saturating_add(unknown);
            stats.batches = stats.batches.saturating_add(1);
            tele.events.store_max(stats.events);
            hub.record_origin_batches(origin, 1);
            if !batch.is_empty() {
                hub.feed_remote_batch(idx, std::mem::take(&mut batch), depth);
            }
            // delivered AFTER processing, by the batch's full event count
            // — unknown-class events included, exactly like the publisher's
            // ring sequence numbers
            let s = stream as usize;
            if s >= delivered.len() {
                delivered.resize(s + 1, 0);
            }
            delivered[s] += n as u64;
            continue;
        }
        let f = frame::decode_body(&body).map_err(io::Error::from)?;
        match f {
            Frame::Hello { .. } => {
                return Err(FrameError::Malformed("duplicate Hello").into());
            }
            Frame::Streams { count } => {
                if count > frame::MAX_STREAMS {
                    return Err(FrameError::Malformed("stream count exceeds MAX_STREAMS").into());
                }
                if count as usize > map.len() {
                    hub.ensure_origin_channels(origin, count as usize);
                    *map = hub.origin_map(origin);
                }
            }
            Frame::Event { stream, event } => {
                let idx = translate(hub, origin, map, stream)?;
                stats.events = stats.events.saturating_add(1);
                tele.events.store_max(stats.events);
                let stamp = overrides
                    .get(&stream)
                    .map_or_else(|| hostname.clone(), |(_, h)| h.clone());
                match classes.get(&event.class_id) {
                    Some(class) => {
                        let msg = EventMsg {
                            ts: event.ts,
                            rank: event.rank,
                            tid: event.tid,
                            hostname: stamp,
                            class: class.clone(),
                            fields: event.fields,
                        };
                        hub.feed_remote(idx, msg, depth);
                    }
                    None => stats.unknown_classes = stats.unknown_classes.saturating_add(1),
                }
                // delivered AFTER processing: an event that errors out
                // above is re-requested by the next resume cursor
                let s = stream as usize;
                if s >= delivered.len() {
                    delivered.resize(s + 1, 0);
                }
                delivered[s] += 1;
            }
            Frame::EventBatch { .. } => {
                // is_event_batch() routed every batch through the
                // zero-copy path above before decode_body could run
                unreachable!("EventBatch is handled by the fast path")
            }
            Frame::Beacon { stream, watermark } => {
                // The watermark promise travels WITH the stream into its
                // shared channel: the merge's release predicate stays
                // exactly the shared one over the whole union.
                let idx = translate(hub, origin, map, stream)?;
                hub.beacon(idx, watermark);
                stats.beacons = stats.beacons.saturating_add(1);
            }
            Frame::Drops { stream, dropped } => {
                if stream >= frame::MAX_STREAMS {
                    return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
                }
                // Cumulative per-stream publisher-side counts: keep the
                // per-origin ledger (saturating) so the fan-in summary
                // can attribute loss to the node that suffered it.
                hub.record_origin_drops(origin, stream as usize, dropped);
            }
            Frame::Close { stream } => {
                let idx = translate(hub, origin, map, stream)?;
                hub.close(idx);
            }
            Frame::Eos { received, dropped } => {
                stats.server_received = received;
                stats.server_dropped = dropped;
                hub.record_origin_eos(origin, received, dropped);
                return Ok(());
            }
            Frame::Resume { .. } => {
                // strictly subscriber→publisher; a publisher echoing it
                // back is broken
                return Err(FrameError::Malformed("unexpected Resume from publisher").into());
            }
            Frame::ResumeGap { stream, missed } => {
                if stream >= frame::MAX_STREAMS {
                    return Err(FrameError::Malformed("stream index exceeds MAX_STREAMS").into());
                }
                // The replay ring evicted `missed` events we never got:
                // book them into the origin's drops ledger (the merged
                // view is incomplete by exactly that many events — the
                // strict gate fails on it) and advance our cursor past
                // the publisher's now-unreachable sequence numbers.
                hub.record_origin_gap(origin, stream as usize, missed);
                stats.resume_gap = stats.resume_gap.saturating_add(missed);
                let s = stream as usize;
                if s >= delivered.len() {
                    delivered.resize(s + 1, 0);
                }
                delivered[s] = delivered[s].saturating_add(missed);
            }
            Frame::Origin { path, hostname: leaf, streams, dropped, resume_gaps, eos } => {
                // An aggregating relay's per-leaf accounting entry
                // (hierarchical origin id): book it as a sub-origin of
                // this connection's origin, keyed by path and
                // max-merged, so drop/eos/gap ledgers and telemetry
                // series survive re-aggregation per LEAF — two relays
                // each forwarding a "0:nodeA" land in different parent
                // books and can never alias. The streams are in the
                // relay's id space, i.e. this connection's.
                for &s in &streams {
                    if s >= frame::MAX_STREAMS {
                        return Err(
                            FrameError::Malformed("stream index exceeds MAX_STREAMS").into()
                        );
                    }
                }
                hub.record_origin_child(origin, &path, &leaf, &streams, dropped, resume_gaps, eos);
                // Remember the leaf hostname per stream for event
                // stamping — deepest path wins, so a leaf's own entry
                // beats its relay's umbrella entry in a 3-level tree.
                let depth_of = path.matches('/').count();
                let host: Arc<str> = Arc::from(leaf.as_str());
                for &s in &streams {
                    let keep = overrides.get(&s).is_some_and(|&(d, _)| d > depth_of);
                    if !keep {
                        overrides.insert(s, (depth_of, host.clone()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::LiveHub;
    use crate::remote::publish::publish;

    fn sample_msg(hub: &LiveHub, ts: u64, rank: u32) -> EventMsg {
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        hub.decode(rank, 7, class.id, ts, &0u64.to_le_bytes()).unwrap()
    }

    /// Publish a tiny 1-stream hub to a wire, tagging events with `rank`.
    fn wire_for(rank: u32, timestamps: &[u64]) -> Vec<u8> {
        let hub = LiveHub::new("fan", 64, false);
        hub.ensure_channels(1);
        hub.push_batch(0, timestamps.iter().map(|&t| sample_msg(&hub, t, rank)).collect());
        hub.close_all();
        let mut wire = Vec::new();
        publish(&hub, &mut wire).unwrap();
        wire
    }

    #[test]
    fn two_publishers_merge_into_one_ordered_union() {
        let a = wire_for(0, &[5, 10]);
        let b = wire_for(1, &[7, 12]);
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        assert_eq!(fan.hostnames, vec!["fan".to_string(), "fan".to_string()]);
        let merged: Vec<(u64, u32)> = fan.source().map(|m| (m.ts, m.rank)).collect();
        assert_eq!(merged, vec![(5, 0), (7, 1), (10, 0), (12, 1)]);
        let origins = fan.hub().origin_stats();
        assert_eq!(origins[0].wire_version, 3, "negotiation outcome surfaces per origin");
        assert!(origins[0].batches >= 1);
        let stats = fan.finish().unwrap();
        assert_eq!(stats.per.len(), 2);
        assert_eq!(stats.per[0].events, 2);
        assert_eq!(stats.per[1].events, 2);
        assert_eq!(stats.per[0].wire_version, 3, "default publisher speaks v3");
        assert!(stats.per[0].batches >= 1, "v3 events arrive batched");
        assert_eq!(stats.server_received(), 4);
        assert_eq!(stats.server_dropped(), 0);
        assert_eq!(stats.failed(), 0);
    }

    #[test]
    fn equal_timestamps_break_ties_by_connection_order() {
        // both publishers call their stream "0" and collide on ts too:
        // namespacing must keep both events and order them by origin
        let a = wire_for(0, &[100]);
        let b = wire_for(1, &[100]);
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        let merged: Vec<(u64, u32)> = fan.source().map(|m| (m.ts, m.rank)).collect();
        assert_eq!(merged, vec![(100, 0), (100, 1)], "no aliasing, origin-order ties");
        let origins = fan.hub().origin_stats();
        assert_eq!(origins.len(), 2);
        assert_eq!(origins[0].received, 1);
        assert_eq!(origins[1].received, 1);
        fan.finish().unwrap();
    }

    #[test]
    fn empty_connection_list_is_rejected() {
        let err = FanIn::open(Vec::<std::io::Cursor<Vec<u8>>>::new(), 8).unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
    }

    #[test]
    fn bad_handshake_on_any_connection_fails_synchronously() {
        let good = wire_for(0, &[1]);
        let mut bad = Vec::new();
        bad.extend_from_slice(&frame::MAGIC);
        bad.extend_from_slice(&99u32.to_le_bytes());
        let err = FanIn::open(
            vec![std::io::Cursor::new(good), std::io::Cursor::new(bad)],
            8,
        )
        .unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn dead_publisher_closes_only_its_origin() {
        let a = wire_for(0, &[1, 2, 3]);
        let mut b = wire_for(1, &[4, 5, 6]);
        b.truncate(b.len().saturating_sub(10)); // kill B before Eos
        let fan =
            FanIn::open(vec![std::io::Cursor::new(a), std::io::Cursor::new(b)], 8).unwrap();
        let merged = fan.source().count();
        assert!(merged >= 3, "all of A must survive B's death (got {merged})");
        let stats = fan.finish().unwrap();
        assert!(stats.per[0].error.is_none());
        assert!(stats.per[1].error.is_some(), "{:?}", stats.per[1]);
        assert_eq!(stats.failed(), 1);
        assert_eq!(stats.per[0].server_received, 3, "A's Eos accounting intact");
    }
}
