//! Hierarchical relay fan-in: the hub→wire pump every publisher shares,
//! plus the per-leaf accounting that lets collection compose into trees
//! (`iprof relay <listen-addr> <downstream-addr>...`).
//!
//! A relay node is simultaneously a [`FanIn`](super::fanin::FanIn)
//! subscriber — draining N downstream publishers into one mirror
//! [`LiveHub`] as namespaced origins — and a resumable
//! [`Broadcaster`](super::publish::Broadcaster) re-publishing the merged
//! union upstream. Two pieces make that composition exact instead of
//! lossy:
//!
//! 1. **One pump.** [`HubPump`] is the single implementation of the
//!    "drain forward batches out of a hub" loop that
//!    [`Publisher`](super::publish::Publisher) and
//!    [`Broadcaster`](super::publish::Broadcaster) both previously
//!    carried as private near-duplicates (`drain_to_ring`). Forward
//!    batches are destructive — exactly one cursor may own them — so
//!    the pump owns the [`ForwardCursor`] behind a mutex and callers
//!    only say what to do with each popped batch.
//! 2. **Hierarchical origin ids.** A relay's upstream connection
//!    carries one [`Frame::Origin`] per aggregated publisher
//!    (`docs/PROTOCOL.md` § Hierarchical origin ids): path-style ids
//!    (`0:relay1/0:nodeA`) plus the leaf's hostname, stream mapping and
//!    drop/eos/gap ledgers. The receiver books them as sub-origins of
//!    the relay's origin ([`LiveHub::record_origin_child`]) and stamps
//!    forwarded events with the *leaf* hostname — so a 2-level tree
//!    merges byte-identically to a flat N-way attach and per-leaf
//!    accounting survives at the root instead of aliasing on re-indexed
//!    origin labels.
//!
//! [`origin_snapshot`] builds the wire-ready entries from a hub;
//! re-sending on change plus max-merge on receipt make the frames
//! idempotent and reordering-tolerant, exactly like [`Frame::Drops`].
//! Ledger updates ride the next forward batch (eventual between
//! batches), and the broadcaster refreshes once more at seal — so the
//! totals are exact by Eos.

use super::frame::Frame;
use crate::live::{ForwardBatch, ForwardCursor, LiveHub};
use crate::telemetry::origin_series_label;
use std::sync::{Arc, Mutex};

/// The one hub→wire forward pump (see module docs). Wraps the hub's
/// destructive [`LiveHub::try_forward_batch`] /
/// [`LiveHub::next_forward_batch`] tee behind the session's single
/// [`ForwardCursor`], so every publisher flavor drains through the same
/// loop and the cursor can never be shared or duplicated by accident.
pub struct HubPump {
    hub: Arc<LiveHub>,
    /// The session's one forward cursor: forward batches are
    /// destructive pops, so exactly one drain path owns them.
    cursor: Mutex<ForwardCursor>,
}

impl HubPump {
    /// A pump over `hub` with a fresh cursor (nothing forwarded yet).
    pub fn new(hub: Arc<LiveHub>) -> HubPump {
        HubPump { hub, cursor: Mutex::new(ForwardCursor::default()) }
    }

    /// The hub this pump drains.
    pub fn hub(&self) -> &Arc<LiveHub> {
        &self.hub
    }

    /// Drain whatever the hub holds *right now*, handing each popped
    /// batch to `apply`; returns once nothing more is immediately
    /// forwardable (including at end of stream). The cursor lock is
    /// released around every `apply` call, so appliers may block
    /// without holding up other pump users.
    pub fn drain_now(&self, mut apply: impl FnMut(ForwardBatch)) {
        loop {
            let mut cursor = self.cursor.lock().unwrap_or_else(|p| p.into_inner());
            let batch = self.hub.try_forward_batch(&mut cursor);
            drop(cursor);
            match batch {
                Some(batch) => apply(batch),
                None => break,
            }
        }
    }

    /// Drain until the hub seals, handing each batch to `apply`; the
    /// blocking flavor of [`HubPump::drain_now`]. Returns on clean end
    /// of stream (hub sealed, closed and drained).
    pub fn run(&self, mut apply: impl FnMut(ForwardBatch)) {
        loop {
            let mut cursor = self.cursor.lock().unwrap_or_else(|p| p.into_inner());
            let batch = self.hub.next_forward_batch(&mut cursor);
            drop(cursor);
            match batch {
                Some(batch) => apply(batch),
                None => break,
            }
        }
    }

    /// Block for the next forward batch, or `None` at clean end of
    /// stream — for serve loops that interleave a socket write per
    /// batch instead of a closure.
    pub fn next(&self) -> Option<ForwardBatch> {
        let mut cursor = self.cursor.lock().unwrap_or_else(|p| p.into_inner());
        self.hub.next_forward_batch(&mut cursor)
    }

    /// Reset the cursor's delta baseline for a new connection that
    /// already knows about `announced` channels (see
    /// [`ForwardCursor::resync`]).
    pub fn resync(&self, announced: usize) {
        self.cursor.lock().unwrap_or_else(|p| p.into_inner()).resync(announced);
    }
}

/// One wire-ready per-leaf accounting entry — the payload of a
/// [`Frame::Origin`], mirrored into the broadcaster's shared board so
/// every subscriber can delta-diff it against its own view.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OriginWire {
    /// Hierarchical origin id (unique per publishing session).
    pub path: String,
    /// The leaf publisher's hostname.
    pub hostname: String,
    /// *This* publisher's stream ids carrying the leaf's events.
    pub streams: Vec<u32>,
    /// Cumulative publisher-side drops attributed to the leaf.
    pub dropped: u64,
    /// Cumulative events the leaf lost to resume gaps.
    pub resume_gaps: u64,
    /// The leaf's Eos totals, once it ended cleanly.
    pub eos: Option<(u64, u64)>,
}

impl OriginWire {
    /// Max-merge a fresh snapshot entry into this one: every counter
    /// is cumulative and monotone, so a racing stale value can never
    /// roll a ledger back (the [`Frame::Drops`] rule).
    pub fn merge(&mut self, newer: OriginWire) {
        debug_assert_eq!(self.path, newer.path);
        if newer.streams.len() > self.streams.len() {
            self.streams = newer.streams;
        }
        if newer.hostname != self.hostname {
            self.hostname = newer.hostname;
        }
        self.dropped = self.dropped.max(newer.dropped);
        self.resume_gaps = self.resume_gaps.max(newer.resume_gaps);
        if newer.eos.is_some() {
            self.eos = newer.eos;
        }
    }

    /// The [`Frame::Origin`] carrying this entry.
    pub fn frame(&self) -> Frame {
        Frame::Origin {
            path: self.path.clone(),
            hostname: self.hostname.clone(),
            streams: self.streams.clone(),
            dropped: self.dropped,
            resume_gaps: self.resume_gaps,
            eos: self.eos,
        }
    }
}

/// Build the wire-ready per-leaf entries for everything `hub` is
/// aggregating right now: one entry per origin (the publishers this
/// node drains directly), plus one per sub-origin relayed *through*
/// them (deeper tree levels), paths extended with this node's own
/// `<index>:<label>` origin names. Remote stream ids translate through
/// each origin's map into this hub's shared stream space, which is the
/// stream space this node's upstream wire announces.
///
/// The emitting node never lists itself — its identity travels in its
/// Hello, its own channel drops as [`Frame::Drops`], its totals as
/// [`Frame::Eos`]. Parent and child entries carry *disjoint* ledgers
/// (the hop into this hub vs loss at and below the leaf), so a
/// receiver summing a parent with its children never counts one event
/// twice — see [`crate::live::OriginStats::children`].
pub fn origin_snapshot(hub: &LiveHub) -> Vec<OriginWire> {
    let mut out = Vec::new();
    for (i, o) in hub.origin_stats().into_iter().enumerate() {
        let map = hub.origin_map(i);
        let base = origin_series_label(i, &o.label);
        out.push(OriginWire {
            path: base.clone(),
            hostname: o.label.clone(),
            streams: map.iter().map(|&g| g as u32).collect(),
            dropped: o.remote_dropped,
            resume_gaps: o.resume_gaps,
            eos: o.eos,
        });
        for c in o.children {
            out.push(OriginWire {
                path: format!("{base}/{}", c.path),
                hostname: c.hostname.clone(),
                // the child's ids are the downstream node's stream
                // space; translate into ours through the origin map
                streams: c
                    .streams
                    .iter()
                    .filter_map(|&s| map.get(s as usize).map(|&g| g as u32))
                    .collect(),
                dropped: c.dropped,
                resume_gaps: c.resume_gaps,
                eos: c.eos,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_pump_drains_exactly_once_across_flavors() {
        let hub = LiveHub::new("pumpnode", 64, false);
        hub.ensure_channels(1);
        let class = crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let msgs: Vec<_> = (0..4)
            .map(|i| hub.decode(0, 1, class.id, 10 + i, &0u64.to_le_bytes()).unwrap())
            .collect();
        hub.push_batch(0, msgs);
        let pump = HubPump::new(hub.clone());
        let mut seen = Vec::new();
        pump.drain_now(|b| seen.extend(b.events.into_iter().map(|(_, m)| m.ts)));
        assert_eq!(seen, vec![10, 11, 12, 13]);
        // already drained: the cursor is shared state, not per-call
        pump.drain_now(|b| seen.extend(b.events.into_iter().map(|(_, m)| m.ts)));
        assert_eq!(seen.len(), 4);
        hub.close_all();
        assert!(pump.next().is_none(), "sealed and drained is a clean end");
    }

    #[test]
    fn origin_snapshot_extends_child_paths_and_translates_streams() {
        let hub = LiveHub::new("rootmirror", 64, false);
        let o = hub.register_origin("relay1");
        hub.ensure_origin_channels(o, 2);
        hub.record_origin_drops(o, 0, 3);
        // the relay reported one leaf: its stream 1 is our shared 1
        hub.record_origin_child(o, "0:nodeA", "nodeA", &[0, 1], 7, 2, Some((100, 7)));
        let snap = origin_snapshot(&hub);
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].path, "0:relay1");
        assert_eq!(snap[0].dropped, 3);
        assert_eq!(snap[0].streams, vec![0, 1]);
        assert_eq!(snap[1].path, "0:relay1/0:nodeA");
        assert_eq!(snap[1].hostname, "nodeA");
        assert_eq!(snap[1].streams, vec![0, 1], "remote ids translate through the origin map");
        assert_eq!(snap[1].eos, Some((100, 7)));
    }

    #[test]
    fn origin_wire_merge_is_monotone() {
        let mut a = OriginWire {
            path: "0:n".into(),
            hostname: "n".into(),
            streams: vec![0],
            dropped: 5,
            resume_gaps: 1,
            eos: None,
        };
        a.merge(OriginWire {
            path: "0:n".into(),
            hostname: "n".into(),
            streams: vec![0, 1],
            dropped: 3, // stale: must not roll back
            resume_gaps: 4,
            eos: Some((9, 5)),
        });
        assert_eq!((a.dropped, a.resume_gaps), (5, 4));
        assert_eq!(a.streams, vec![0, 1]);
        assert_eq!(a.eos, Some((9, 5)));
    }
}
