//! Remote live viewing: the network hop between hub and merge.
//!
//! PR 2's live mode runs collection and analysis in one process; this
//! module splits them across a socket — the `lttng-relayd` /
//! babeltrace2-live analogue, and the first step toward multi-node
//! fan-in:
//!
//! ```text
//!  traced app ── rings ──► consumer ──► LiveHub (bounded channels)
//!                                          │ next_forward_batch (tee)
//!                 iprof serve              ▼
//!                                publish: THRL frames          publish.rs
//!                                preamble · Hello(metadata) ·
//!                                Event/Beacon/Drops/Close · Eos
//!                                          │
//!                                     any byte stream (TCP)    frame.rs
//!                                          │
//!                 iprof attach             ▼
//!                                Attachment: mirror LiveHub     attach.rs
//!                                          │
//!                                          ▼
//!                           UNMODIFIED LiveSource k-way merge
//!                                          │
//!                                          ▼
//!                           run_live_pipeline → existing sinks
//! ```
//!
//! Three properties carry the design (all pinned by `rust/tests/remote.rs`):
//!
//! 1. **Byte-identical remote output.** The subscriber rebuilds a hub
//!    whose (events, watermarks, closes) sequence is equivalent to the
//!    publisher's, and drains it with the same merge and sinks as local
//!    `--live` — for a lossless feed, `iprof attach` output equals local
//!    output byte for byte.
//! 2. **The traced application never blocks.** A slow subscriber stalls
//!    the publisher thread, the hub's channels fill, and the consumer's
//!    try-push drops-and-counts — loss is reported on *both* ends
//!    ([`Frame::Drops`] per stream, totals in [`Frame::Eos`]), never
//!    converted into application latency.
//! 3. **A deterministic codec.** Frames are pure data
//!    ([`encode`]/[`decode`] round-trip property-tested); version
//!    negotiation, the frame grammar and the beacon/drop/EOS semantics
//!    are specified in `docs/PROTOCOL.md`.
//!
//! A fourth property arrived with multi-publisher fan-in
//! ([`fanin`], `iprof attach <addr> <addr>...`, pinned by
//! `rust/tests/fanin.rs`):
//!
//! 4. **N publishers, one merge.** [`FanIn`] handshakes N connections,
//!    namespaces each publisher's stream ids into one shared hub
//!    (origin blocks in connection order — colliding per-node ids can
//!    never alias), translates every per-publisher watermark beacon onto its
//!    shared channel, and drains the union with the same UNMODIFIED
//!    merge — byte-identical to a single local `--live` run over the
//!    concatenated stream set for lossless feeds, and degrading to a
//!    partial-but-correct analysis when a publisher dies.
//!
//! Entry points: [`crate::coordinator::run_serve`] /
//! [`crate::coordinator::run_attach`] /
//! [`crate::coordinator::run_fanin`] (the `iprof serve` / `iprof
//! attach` CLI), or [`publish`] + [`Attachment`] / [`FanIn`] directly
//! for custom transports (anything `Read`/`Write`).

pub mod attach;
pub mod fanin;
pub mod frame;
pub mod publish;

pub use attach::Attachment;
pub use fanin::{FanIn, FanInStats, RemoteStats};
pub use frame::{decode, decode_body, encode, Frame, FrameError, WireEvent, MAGIC, VERSION};
pub use publish::{publish, PublishStats};
