//! Remote live viewing: the network hop between hub and merge.
//!
//! PR 2's live mode runs collection and analysis in one process; this
//! module splits them across a socket — the `lttng-relayd` /
//! babeltrace2-live analogue, and the first step toward multi-node
//! fan-in:
//!
//! ```text
//!  traced app ── rings ──► consumer ──► LiveHub (bounded channels)
//!                                          │ next_forward_batch (tee)
//!                 iprof serve              ▼
//!                                publish: THRL frames          publish.rs
//!                                preamble · Hello(metadata) ·
//!                                Event/Beacon/Drops/Close · Eos
//!                                          │
//!                                     any byte stream (TCP)    frame.rs
//!                                          │
//!                 iprof attach             ▼
//!                                Attachment: mirror LiveHub     attach.rs
//!                                          │
//!                                          ▼
//!                           UNMODIFIED LiveSource k-way merge
//!                                          │
//!                                          ▼
//!                           run_live_pipeline → existing sinks
//! ```
//!
//! Three properties carry the design (all pinned by `rust/tests/remote.rs`):
//!
//! 1. **Byte-identical remote output.** The subscriber rebuilds a hub
//!    whose (events, watermarks, closes) sequence is equivalent to the
//!    publisher's, and drains it with the same merge and sinks as local
//!    `--live` — for a lossless feed, `iprof attach` output equals local
//!    output byte for byte.
//! 2. **The traced application never blocks.** A slow subscriber stalls
//!    the publisher thread, the hub's channels fill, and the consumer's
//!    try-push drops-and-counts — loss is reported on *both* ends
//!    ([`Frame::Drops`] per stream, totals in [`Frame::Eos`]), never
//!    converted into application latency.
//! 3. **A deterministic codec.** Frames are pure data
//!    ([`encode`]/[`decode`] round-trip property-tested); version
//!    negotiation, the frame grammar and the beacon/drop/EOS semantics
//!    are specified in `docs/PROTOCOL.md`.
//!
//! A fourth property arrived with multi-publisher fan-in
//! ([`fanin`], `iprof attach <addr> <addr>...`, pinned by
//! `rust/tests/fanin.rs`):
//!
//! 4. **N publishers, one merge.** [`FanIn`] handshakes N connections,
//!    namespaces each publisher's stream ids into one shared hub
//!    (origin blocks in connection order — colliding per-node ids can
//!    never alias), translates every per-publisher watermark beacon onto its
//!    shared channel, and drains the union with the same UNMODIFIED
//!    merge — byte-identical to a single local `--live` run over the
//!    concatenated stream set for lossless feeds, and degrading to a
//!    partial-but-correct analysis when a publisher dies.
//!
//! And a fifth with session resumption (protocol v2, `iprof serve
//! --resume-buffer` + `iprof attach --reconnect`):
//!
//! 5. **A dropped connection is not data loss.** A resumable
//!    [`Publisher`] owns a session *epoch* and a byte-budgeted replay
//!    ring of every event frame it relays; a reconnecting subscriber
//!    sends [`Frame::Resume`] with its per-stream delivered cursors and
//!    the publisher replays exactly the lost tail — merged output stays
//!    byte-identical to an uninterrupted run. Only when a cursor falls
//!    out of the ring does loss occur, and then it is *accounted*
//!    ([`Frame::ResumeGap`] → the per-origin drops ledger), never
//!    silent:
//!
//!    ```text
//!    subscriber  ──connect──► Hello(epoch E)
//!                ──Resume(E, cursors)──►
//!                ◄── [ResumeGap?] + ring replay + live frames ... Eos
//!         ▲                                   │
//!         └────── redial with backoff ◄───────┘ (connection drops)
//!    ```
//!
//! And a sixth with the v3 batched hot path (`iprof serve`, default
//! wire; `--wire 2` keeps the per-event fallback for old subscribers):
//!
//! 6. **Batching never changes accounting.** A v3 publisher coalesces
//!    each forward round's events into [`Frame::EventBatch`] frames
//!    (delta timestamps, varint ids, a per-connection
//!    `(rank, tid, class_id)` dictionary) and flushes whole rounds with
//!    vectored writes; the subscriber decodes batches straight into its
//!    mirror hub ([`frame::decode_batch_into`] →
//!    [`crate::live::LiveHub::feed_remote_batch`]). Replay rings, resume
//!    cursors and drop ledgers keep counting *events*, so every
//!    resumption and loss-accounting property above holds verbatim on
//!    either wire — and a v2 peer sees the exact frozen v2 byte stream.
//!
//! And a seventh with broadcast serve (`iprof serve --subscribers N`):
//!
//! 7. **One publisher, N concurrent subscribers.** A [`Broadcaster`]
//!    decouples hub draining from delivery: one pump mirrors the hub
//!    into a shared replay ring + stream board, and every accepted
//!    connection reads the ring on its own thread with independent
//!    per-stream cursors, wire version and batch dictionary. Ring
//!    eviction is driven by the slowest *entitled* cursor; a
//!    per-subscriber lag budget (`--max-lag`) demotes a laggard to gap
//!    delivery ([`Frame::ResumeGap`], exact counts) instead of letting
//!    it stall the ring, and a disconnected subscriber is unregistered
//!    from entitlement immediately. On the wire each connection is an
//!    independent, fully conforming resumable THRL connection —
//!    broadcast is server-side, invisible to subscribers (pinned by
//!    `rust/tests/broadcast.rs`).
//!
//! And an eighth with hierarchical relay fan-in (`iprof relay
//! <listen-addr> <addr>...`):
//!
//! 8. **Collection composes into trees.** A [`relay`] node is a fan-in
//!    subscriber and a broadcast publisher at once: it drains N
//!    downstream publishers into its mirror hub and re-publishes the
//!    merged union upstream through the one shared [`relay::HubPump`].
//!    Per-leaf identity travels as [`Frame::Origin`] entries with
//!    *path-style* hierarchical origin ids (`0:relay1/0:nodeA`), so the
//!    root books drops/eos/resume-gap ledgers and telemetry series per
//!    leaf — never aliased across relays — and stamps merged events
//!    with leaf hostnames: a 2-level tree merges byte-identically to a
//!    flat N-way attach (pinned by `rust/tests/relay.rs`).
//!
//! Entry points: [`crate::coordinator::run_serve`] /
//! [`crate::coordinator::run_serve_resumable`] /
//! [`crate::coordinator::run_attach`] /
//! [`crate::coordinator::run_fanin`] /
//! [`crate::coordinator::run_fanin_resumable`] /
//! [`crate::coordinator::run_relay`] (the `iprof serve` /
//! `iprof attach` / `iprof relay` CLI — see `docs/GUIDE.md` for the
//! operator view), or [`publish`] / [`Publisher`] + [`Attachment`] /
//! [`FanIn`] directly for custom transports (anything `Read`/`Write`).

pub mod attach;
pub mod fanin;
pub mod frame;
pub mod publish;
pub mod relay;

pub use attach::Attachment;
pub use fanin::{FanIn, FanInStats, ReconnectPolicy, RemoteStats};
pub use frame::{
    decode, decode_batch_into, decode_body, encode, is_event_batch, read_frame_into,
    write_preamble_version, BatchDict, BatchDictEncoder, BatchEvent, BatchKey, Frame, FrameError,
    WireEvent, MAGIC, MAX_BATCH_EVENTS, MAX_DICT_ENTRIES, SUPPORTED_VERSIONS, VERSION,
};
pub use publish::{
    publish, publish_with, Broadcaster, KillAfter, PublishStats, Publisher, ServeOutcome,
    SubscriberStats,
};
pub use relay::{origin_snapshot, HubPump, OriginWire};
