//! OpenMP target-offload frontend over the Level-Zero backend — including
//! the switchable copy-engine bug of the paper's §4.1 case study.
//!
//! The real Intel OpenMP runtime is closed source; the paper shows that
//! tracing its Level-Zero calls was enough to find that data transfers
//! were bound to the *compute* engine instead of the dedicated copy
//! engine. [`OmpRuntime`] reproduces both behaviours behind
//! [`OmpConfig::use_copy_engine`]: analysis of the resulting trace (engine
//! ordinals on `command_completed`, queue bindings) exposes the bug
//! exactly as the case study describes.

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use super::ze::{ze_result, ZeDriver};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::sync::{Arc, Mutex};

/// `omp_result_t` values.
pub mod omp_result {
    /// Success.
    pub const SUCCESS: u64 = 0;
    /// Failure.
    pub const FAIL: u64 = 1;
}

declare_tps!(pub(crate) OmpTps, Api::Omp, {
    target_alloc: "omp_target_alloc",
    target_free: "omp_target_free",
    target_memcpy: "omp_target_memcpy",
    target_submit: "ompt_target_submit",
    target_data_op: "ompt_target_data_op",
    target_sync: "omp_target_sync",
});

static TPS: Lazy<OmpTps> = Lazy::new(OmpTps::load);

/// OpenMP runtime configuration.
#[derive(Debug, Clone)]
pub struct OmpConfig {
    /// `true` = fixed runtime (transfers on the copy engine);
    /// `false` = the §4.1 bug (everything on the compute engine).
    pub use_copy_engine: bool,
}

impl Default for OmpConfig {
    fn default() -> Self {
        OmpConfig { use_copy_engine: true }
    }
}

struct DeviceState {
    ze_device: u64,
    compute_queue: u64,
    copy_queue: u64,
    compute_list: u64,
    copy_list: u64,
    /// Completion event the runtime polls on (`zeEventQueryStatus` storm —
    /// the "non-spawned APIs invoked in spin-lock scenarios" that the
    /// *full* tracing mode records and *default* excludes, §5.2).
    event: u64,
}

struct OmpState {
    ctx: u64,
    devices: Vec<DeviceState>,
}

/// The OpenMP offload runtime.
pub struct OmpRuntime {
    /// Level-Zero backend.
    pub ze: Arc<ZeDriver>,
    /// Behaviour switch (§4.1).
    pub config: OmpConfig,
    handles: HandleAllocator,
    state: Mutex<OmpState>,
}

impl OmpRuntime {
    /// The runtime's internal completion wait: a `zeEventQueryStatus`
    /// polling loop (like the real closed-source runtime's spin-lock),
    /// then the final queue synchronize that reads GPU timings. The
    /// query storm is exactly what separates *full* from *default*
    /// tracing in Fig. 7/8.
    fn wait_polling(&self, queue: u64, event: u64) {
        while self.ze.ze_event_query_status(event) != ze_result::SUCCESS {
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        self.ze.ze_command_queue_synchronize(queue, u64::MAX);
    }

    /// Bring up the runtime: one compute + one transfer queue per device.
    /// With the bug enabled the "transfer" queue is bound to the compute
    /// engine ordinal — precisely what the paper's trace analysis caught.
    pub fn new(ze: Arc<ZeDriver>, config: OmpConfig) -> Arc<Self> {
        ze.ze_init(0);
        let mut drivers = vec![];
        ze.ze_driver_get(&mut drivers);
        let mut devices = vec![];
        ze.ze_device_get(drivers[0], &mut devices);
        let (_, ctx) = ze.ze_context_create(drivers[0]);
        let mut dev_states = Vec::new();
        for d in devices {
            let (_, compute_queue) = ze.ze_command_queue_create(ctx, d, 0);
            let copy_ordinal = ze.copy_ordinal(d, config.use_copy_engine);
            let (_, copy_queue) = ze.ze_command_queue_create(ctx, d, copy_ordinal);
            let (_, compute_list) = ze.ze_command_list_create(ctx, d);
            let (_, copy_list) = ze.ze_command_list_create(ctx, d);
            let (_, pool) = ze.ze_event_pool_create(ctx, 4);
            let (_, event) = ze.ze_event_create(pool);
            dev_states.push(DeviceState {
                ze_device: d,
                compute_queue,
                copy_queue,
                compute_list,
                copy_list,
                event,
            });
        }
        Arc::new(OmpRuntime {
            ze,
            config,
            handles: HandleAllocator::new(),
            state: Mutex::new(OmpState { ctx, devices: dev_states }),
        })
    }

    /// `omp_target_alloc`.
    pub fn omp_target_alloc(&self, size: u64, device_num: i32) -> (u64, u64) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.target_alloc.0, |e| {
            e.u64(size).i64(device_num as i64).ptr(p);
        });
        let (ctx, dev) = {
            let st = self.state.lock().unwrap();
            let d = &st.devices[device_num as usize % st.devices.len()];
            (st.ctx, d.ze_device)
        };
        let (zr, ptr) = self.ze.ze_mem_alloc_device(ctx, size, 64, dev);
        let result = if zr == ze_result::SUCCESS { omp_result::SUCCESS } else { omp_result::FAIL };
        emit(TPS.target_alloc.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `omp_target_free`.
    pub fn omp_target_free(&self, device_ptr: u64, device_num: i32) -> u64 {
        emit(TPS.target_free.0, |e| {
            e.ptr(device_ptr).i64(device_num as i64);
        });
        let ctx = self.state.lock().unwrap().ctx;
        let zr = self.ze.ze_mem_free(ctx, device_ptr);
        let result = if zr == ze_result::SUCCESS { omp_result::SUCCESS } else { omp_result::FAIL };
        emit(TPS.target_free.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `omp_target_memcpy` — the §4.1 operation: which engine it lands on
    /// depends on the config.
    #[allow(clippy::too_many_arguments)]
    pub fn omp_target_memcpy(
        &self,
        dst: u64,
        src: u64,
        length: u64,
        dst_offset: u64,
        src_offset: u64,
        dst_device: i32,
        src_device: i32,
    ) -> u64 {
        emit(TPS.target_memcpy.0, |e| {
            e.ptr(dst)
                .ptr(src)
                .u64(length)
                .u64(dst_offset)
                .u64(src_offset)
                .i64(dst_device as i64)
                .i64(src_device as i64);
        });
        // OMPT data-op callback (THAPI's OMPT tracing hook).
        emit(TPS.target_data_op.0, |e| {
            e.i64(dst_device as i64).u64(1).ptr(src + src_offset).ptr(dst + dst_offset).u64(length);
        });
        let dev_idx = dst_device.max(src_device).max(0);
        let (queue, list, event) = {
            let st = self.state.lock().unwrap();
            let d = &st.devices[dev_idx as usize % st.devices.len()];
            (d.copy_queue, d.copy_list, d.event)
        };
        self.ze.ze_command_list_reset(list);
        self.ze.ze_event_host_reset(event);
        self.ze.ze_command_list_append_memory_copy(
            list,
            dst + dst_offset,
            src + src_offset,
            length,
            event,
        );
        self.ze.ze_command_list_close(list);
        self.ze.ze_command_queue_execute_command_lists(queue, &[list]);
        self.wait_polling(queue, event);
        emit(TPS.target_data_op.1, |e| {
            e.u64(omp_result::SUCCESS);
        });
        emit(TPS.target_memcpy.1, |e| {
            e.u64(omp_result::SUCCESS);
        });
        omp_result::SUCCESS
    }

    /// `ompt_target_submit` — launch a named kernel (`#pragma omp target`).
    /// `args` are device pointers (inputs then output).
    pub fn omp_target_submit(
        &self,
        kernel_name: &str,
        device_num: i32,
        teams: u32,
        args: &[u64],
    ) -> u64 {
        emit(TPS.target_submit.0, |e| {
            e.str(kernel_name).i64(device_num as i64).u64(teams as u64).u64(teams as u64);
        });
        let (ctx, dev, queue, list, event) = {
            let st = self.state.lock().unwrap();
            let d = &st.devices[device_num as usize % st.devices.len()];
            (st.ctx, d.ze_device, d.compute_queue, d.compute_list, d.event)
        };
        // The OpenMP runtime lazily builds the module (cached by PJRT).
        let (zr, module) = self.ze.ze_module_create(ctx, dev, kernel_name);
        if zr != ze_result::SUCCESS {
            emit(TPS.target_submit.1, |e| {
                e.u64(omp_result::FAIL);
            });
            return omp_result::FAIL;
        }
        let (_, kernel) = self.ze.ze_kernel_create(module, kernel_name);
        for (i, a) in args.iter().enumerate() {
            self.ze.ze_kernel_set_argument_value(kernel, i as u32, *a);
        }
        self.ze.ze_kernel_set_group_size(kernel, teams.max(1), 1, 1);
        self.ze.ze_command_list_reset(list);
        self.ze.ze_event_host_reset(event);
        self.ze.ze_command_list_append_launch_kernel(list, kernel, (teams.max(1), 1, 1), event);
        self.ze.ze_command_list_close(list);
        self.ze.ze_command_queue_execute_command_lists(queue, &[list]);
        self.wait_polling(queue, event);
        self.ze.ze_kernel_destroy(kernel);
        self.ze.ze_module_destroy(module);
        emit(TPS.target_submit.1, |e| {
            e.u64(omp_result::SUCCESS);
        });
        omp_result::SUCCESS
    }

    /// `omp_target_sync` (device barrier).
    pub fn omp_target_sync(&self, device_num: i32) -> u64 {
        emit(TPS.target_sync.0, |e| {
            e.i64(device_num as i64);
        });
        let queue = {
            let st = self.state.lock().unwrap();
            st.devices[device_num as usize % st.devices.len()].compute_queue
        };
        self.ze.ze_command_queue_synchronize(queue, u64::MAX);
        emit(TPS.target_sync.1, |e| {
            e.u64(omp_result::SUCCESS);
        });
        omp_result::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{EngineKind, Node, NodeConfig};
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    fn runtime(use_copy_engine: bool) -> Arc<OmpRuntime> {
        let node = Node::new(NodeConfig::test_small());
        OmpRuntime::new(ZeDriver::new(node), OmpConfig { use_copy_engine })
    }

    fn run_memcpy_and_count_engines(use_copy_engine: bool) -> (u64, u64) {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let omp = runtime(use_copy_engine);
        let (_, d) = omp.omp_target_alloc(1 << 20, 0);
        let gpu = omp.ze.node.gpu(0);
        let host = gpu.pool.alloc(crate::device::AllocKind::Host, 1 << 20).unwrap();
        for _ in 0..5 {
            omp.omp_target_memcpy(d, host, 1 << 20, 0, 0, 0, -1);
        }
        let session = uninstall_session().unwrap();
        let trace = crate::tracer::btf::collect(&session, &[]);
        let md = crate::tracer::btf::parse_metadata(&trace.metadata).unwrap();
        let (mut compute, mut copy) = (0u64, 0u64);
        for s in &trace.streams {
            crate::tracer::btf::iter_records(&s.bytes, |id, _, payload| {
                let dec = &md.classes[&id];
                if dec.name == "lttng_ust_profiling:command_completed" {
                    let vals = crate::tracer::encoder::decode_payload(&dec.fields, payload);
                    // field 2 = engine_kind, field 3 = kind
                    if vals[3].as_str() == "memcpy" {
                        if vals[2].as_u64() == EngineKind::Copy.code() as u64 {
                            copy += 1;
                        } else {
                            compute += 1;
                        }
                    }
                }
            });
        }
        (compute, copy)
    }

    #[test]
    fn fixed_runtime_uses_copy_engine() {
        let (compute, copy) = run_memcpy_and_count_engines(true);
        assert_eq!(compute, 0, "fixed runtime must not copy on the compute engine");
        assert_eq!(copy, 5);
    }

    #[test]
    fn buggy_runtime_uses_compute_engine_like_sec4_1() {
        let (compute, copy) = run_memcpy_and_count_engines(false);
        assert_eq!(copy, 0, "buggy runtime must not touch the copy engine");
        assert_eq!(compute, 5);
    }

    #[test]
    fn target_submit_runs_kernel() {
        let _g = test_support::lock();
        let omp = runtime(true);
        let elems = 512 * 512usize;
        let bytes = (elems * 4) as u64;
        let (_, din) = omp.omp_target_alloc(bytes, 0);
        let (_, dout) = omp.omp_target_alloc(bytes, 0);
        let gpu = omp.ze.node.gpu(0);
        gpu.pool
            .write(din, &crate::runtime::executor::f32_to_bytes(&vec![1.0; elems]))
            .unwrap();
        assert_eq!(omp.omp_target_submit("stencil", 0, 8, &[din, dout]), omp_result::SUCCESS);
        let out = crate::runtime::executor::bytes_to_f32(&gpu.pool.read(dout, bytes).unwrap());
        // constant field is a Jacobi fixed point
        assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-5));
        omp.omp_target_free(din, 0);
        omp.omp_target_free(dout, 0);
    }
}
