//! Level-Zero frontend: the traced `ze*` runtime over the simulated node.
//!
//! Every function emits a full-context `_entry`/`_exit` event pair with
//! the exact fields the generated trace model declares (debug builds
//! assert this). The runtime itself is a faithful-enough Level-Zero:
//! contexts, command queues bound to engine ordinals, command lists with
//! close/reset semantics, event pools/events, modules compiled by the
//! *real* PJRT executor (so `zeModuleCreate` costs real milliseconds) and
//! kernels with indexed arguments.

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use super::profiling;
use crate::device::{Command, DevEvent, Gpu, Node};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// `ze_result_t` values (mirrors the bundled header enum).
pub mod ze_result {
    /// Success.
    pub const SUCCESS: u64 = 0;
    /// Not ready (event not signaled).
    pub const NOT_READY: u64 = 1;
    /// Invalid argument.
    pub const INVALID_ARGUMENT: u64 = 3;
    /// Uninitialized driver.
    pub const UNINITIALIZED: u64 = 4;
    /// Null handle.
    pub const INVALID_NULL_HANDLE: u64 = 5;
}

declare_tps!(pub(crate) ZeTps, Api::Ze, {
    init: "zeInit",
    driver_get: "zeDriverGet",
    device_get: "zeDeviceGet",
    device_get_properties: "zeDeviceGetProperties",
    context_create: "zeContextCreate",
    context_destroy: "zeContextDestroy",
    mem_alloc_device: "zeMemAllocDevice",
    mem_alloc_host: "zeMemAllocHost",
    mem_alloc_shared: "zeMemAllocShared",
    mem_free: "zeMemFree",
    queue_create: "zeCommandQueueCreate",
    queue_destroy: "zeCommandQueueDestroy",
    list_create: "zeCommandListCreate",
    list_destroy: "zeCommandListDestroy",
    list_close: "zeCommandListClose",
    list_reset: "zeCommandListReset",
    append_memory_copy: "zeCommandListAppendMemoryCopy",
    append_launch_kernel: "zeCommandListAppendLaunchKernel",
    append_barrier: "zeCommandListAppendBarrier",
    queue_execute: "zeCommandQueueExecuteCommandLists",
    queue_synchronize: "zeCommandQueueSynchronize",
    event_pool_create: "zeEventPoolCreate",
    event_pool_destroy: "zeEventPoolDestroy",
    event_create: "zeEventCreate",
    event_destroy: "zeEventDestroy",
    event_host_synchronize: "zeEventHostSynchronize",
    event_query_status: "zeEventQueryStatus",
    event_host_reset: "zeEventHostReset",
    module_create: "zeModuleCreate",
    module_destroy: "zeModuleDestroy",
    kernel_create: "zeKernelCreate",
    kernel_destroy: "zeKernelDestroy",
    kernel_set_group_size: "zeKernelSetGroupSize",
    kernel_set_argument_value: "zeKernelSetArgumentValue",
});

static TPS: Lazy<ZeTps> = Lazy::new(ZeTps::load);

/// Device-properties struct (the §4.2 UB case: `pNext` must be zeroed by
/// the caller; the tracer records whatever value it holds).
#[derive(Debug, Clone, Default)]
pub struct ZeDeviceProperties {
    /// Extension chain pointer — must be null-initialized by the app.
    pub p_next: u64,
    /// Device name (filled by the driver).
    pub name: String,
    /// Tile count.
    pub num_tiles: u32,
    /// Total device memory.
    pub total_mem: u64,
}

struct ZeQueue {
    gpu: u32,
    ordinal: u32,
    fences: Vec<Arc<DevEvent>>,
}

#[derive(Default)]
struct ZeList {
    /// Owning GPU (kept for cross-device validation checks).
    #[allow(dead_code)]
    gpu: u32,
    commands: Vec<Command>,
    closed: bool,
    /// Number of times executed since last reset (validation: §4.2).
    executions: u32,
}

struct ZeKernel {
    /// Owning module (kept for teardown validation).
    #[allow(dead_code)]
    module: u64,
    name: String,
    args: HashMap<u32, u64>,
    group_size: (u32, u32, u32),
}

#[derive(Default)]
struct ZeState {
    initialized: bool,
    contexts: HashMap<u64, ()>,
    queues: HashMap<u64, ZeQueue>,
    lists: HashMap<u64, ZeList>,
    event_pools: HashMap<u64, ()>,
    events: HashMap<u64, Arc<DevEvent>>,
    modules: HashMap<u64, String>,
    kernels: HashMap<u64, ZeKernel>,
}

/// The Level-Zero driver instance for one node.
pub struct ZeDriver {
    /// The node this driver exposes.
    pub node: Arc<Node>,
    handles: HandleAllocator,
    driver_handle: u64,
    device_handles: Vec<u64>,
    state: Mutex<ZeState>,
}

impl ZeDriver {
    /// Create the driver for `node`.
    pub fn new(node: Arc<Node>) -> Arc<Self> {
        let handles = HandleAllocator::new();
        let driver_handle = handles.alloc(HandleKind::Driver);
        let device_handles = node.gpus.iter().map(|g| g.handle).collect();
        Arc::new(ZeDriver {
            node,
            handles,
            driver_handle,
            device_handles,
            state: Mutex::new(ZeState::default()),
        })
    }

    fn desc(&self) -> u64 {
        self.handles.alloc(HandleKind::Desc)
    }

    fn gpu_by_handle(&self, handle: u64) -> Option<&Arc<Gpu>> {
        self.node.gpus.iter().find(|g| g.handle == handle)
    }

    // -----------------------------------------------------------------
    // Initialization / discovery
    // -----------------------------------------------------------------

    /// `zeInit`.
    pub fn ze_init(&self, flags: u32) -> u64 {
        emit(TPS.init.0, |e| {
            e.u64(flags as u64);
        });
        self.state.lock().unwrap().initialized = true;
        let result = ze_result::SUCCESS;
        emit(TPS.init.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeDriverGet` — fills `drivers` and returns (result, count).
    pub fn ze_driver_get(&self, drivers: &mut Vec<u64>) -> (u64, u32) {
        let p_count = self.desc();
        let ph = self.desc();
        emit(TPS.driver_get.0, |e| {
            e.ptr(p_count).ptr(ph);
        });
        let initialized = self.state.lock().unwrap().initialized;
        let (result, count) = if initialized {
            drivers.clear();
            drivers.push(self.driver_handle);
            (ze_result::SUCCESS, 1u32)
        } else {
            (ze_result::UNINITIALIZED, 0)
        };
        let first = drivers.first().copied().unwrap_or(0);
        emit(TPS.driver_get.1, |e| {
            e.u64(result).u64(count as u64).ptr(first);
        });
        (result, count)
    }

    /// `zeDeviceGet`.
    pub fn ze_device_get(&self, driver: u64, devices: &mut Vec<u64>) -> (u64, u32) {
        let p_count = self.desc();
        let ph = self.desc();
        emit(TPS.device_get.0, |e| {
            e.ptr(driver).ptr(p_count).ptr(ph);
        });
        let (result, count) = if driver == self.driver_handle {
            devices.clear();
            devices.extend_from_slice(&self.device_handles);
            (ze_result::SUCCESS, devices.len() as u32)
        } else {
            (ze_result::INVALID_NULL_HANDLE, 0)
        };
        let first = devices.first().copied().unwrap_or(0);
        emit(TPS.device_get.1, |e| {
            e.u64(result).u64(count as u64).ptr(first);
        });
        (result, count)
    }

    /// `zeDeviceGetProperties`. The caller-provided struct's `pNext` is
    /// traced verbatim — the §4.2 validation plugin flags non-null values.
    pub fn ze_device_get_properties(&self, device: u64, props: &mut ZeDeviceProperties) -> u64 {
        let p = self.desc();
        emit(TPS.device_get_properties.0, |e| {
            e.ptr(device).ptr(p).ptr(props.p_next);
        });
        let result = match self.gpu_by_handle(device) {
            Some(g) => {
                props.name = g.name.clone();
                props.num_tiles = g.tiles;
                props.total_mem = g.pool.device_usage().1;
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        emit(TPS.device_get_properties.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeContextCreate`.
    pub fn ze_context_create(&self, driver: u64) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.context_create.0, |e| {
            e.ptr(driver).ptr(desc).ptr(ph);
        });
        let ctx = self.handles.alloc(HandleKind::Context);
        self.state.lock().unwrap().contexts.insert(ctx, ());
        emit(TPS.context_create.1, |e| {
            e.u64(ze_result::SUCCESS).ptr(ctx);
        });
        (ze_result::SUCCESS, ctx)
    }

    /// `zeContextDestroy`.
    pub fn ze_context_destroy(&self, ctx: u64) -> u64 {
        emit(TPS.context_destroy.0, |e| {
            e.ptr(ctx);
        });
        let ok = self.state.lock().unwrap().contexts.remove(&ctx).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.context_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    // -----------------------------------------------------------------
    // Memory
    // -----------------------------------------------------------------

    /// `zeMemAllocDevice`.
    pub fn ze_mem_alloc_device(&self, ctx: u64, size: u64, alignment: u64, device: u64) -> (u64, u64) {
        let desc = self.desc();
        let pptr = self.desc();
        emit(TPS.mem_alloc_device.0, |e| {
            e.ptr(ctx).ptr(desc).u64(size).u64(alignment).ptr(device).ptr(pptr);
        });
        let (result, ptr) = match self.gpu_by_handle(device) {
            Some(g) => match g.alloc(crate::device::AllocKind::Device, size) {
                Ok(p) => (ze_result::SUCCESS, p),
                Err(_) => (ze_result::INVALID_ARGUMENT, 0),
            },
            None => (ze_result::INVALID_NULL_HANDLE, 0),
        };
        emit(TPS.mem_alloc_device.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `zeMemAllocHost`.
    pub fn ze_mem_alloc_host(&self, ctx: u64, size: u64, alignment: u64) -> (u64, u64) {
        let desc = self.desc();
        let pptr = self.desc();
        emit(TPS.mem_alloc_host.0, |e| {
            e.ptr(ctx).ptr(desc).u64(size).u64(alignment).ptr(pptr);
        });
        // host allocations go through GPU 0's pool (one host address space)
        let (result, ptr) = match self.node.gpus[0].alloc(crate::device::AllocKind::Host, size) {
            Ok(p) => (ze_result::SUCCESS, p),
            Err(_) => (ze_result::INVALID_ARGUMENT, 0),
        };
        emit(TPS.mem_alloc_host.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `zeMemAllocShared`.
    pub fn ze_mem_alloc_shared(&self, ctx: u64, size: u64, alignment: u64, device: u64) -> (u64, u64) {
        let ddesc = self.desc();
        let hdesc = self.desc();
        let pptr = self.desc();
        emit(TPS.mem_alloc_shared.0, |e| {
            e.ptr(ctx).ptr(ddesc).ptr(hdesc).u64(size).u64(alignment).ptr(device).ptr(pptr);
        });
        let (result, ptr) = match self.gpu_by_handle(device) {
            Some(g) => match g.alloc(crate::device::AllocKind::Shared, size) {
                Ok(p) => (ze_result::SUCCESS, p),
                Err(_) => (ze_result::INVALID_ARGUMENT, 0),
            },
            None => (ze_result::INVALID_NULL_HANDLE, 0),
        };
        emit(TPS.mem_alloc_shared.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `zeMemFree`. Frees from whichever GPU pool owns the pointer.
    pub fn ze_mem_free(&self, ctx: u64, ptr: u64) -> u64 {
        emit(TPS.mem_free.0, |e| {
            e.ptr(ctx).ptr(ptr);
        });
        let mut result = ze_result::INVALID_ARGUMENT;
        for g in &self.node.gpus {
            if g.free(ptr).is_ok() {
                result = ze_result::SUCCESS;
                break;
            }
        }
        emit(TPS.mem_free.1, |e| {
            e.u64(result);
        });
        result
    }

    // -----------------------------------------------------------------
    // Queues and lists
    // -----------------------------------------------------------------

    /// `zeCommandQueueCreate`. `ordinal` selects the engine (compute tiles
    /// first, then copy tiles — PVC-style engine groups).
    pub fn ze_command_queue_create(&self, ctx: u64, device: u64, ordinal: u32) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.queue_create.0, |e| {
            e.ptr(ctx).ptr(device).ptr(desc).ptr(ph);
        });
        let (result, q) = match self.gpu_by_handle(device) {
            Some(g) => {
                let q = self.handles.alloc(HandleKind::Queue);
                self.state.lock().unwrap().queues.insert(
                    q,
                    ZeQueue { gpu: g.index, ordinal, fences: Vec::new() },
                );
                (ze_result::SUCCESS, q)
            }
            None => (ze_result::INVALID_NULL_HANDLE, 0),
        };
        emit(TPS.queue_create.1, |e| {
            e.u64(result).ptr(q);
        });
        (result, q)
    }

    /// `zeCommandQueueDestroy`.
    pub fn ze_command_queue_destroy(&self, queue: u64) -> u64 {
        emit(TPS.queue_destroy.0, |e| {
            e.ptr(queue);
        });
        let ok = self.state.lock().unwrap().queues.remove(&queue).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.queue_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListCreate`.
    pub fn ze_command_list_create(&self, ctx: u64, device: u64) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.list_create.0, |e| {
            e.ptr(ctx).ptr(device).ptr(desc).ptr(ph);
        });
        let (result, l) = match self.gpu_by_handle(device) {
            Some(g) => {
                let l = self.handles.alloc(HandleKind::List);
                self.state
                    .lock()
                    .unwrap()
                    .lists
                    .insert(l, ZeList { gpu: g.index, ..Default::default() });
                (ze_result::SUCCESS, l)
            }
            None => (ze_result::INVALID_NULL_HANDLE, 0),
        };
        emit(TPS.list_create.1, |e| {
            e.u64(result).ptr(l);
        });
        (result, l)
    }

    /// `zeCommandListDestroy`.
    pub fn ze_command_list_destroy(&self, list: u64) -> u64 {
        emit(TPS.list_destroy.0, |e| {
            e.ptr(list);
        });
        let ok = self.state.lock().unwrap().lists.remove(&list).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.list_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListClose`.
    pub fn ze_command_list_close(&self, list: u64) -> u64 {
        emit(TPS.list_close.0, |e| {
            e.ptr(list);
        });
        let mut st = self.state.lock().unwrap();
        let result = match st.lists.get_mut(&list) {
            Some(l) => {
                l.closed = true;
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.list_close.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListReset`.
    pub fn ze_command_list_reset(&self, list: u64) -> u64 {
        emit(TPS.list_reset.0, |e| {
            e.ptr(list);
        });
        let mut st = self.state.lock().unwrap();
        let result = match st.lists.get_mut(&list) {
            Some(l) => {
                l.commands.clear();
                l.closed = false;
                l.executions = 0;
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.list_reset.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListAppendMemoryCopy` — the paper's §1.1 example event.
    pub fn ze_command_list_append_memory_copy(
        &self,
        list: u64,
        dst: u64,
        src: u64,
        size: u64,
        signal_event: u64,
    ) -> u64 {
        emit(TPS.append_memory_copy.0, |e| {
            e.ptr(list).ptr(dst).ptr(src).u64(size).ptr(signal_event).u64(0).ptr(0);
        });
        let mut st = self.state.lock().unwrap();
        let signal = st.events.get(&signal_event).cloned();
        let result = match st.lists.get_mut(&list) {
            Some(l) if !l.closed => {
                l.commands.push(Command::Memcpy { dst, src, bytes: size, signal });
                ze_result::SUCCESS
            }
            Some(_) => ze_result::INVALID_ARGUMENT,
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.append_memory_copy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListAppendLaunchKernel`.
    pub fn ze_command_list_append_launch_kernel(
        &self,
        list: u64,
        kernel: u64,
        groups: (u32, u32, u32),
        signal_event: u64,
    ) -> u64 {
        let group_ptr = self.desc();
        emit(TPS.append_launch_kernel.0, |e| {
            e.ptr(list).ptr(kernel).ptr(group_ptr).ptr(signal_event).u64(0).ptr(0);
        });
        let mut st = self.state.lock().unwrap();
        let signal = st.events.get(&signal_event).cloned();
        let cmd = match st.kernels.get(&kernel) {
            Some(k) => {
                let mut idx: Vec<_> = k.args.keys().copied().collect();
                idx.sort_unstable();
                let args: Vec<u64> = idx.iter().map(|i| k.args[i]).collect();
                Some(Command::Kernel { name: k.name.clone(), args, groups, signal })
            }
            None => None,
        };
        let result = match (cmd, st.lists.get_mut(&list)) {
            (Some(c), Some(l)) if !l.closed => {
                l.commands.push(c);
                ze_result::SUCCESS
            }
            (Some(_), Some(_)) => ze_result::INVALID_ARGUMENT,
            (None, _) => ze_result::INVALID_NULL_HANDLE,
            (_, None) => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.append_launch_kernel.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandListAppendBarrier`.
    pub fn ze_command_list_append_barrier(&self, list: u64, signal_event: u64) -> u64 {
        emit(TPS.append_barrier.0, |e| {
            e.ptr(list).ptr(signal_event).u64(0).ptr(0);
        });
        let mut st = self.state.lock().unwrap();
        let signal = st.events.get(&signal_event).cloned();
        let result = match st.lists.get_mut(&list) {
            Some(l) if !l.closed => {
                l.commands.push(Command::Barrier { signal });
                ze_result::SUCCESS
            }
            Some(_) => ze_result::INVALID_ARGUMENT,
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.append_barrier.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandQueueExecuteCommandLists`.
    pub fn ze_command_queue_execute_command_lists(&self, queue: u64, lists: &[u64]) -> u64 {
        let ph = self.desc();
        emit(TPS.queue_execute.0, |e| {
            e.ptr(queue).u64(lists.len() as u64).ptr(ph).ptr(0);
        });
        let mut st = self.state.lock().unwrap();
        let mut result = ze_result::SUCCESS;
        let (gpu_idx, ordinal) = match st.queues.get(&queue) {
            Some(q) => (q.gpu, q.ordinal),
            None => {
                drop(st);
                emit(TPS.queue_execute.1, |e| {
                    e.u64(ze_result::INVALID_NULL_HANDLE);
                });
                return ze_result::INVALID_NULL_HANDLE;
            }
        };
        let mut batches = Vec::new();
        for lh in lists {
            match st.lists.get_mut(lh) {
                Some(l) if l.closed => {
                    // NOTE: a second execution without reset is the §4.2
                    // validation case — we allow it (UB in real L0) and the
                    // validation plugin flags it post-mortem.
                    l.executions += 1;
                    batches.push(l.commands.clone());
                }
                _ => result = ze_result::INVALID_ARGUMENT,
            }
        }
        let gpu = self.node.gpus[gpu_idx as usize].clone();
        let mut fences = Vec::new();
        for cmds in batches {
            let fence = Arc::new(DevEvent::new());
            gpu.submit(ordinal, queue, cmds, Some(fence.clone()));
            fences.push(fence);
        }
        if let Some(q) = st.queues.get_mut(&queue) {
            q.fences.extend(fences);
        }
        drop(st);
        emit(TPS.queue_execute.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeCommandQueueSynchronize` — waits for all outstanding batches,
    /// then lets the profiling helpers read device timestamps (Fig. 2).
    pub fn ze_command_queue_synchronize(&self, queue: u64, timeout: u64) -> u64 {
        emit(TPS.queue_synchronize.0, |e| {
            e.ptr(queue).u64(timeout);
        });
        let fences = {
            let mut st = self.state.lock().unwrap();
            match st.queues.get_mut(&queue) {
                Some(q) => std::mem::take(&mut q.fences),
                None => {
                    drop(st);
                    emit(TPS.queue_synchronize.1, |e| {
                        e.u64(ze_result::INVALID_NULL_HANDLE);
                    });
                    return ze_result::INVALID_NULL_HANDLE;
                }
            }
        };
        for f in &fences {
            f.wait(Duration::from_secs(600));
        }
        let gpu_idx = self.state.lock().unwrap().queues[&queue].gpu;
        let gpu = &self.node.gpus[gpu_idx as usize];
        profiling::drain_and_emit(gpu, Some(queue));
        emit(TPS.queue_synchronize.1, |e| {
            e.u64(ze_result::SUCCESS);
        });
        ze_result::SUCCESS
    }

    // -----------------------------------------------------------------
    // Events
    // -----------------------------------------------------------------

    /// `zeEventPoolCreate`.
    pub fn ze_event_pool_create(&self, ctx: u64, count: u32) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.event_pool_create.0, |e| {
            e.ptr(ctx).ptr(desc).u64(count as u64).ptr(0).ptr(ph);
        });
        let pool = self.handles.alloc(HandleKind::EventPool);
        self.state.lock().unwrap().event_pools.insert(pool, ());
        emit(TPS.event_pool_create.1, |e| {
            e.u64(ze_result::SUCCESS).ptr(pool);
        });
        (ze_result::SUCCESS, pool)
    }

    /// `zeEventPoolDestroy`.
    pub fn ze_event_pool_destroy(&self, pool: u64) -> u64 {
        emit(TPS.event_pool_destroy.0, |e| {
            e.ptr(pool);
        });
        let ok = self.state.lock().unwrap().event_pools.remove(&pool).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.event_pool_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeEventCreate`.
    pub fn ze_event_create(&self, pool: u64) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.event_create.0, |e| {
            e.ptr(pool).ptr(desc).ptr(ph);
        });
        let ev = self.handles.alloc(HandleKind::Event);
        self.state.lock().unwrap().events.insert(ev, Arc::new(DevEvent::new()));
        emit(TPS.event_create.1, |e| {
            e.u64(ze_result::SUCCESS).ptr(ev);
        });
        (ze_result::SUCCESS, ev)
    }

    /// `zeEventDestroy`.
    pub fn ze_event_destroy(&self, event: u64) -> u64 {
        emit(TPS.event_destroy.0, |e| {
            e.ptr(event);
        });
        let ok = self.state.lock().unwrap().events.remove(&event).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.event_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeEventHostSynchronize` with `timeout` ns. HIPLZ spins on this
    /// with short timeouts — the 9.9-million-call row of the §4.3 tally.
    pub fn ze_event_host_synchronize(&self, event: u64, timeout: u64) -> u64 {
        emit(TPS.event_host_synchronize.0, |e| {
            e.ptr(event).u64(timeout);
        });
        let ev = self.state.lock().unwrap().events.get(&event).cloned();
        let result = match ev {
            Some(ev) => {
                if ev.wait(Duration::from_nanos(timeout)) {
                    ze_result::SUCCESS
                } else {
                    ze_result::NOT_READY
                }
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        emit(TPS.event_host_synchronize.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeEventQueryStatus` (polling class — dropped in default mode).
    pub fn ze_event_query_status(&self, event: u64) -> u64 {
        emit(TPS.event_query_status.0, |e| {
            e.ptr(event);
        });
        let ev = self.state.lock().unwrap().events.get(&event).cloned();
        let result = match ev {
            Some(ev) => {
                if ev.query() {
                    ze_result::SUCCESS
                } else {
                    ze_result::NOT_READY
                }
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        emit(TPS.event_query_status.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeEventHostReset`.
    pub fn ze_event_host_reset(&self, event: u64) -> u64 {
        emit(TPS.event_host_reset.0, |e| {
            e.ptr(event);
        });
        let ev = self.state.lock().unwrap().events.get(&event).cloned();
        let result = match ev {
            Some(ev) => {
                ev.reset();
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        emit(TPS.event_host_reset.1, |e| {
            e.u64(result);
        });
        result
    }

    // -----------------------------------------------------------------
    // Modules and kernels
    // -----------------------------------------------------------------

    /// `zeModuleCreate` — compiles the named artifact through PJRT; the
    /// (real) compile time is what the tally reports for this call.
    pub fn ze_module_create(&self, ctx: u64, device: u64, kernel_name: &str) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        let phlog = self.desc();
        emit(TPS.module_create.0, |e| {
            e.ptr(ctx).ptr(device).ptr(desc).ptr(ph).ptr(phlog);
        });
        let (result, module) = match self.node.executor.compile(kernel_name) {
            Ok(_elapsed) => {
                let m = self.handles.alloc(HandleKind::Module);
                self.state.lock().unwrap().modules.insert(m, kernel_name.to_string());
                (ze_result::SUCCESS, m)
            }
            Err(_) => (ze_result::INVALID_ARGUMENT, 0),
        };
        emit(TPS.module_create.1, |e| {
            e.u64(result).ptr(module).ptr(0);
        });
        (result, module)
    }

    /// `zeModuleDestroy`.
    pub fn ze_module_destroy(&self, module: u64) -> u64 {
        emit(TPS.module_destroy.0, |e| {
            e.ptr(module);
        });
        let ok = self.state.lock().unwrap().modules.remove(&module).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.module_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeKernelCreate` — `name` must match the module's kernel.
    pub fn ze_kernel_create(&self, module: u64, name: &str) -> (u64, u64) {
        let desc = self.desc();
        let ph = self.desc();
        emit(TPS.kernel_create.0, |e| {
            e.ptr(module).ptr(desc).ptr(ph);
        });
        let mut st = self.state.lock().unwrap();
        let (result, k) = match st.modules.get(&module) {
            Some(mname) if mname == name => {
                let k = self.handles.alloc(HandleKind::Kernel);
                st.kernels.insert(
                    k,
                    ZeKernel {
                        module,
                        name: name.to_string(),
                        args: HashMap::new(),
                        group_size: (1, 1, 1),
                    },
                );
                (ze_result::SUCCESS, k)
            }
            Some(_) => (ze_result::INVALID_ARGUMENT, 0),
            None => (ze_result::INVALID_NULL_HANDLE, 0),
        };
        drop(st);
        emit(TPS.kernel_create.1, |e| {
            e.u64(result).ptr(k);
        });
        (result, k)
    }

    /// `zeKernelDestroy`.
    pub fn ze_kernel_destroy(&self, kernel: u64) -> u64 {
        emit(TPS.kernel_destroy.0, |e| {
            e.ptr(kernel);
        });
        let ok = self.state.lock().unwrap().kernels.remove(&kernel).is_some();
        let result = if ok { ze_result::SUCCESS } else { ze_result::INVALID_NULL_HANDLE };
        emit(TPS.kernel_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeKernelSetGroupSize`.
    pub fn ze_kernel_set_group_size(&self, kernel: u64, x: u32, y: u32, z: u32) -> u64 {
        emit(TPS.kernel_set_group_size.0, |e| {
            e.ptr(kernel).u64(x as u64).u64(y as u64).u64(z as u64);
        });
        let mut st = self.state.lock().unwrap();
        let result = match st.kernels.get_mut(&kernel) {
            Some(k) => {
                k.group_size = (x, y, z);
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.kernel_set_group_size.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `zeKernelSetArgumentValue` — `value` is the 8-byte argument (a
    /// device pointer); both the fabricated `pArgValue` host address and
    /// the value behind it are traced (paper: "values behind pointers").
    pub fn ze_kernel_set_argument_value(&self, kernel: u64, index: u32, value: u64) -> u64 {
        let p_arg = self.desc();
        emit(TPS.kernel_set_argument_value.0, |e| {
            e.ptr(kernel).u64(index as u64).u64(8).ptr(p_arg).u64(value);
        });
        let mut st = self.state.lock().unwrap();
        let result = match st.kernels.get_mut(&kernel) {
            Some(k) => {
                k.args.insert(index, value);
                ze_result::SUCCESS
            }
            None => ze_result::INVALID_NULL_HANDLE,
        };
        drop(st);
        emit(TPS.kernel_set_argument_value.1, |e| {
            e.u64(result);
        });
        result
    }

    /// Convenience for layered runtimes (HIP/OMP): pick the engine
    /// ordinal for a transfer. The fixed runtime uses the copy engine,
    /// the buggy one (§4.1) the compute engine.
    pub fn copy_ordinal(&self, device: u64, use_copy_engine: bool) -> u32 {
        match self.gpu_by_handle(device) {
            Some(g) if use_copy_engine => g.tiles, // first copy engine
            _ => 0,                                // compute engine 0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    fn driver() -> Arc<ZeDriver> {
        ZeDriver::new(Node::new(NodeConfig::test_small()))
    }

    /// Full happy-path: init → alloc → copy in → launch saxpy → copy out.
    #[test]
    fn end_to_end_saxpy_via_ze_api() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let ze = driver();
        assert_eq!(ze.ze_init(0), ze_result::SUCCESS);
        let mut drivers = vec![];
        let (r, n) = ze.ze_driver_get(&mut drivers);
        assert_eq!((r, n), (ze_result::SUCCESS, 1));
        let mut devices = vec![];
        let (r, n) = ze.ze_device_get(drivers[0], &mut devices);
        assert_eq!(r, ze_result::SUCCESS);
        assert_eq!(n, 1);
        let dev = devices[0];
        let (_, ctx) = ze.ze_context_create(drivers[0]);

        let elems = 1usize << 20;
        let bytes = (elems * 4) as u64;
        let (_, ha) = ze.ze_mem_alloc_host(ctx, 4, 4);
        let (_, hx) = ze.ze_mem_alloc_host(ctx, bytes, 64);
        let (_, hy) = ze.ze_mem_alloc_host(ctx, bytes, 64);
        let (_, da) = ze.ze_mem_alloc_device(ctx, 4, 4, dev);
        let (_, dx) = ze.ze_mem_alloc_device(ctx, bytes, 64, dev);
        let (_, dy) = ze.ze_mem_alloc_device(ctx, bytes, 64, dev);
        let (_, dout) = ze.ze_mem_alloc_device(ctx, bytes, 64, dev);
        assert!(da >= 0xff00_0000_0000_0000, "device ptrs are 0xff-tagged");

        // host data
        let gpu = ze.node.gpu(0);
        gpu.pool.write(ha, &2.0f32.to_le_bytes()).unwrap();
        gpu.pool
            .write(hx, &crate::runtime::executor::f32_to_bytes(&vec![3.0; elems]))
            .unwrap();
        gpu.pool
            .write(hy, &crate::runtime::executor::f32_to_bytes(&vec![1.0; elems]))
            .unwrap();

        let (_, module) = ze.ze_module_create(ctx, dev, "saxpy");
        assert_ne!(module, 0);
        let (_, kernel) = ze.ze_kernel_create(module, "saxpy");
        ze.ze_kernel_set_group_size(kernel, 64, 1, 1);
        ze.ze_kernel_set_argument_value(kernel, 0, da);
        ze.ze_kernel_set_argument_value(kernel, 1, dx);
        ze.ze_kernel_set_argument_value(kernel, 2, dy);
        ze.ze_kernel_set_argument_value(kernel, 3, dout);

        let (_, queue) = ze.ze_command_queue_create(ctx, dev, 0);
        let (_, list) = ze.ze_command_list_create(ctx, dev);
        ze.ze_command_list_append_memory_copy(list, da, ha, 4, 0);
        ze.ze_command_list_append_memory_copy(list, dx, hx, bytes, 0);
        ze.ze_command_list_append_memory_copy(list, dy, hy, bytes, 0);
        ze.ze_command_list_append_launch_kernel(list, kernel, (16, 1, 1), 0);
        ze.ze_command_list_append_memory_copy(list, hy, dout, bytes, 0);
        assert_eq!(ze.ze_command_list_close(list), ze_result::SUCCESS);
        assert_eq!(
            ze.ze_command_queue_execute_command_lists(queue, &[list]),
            ze_result::SUCCESS
        );
        assert_eq!(ze.ze_command_queue_synchronize(queue, u64::MAX), ze_result::SUCCESS);

        let out = crate::runtime::executor::bytes_to_f32(&gpu.pool.read(hy, bytes).unwrap());
        assert!(out.iter().all(|&v| (v - 7.0).abs() < 1e-6), "saxpy through ZE wrong");

        let session = uninstall_session().unwrap();
        let stats = session.stats();
        // every API call above contributed entry+exit, plus profiling events
        assert!(stats.written > 40, "expected >40 events, got {}", stats.written);
        assert_eq!(stats.dropped, 0);
    }

    #[test]
    fn event_spin_wait_pattern() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let ze = driver();
        ze.ze_init(0);
        let mut drivers = vec![];
        ze.ze_driver_get(&mut drivers);
        let mut devices = vec![];
        ze.ze_device_get(drivers[0], &mut devices);
        let (_, ctx) = ze.ze_context_create(drivers[0]);
        let (_, pool) = ze.ze_event_pool_create(ctx, 4);
        let (_, ev) = ze.ze_event_create(pool);
        // not signaled: poll returns NOT_READY
        assert_eq!(ze.ze_event_host_synchronize(ev, 0), ze_result::NOT_READY);
        assert_eq!(ze.ze_event_query_status(ev), ze_result::NOT_READY);
        // signal through a barrier command
        let (_, queue) = ze.ze_command_queue_create(ctx, devices[0], 0);
        let (_, list) = ze.ze_command_list_create(ctx, devices[0]);
        ze.ze_command_list_append_barrier(list, ev);
        ze.ze_command_list_close(list);
        ze.ze_command_queue_execute_command_lists(queue, &[list]);
        // spin like HIPLZ does
        let mut spins = 0u64;
        while ze.ze_event_host_synchronize(ev, 10_000) != ze_result::SUCCESS {
            spins += 1;
            assert!(spins < 1_000_000, "event never signaled");
        }
        assert_eq!(ze.ze_event_query_status(ev), ze_result::SUCCESS);
        ze.ze_event_host_reset(ev);
        assert_eq!(ze.ze_event_query_status(ev), ze_result::NOT_READY);
        ze.ze_command_queue_synchronize(queue, u64::MAX);
        uninstall_session();
    }

    #[test]
    fn invalid_handles_return_errors() {
        let _g = test_support::lock();
        let ze = driver();
        assert_eq!(ze.ze_context_destroy(0xbad), ze_result::INVALID_NULL_HANDLE);
        assert_eq!(ze.ze_command_list_close(0xbad), ze_result::INVALID_NULL_HANDLE);
        assert_eq!(ze.ze_mem_free(0, 0xbad), ze_result::INVALID_ARGUMENT);
        let (r, _) = ze.ze_kernel_create(0xbad, "saxpy");
        assert_eq!(r, ze_result::INVALID_NULL_HANDLE);
    }

    #[test]
    fn device_properties_reports_gpu_info() {
        let _g = test_support::lock();
        let ze = driver();
        ze.ze_init(0);
        let mut drivers = vec![];
        ze.ze_driver_get(&mut drivers);
        let mut devices = vec![];
        ze.ze_device_get(drivers[0], &mut devices);
        let mut props = ZeDeviceProperties { p_next: 0xdeadbeef, ..Default::default() };
        assert_eq!(ze.ze_device_get_properties(devices[0], &mut props), ze_result::SUCCESS);
        assert_eq!(props.num_tiles, 2);
        assert!(!props.name.is_empty());
    }
}
