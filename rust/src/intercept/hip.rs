//! HIP frontend implemented **on top of Level-Zero** — the HIPLZ stack of
//! the paper's §4.3 case study.
//!
//! Every `hip*` call is traced, and its implementation calls the *traced*
//! `ze*` frontend, so the trace shows the layering the paper analyzes:
//! `hipDeviceSynchronize` spinning on `zeEventHostSynchronize` (the
//! 9.9-million-call row), `hipMemcpy` decomposing into command-list
//! reset/append/close/execute, `hipModuleLoad` → `zeModuleCreate` (real
//! PJRT compile milliseconds), `hipUnregisterFatBinary` tearing down the
//! module state.

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use super::ze::{ze_result, ZeDriver};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// `hipError_t` values.
pub mod hip_error {
    /// Success.
    pub const SUCCESS: u64 = 0;
    /// Invalid value.
    pub const INVALID_VALUE: u64 = 1;
    /// Out of memory.
    pub const OUT_OF_MEMORY: u64 = 2;
    /// Not ready.
    pub const NOT_READY: u64 = 600;
}

/// `hipMemcpyKind` values.
pub mod memcpy_kind {
    /// Host → host.
    pub const H2H: u64 = 0;
    /// Host → device.
    pub const H2D: u64 = 1;
    /// Device → host.
    pub const D2H: u64 = 2;
    /// Device → device.
    pub const D2D: u64 = 3;
}

declare_tps!(pub(crate) HipTps, Api::Hip, {
    init: "hipInit",
    get_device_count: "hipGetDeviceCount",
    set_device: "hipSetDevice",
    device_synchronize: "hipDeviceSynchronize",
    malloc: "hipMalloc",
    free: "hipFree",
    memcpy: "hipMemcpy",
    module_load: "hipModuleLoad",
    module_get_function: "hipModuleGetFunction",
    module_unload: "hipModuleUnload",
    launch_kernel: "hipLaunchKernel",
    stream_create: "hipStreamCreate",
    stream_synchronize: "hipStreamSynchronize",
    stream_destroy: "hipStreamDestroy",
    register_fat_binary: "hipRegisterFatBinary",
    unregister_fat_binary: "hipUnregisterFatBinary",
});

static TPS: Lazy<HipTps> = Lazy::new(HipTps::load);

/// Per-device Level-Zero state HIPLZ keeps (context, queue, reusable
/// command list, pool + completion event).
struct DeviceState {
    ze_device: u64,
    queue: u64,
    list: u64,
    event: u64,
}

#[derive(Default)]
struct HipState {
    current: u32,
    ctx: u64,
    devices: Vec<DeviceState>,
    modules: HashMap<u64, u64>,   // hip module -> ze module
    functions: HashMap<u64, u64>, // hip function -> ze kernel
    fat_binaries: HashMap<u64, Vec<u64>>,
    streams: HashMap<u64, u32>,   // stream -> device index
    pending: Vec<u64>,            // ze events not yet synchronized
}

/// The HIPLZ runtime.
pub struct HipRuntime {
    /// The Level-Zero backend this HIP runs on.
    pub ze: Arc<ZeDriver>,
    handles: HandleAllocator,
    state: Mutex<HipState>,
    /// Spin-wait timeout per `zeEventHostSynchronize` call (ns). Small
    /// values reproduce the paper's huge call counts; tests raise it.
    pub spin_timeout_ns: u64,
}

impl HipRuntime {
    /// Create the HIP runtime over a ZE driver.
    pub fn new(ze: Arc<ZeDriver>) -> Arc<Self> {
        Arc::new(HipRuntime {
            ze,
            handles: HandleAllocator::new(),
            state: Mutex::new(HipState::default()),
            spin_timeout_ns: 20_000,
        })
    }

    /// `hipInit` — initializes Level-Zero underneath (traced layering).
    pub fn hip_init(&self, flags: u32) -> u64 {
        emit(TPS.init.0, |e| {
            e.u64(flags as u64);
        });
        self.ze.ze_init(0);
        let mut drivers = vec![];
        self.ze.ze_driver_get(&mut drivers);
        let mut devices = vec![];
        self.ze.ze_device_get(drivers[0], &mut devices);
        let (_, ctx) = self.ze.ze_context_create(drivers[0]);
        let mut st = self.state.lock().unwrap();
        st.ctx = ctx;
        for d in devices {
            let (_, queue) = self.ze.ze_command_queue_create(ctx, d, 0);
            let (_, list) = self.ze.ze_command_list_create(ctx, d);
            let (_, pool) = self.ze.ze_event_pool_create(ctx, 16);
            let (_, event) = self.ze.ze_event_create(pool);
            st.devices.push(DeviceState { ze_device: d, queue, list, event });
        }
        drop(st);
        emit(TPS.init.1, |e| {
            e.u64(hip_error::SUCCESS);
        });
        hip_error::SUCCESS
    }

    /// `hipGetDeviceCount`.
    pub fn hip_get_device_count(&self) -> (u64, i32) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.get_device_count.0, |e| {
            e.ptr(p);
        });
        let n = self.state.lock().unwrap().devices.len() as i32;
        emit(TPS.get_device_count.1, |e| {
            e.u64(hip_error::SUCCESS).i64(n as i64);
        });
        (hip_error::SUCCESS, n)
    }

    /// `hipSetDevice`.
    pub fn hip_set_device(&self, device: i32) -> u64 {
        emit(TPS.set_device.0, |e| {
            e.i64(device as i64);
        });
        let mut st = self.state.lock().unwrap();
        let result = if (device as usize) < st.devices.len() {
            st.current = device as u32;
            hip_error::SUCCESS
        } else {
            hip_error::INVALID_VALUE
        };
        drop(st);
        emit(TPS.set_device.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `hipMalloc` → `zeMemAllocDevice`.
    pub fn hip_malloc(&self, size: u64) -> (u64, u64) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.malloc.0, |e| {
            e.ptr(p).u64(size);
        });
        let (ctx, dev) = {
            let st = self.state.lock().unwrap();
            (st.ctx, st.devices[st.current as usize].ze_device)
        };
        let (zr, ptr) = self.ze.ze_mem_alloc_device(ctx, size, 64, dev);
        let result = if zr == ze_result::SUCCESS {
            hip_error::SUCCESS
        } else {
            hip_error::OUT_OF_MEMORY
        };
        emit(TPS.malloc.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `hipFree` → `zeMemFree`.
    pub fn hip_free(&self, ptr: u64) -> u64 {
        emit(TPS.free.0, |e| {
            e.ptr(ptr);
        });
        let ctx = self.state.lock().unwrap().ctx;
        let zr = self.ze.ze_mem_free(ctx, ptr);
        let result = if zr == ze_result::SUCCESS {
            hip_error::SUCCESS
        } else {
            hip_error::INVALID_VALUE
        };
        emit(TPS.free.1, |e| {
            e.u64(result);
        });
        result
    }

    /// Spin on `zeEventHostSynchronize` until success — the HIPLZ pattern
    /// (paper §4.3: hipDeviceSynchronize implemented on a spin lock).
    fn spin_event(&self, event: u64) {
        loop {
            if self.ze.ze_event_host_synchronize(event, self.spin_timeout_ns)
                == ze_result::SUCCESS
            {
                return;
            }
        }
    }

    /// `hipMemcpy` (synchronous) → ZE list reset/append/close/execute +
    /// event spin.
    pub fn hip_memcpy(&self, dst: u64, src: u64, size: u64, kind: u64) -> u64 {
        emit(TPS.memcpy.0, |e| {
            e.ptr(dst).ptr(src).u64(size).u64(kind);
        });
        let (queue, list, event) = {
            let st = self.state.lock().unwrap();
            let d = &st.devices[st.current as usize];
            (d.queue, d.list, d.event)
        };
        self.ze.ze_command_list_reset(list);
        self.ze.ze_event_host_reset(event);
        self.ze.ze_command_list_append_memory_copy(list, dst, src, size, event);
        self.ze.ze_command_list_close(list);
        self.ze.ze_command_queue_execute_command_lists(queue, &[list]);
        self.spin_event(event);
        self.ze.ze_command_queue_synchronize(queue, u64::MAX);
        emit(TPS.memcpy.1, |e| {
            e.u64(hip_error::SUCCESS);
        });
        hip_error::SUCCESS
    }

    /// `hipModuleLoad` → `zeModuleCreate` (real compile cost).
    pub fn hip_module_load(&self, fname: &str) -> (u64, u64) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.module_load.0, |e| {
            e.ptr(p).str(fname);
        });
        let (ctx, dev) = {
            let st = self.state.lock().unwrap();
            (st.ctx, st.devices[st.current as usize].ze_device)
        };
        let (zr, ze_module) = self.ze.ze_module_create(ctx, dev, fname);
        let (result, module) = if zr == ze_result::SUCCESS {
            let m = self.handles.alloc(HandleKind::Module);
            self.state.lock().unwrap().modules.insert(m, ze_module);
            (hip_error::SUCCESS, m)
        } else {
            (hip_error::INVALID_VALUE, 0)
        };
        emit(TPS.module_load.1, |e| {
            e.u64(result).ptr(module);
        });
        (result, module)
    }

    /// `hipModuleGetFunction` → `zeKernelCreate`.
    pub fn hip_module_get_function(&self, module: u64, kname: &str) -> (u64, u64) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.module_get_function.0, |e| {
            e.ptr(p).ptr(module).str(kname);
        });
        let ze_module = self.state.lock().unwrap().modules.get(&module).copied();
        let (result, f) = match ze_module {
            Some(zm) => {
                let (zr, zk) = self.ze.ze_kernel_create(zm, kname);
                if zr == ze_result::SUCCESS {
                    let f = self.handles.alloc(HandleKind::Kernel);
                    self.state.lock().unwrap().functions.insert(f, zk);
                    (hip_error::SUCCESS, f)
                } else {
                    (hip_error::INVALID_VALUE, 0)
                }
            }
            None => (hip_error::INVALID_VALUE, 0),
        };
        emit(TPS.module_get_function.1, |e| {
            e.u64(result).ptr(f);
        });
        (result, f)
    }

    /// `hipModuleUnload` → `zeModuleDestroy`.
    pub fn hip_module_unload(&self, module: u64) -> u64 {
        emit(TPS.module_unload.0, |e| {
            e.ptr(module);
        });
        let ze_module = self.state.lock().unwrap().modules.remove(&module);
        let result = match ze_module {
            Some(zm) => {
                self.ze.ze_module_destroy(zm);
                hip_error::SUCCESS
            }
            None => hip_error::INVALID_VALUE,
        };
        emit(TPS.module_unload.1, |e| {
            e.u64(result);
        });
        result
    }

    /// Set kernel args then `hipLaunchKernel` → ZE set-args + append +
    /// execute (asynchronous; completion observed at a later sync).
    pub fn hip_launch_kernel(
        &self,
        f: u64,
        grid: (u32, u32, u32),
        block: (u32, u32, u32),
        shared_mem: u32,
        stream: u64,
        params: &[u64],
    ) -> u64 {
        emit(TPS.launch_kernel.0, |e| {
            e.ptr(f)
                .u64(grid.0 as u64)
                .u64(grid.1 as u64)
                .u64(grid.2 as u64)
                .u64(block.0 as u64)
                .u64(block.1 as u64)
                .u64(block.2 as u64)
                .u64(shared_mem as u64)
                .ptr(stream);
        });
        let (zk, queue, list, event) = {
            let st = self.state.lock().unwrap();
            let d = &st.devices[st.current as usize];
            match st.functions.get(&f) {
                Some(zk) => (*zk, d.queue, d.list, d.event),
                None => {
                    drop(st);
                    emit(TPS.launch_kernel.1, |e| {
                        e.u64(hip_error::INVALID_VALUE);
                    });
                    return hip_error::INVALID_VALUE;
                }
            }
        };
        for (i, p) in params.iter().enumerate() {
            self.ze.ze_kernel_set_argument_value(zk, i as u32, *p);
        }
        self.ze.ze_kernel_set_group_size(zk, block.0, block.1, block.2);
        self.ze.ze_command_list_reset(list);
        self.ze.ze_event_host_reset(event);
        self.ze.ze_command_list_append_launch_kernel(list, zk, grid, event);
        self.ze.ze_command_list_close(list);
        self.ze.ze_command_queue_execute_command_lists(queue, &[list]);
        self.state.lock().unwrap().pending.push(event);
        emit(TPS.launch_kernel.1, |e| {
            e.u64(hip_error::SUCCESS);
        });
        hip_error::SUCCESS
    }

    /// `hipDeviceSynchronize` — spins on `zeEventHostSynchronize` for every
    /// pending event then drains the queue (the §4.3 hot row).
    pub fn hip_device_synchronize(&self) -> u64 {
        emit(TPS.device_synchronize.0, |_e| {});
        let (pending, queue) = {
            let mut st = self.state.lock().unwrap();
            let d = &st.devices[st.current as usize];
            let q = d.queue;
            (std::mem::take(&mut st.pending), q)
        };
        for ev in pending {
            self.spin_event(ev);
        }
        self.ze.ze_command_queue_synchronize(queue, u64::MAX);
        emit(TPS.device_synchronize.1, |e| {
            e.u64(hip_error::SUCCESS);
        });
        hip_error::SUCCESS
    }

    /// `hipStreamCreate` (streams share the device queue in HIPLZ-style).
    pub fn hip_stream_create(&self) -> (u64, u64) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.stream_create.0, |e| {
            e.ptr(p);
        });
        let stream = self.handles.alloc(HandleKind::Queue);
        let cur = self.state.lock().unwrap().current;
        self.state.lock().unwrap().streams.insert(stream, cur);
        emit(TPS.stream_create.1, |e| {
            e.u64(hip_error::SUCCESS).ptr(stream);
        });
        (hip_error::SUCCESS, stream)
    }

    /// `hipStreamSynchronize` — same spin pattern as device sync.
    pub fn hip_stream_synchronize(&self, stream: u64) -> u64 {
        emit(TPS.stream_synchronize.0, |e| {
            e.ptr(stream);
        });
        let known = self.state.lock().unwrap().streams.contains_key(&stream);
        let result = if known {
            let (pending, queue) = {
                let mut st = self.state.lock().unwrap();
                let d = &st.devices[st.current as usize];
                let q = d.queue;
                (std::mem::take(&mut st.pending), q)
            };
            for ev in pending {
                self.spin_event(ev);
            }
            self.ze.ze_command_queue_synchronize(queue, u64::MAX);
            hip_error::SUCCESS
        } else {
            hip_error::INVALID_VALUE
        };
        emit(TPS.stream_synchronize.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `hipStreamDestroy`.
    pub fn hip_stream_destroy(&self, stream: u64) -> u64 {
        emit(TPS.stream_destroy.0, |e| {
            e.ptr(stream);
        });
        let ok = self.state.lock().unwrap().streams.remove(&stream).is_some();
        let result = if ok { hip_error::SUCCESS } else { hip_error::INVALID_VALUE };
        emit(TPS.stream_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `hipRegisterFatBinary` — eagerly builds every module in the binary
    /// (one per kernel name), like HIPLZ does at program start.
    pub fn hip_register_fat_binary(&self, kernels: &[&str]) -> (u64, u64) {
        let data = self.handles.alloc(HandleKind::Desc);
        let ph = self.handles.alloc(HandleKind::Desc);
        emit(TPS.register_fat_binary.0, |e| {
            e.ptr(data).ptr(ph);
        });
        let handle = self.handles.alloc(HandleKind::Module);
        let mut modules = Vec::new();
        let (ctx, dev) = {
            let st = self.state.lock().unwrap();
            (st.ctx, st.devices[st.current as usize].ze_device)
        };
        for k in kernels {
            let (zr, zm) = self.ze.ze_module_create(ctx, dev, k);
            if zr == ze_result::SUCCESS {
                modules.push(zm);
            }
        }
        self.state.lock().unwrap().fat_binaries.insert(handle, modules);
        emit(TPS.register_fat_binary.1, |e| {
            e.u64(hip_error::SUCCESS).ptr(handle);
        });
        (hip_error::SUCCESS, handle)
    }

    /// `hipUnregisterFatBinary` — tears every module down (the 500 ms row
    /// in the §4.3 tally is this teardown; ours costs what module
    /// destruction really costs).
    pub fn hip_unregister_fat_binary(&self, handle: u64) -> u64 {
        emit(TPS.unregister_fat_binary.0, |e| {
            e.ptr(handle);
        });
        let modules = self.state.lock().unwrap().fat_binaries.remove(&handle);
        let result = match modules {
            Some(ms) => {
                for m in ms {
                    self.ze.ze_module_destroy(m);
                }
                hip_error::SUCCESS
            }
            None => hip_error::INVALID_VALUE,
        };
        emit(TPS.unregister_fat_binary.1, |e| {
            e.u64(result);
        });
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Node, NodeConfig};
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    fn hip() -> Arc<HipRuntime> {
        let node = Node::new(NodeConfig::test_small());
        HipRuntime::new(ZeDriver::new(node))
    }

    #[test]
    fn hip_layers_on_ze_lrn_end_to_end() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let hip = hip();
        hip.hip_init(0);
        let (_, n) = hip.hip_get_device_count();
        assert_eq!(n, 1);
        hip.hip_set_device(0);

        // LRN: x (32,64,256) f32 -> same shape
        let elems = 32 * 64 * 256usize;
        let bytes = (elems * 4) as u64;
        let (_, dx) = hip.hip_malloc(bytes);
        let (_, dout) = hip.hip_malloc(bytes);
        let (_, hsrc) = hip.hip_malloc(16); // small scratch (device) — host data goes via pool
        let _ = hsrc;
        let gpu = hip.ze.node.gpu(0);
        // write input directly into device memory then memcpy device->device
        // to exercise the traced path
        let host = gpu.pool.alloc(crate::device::AllocKind::Host, bytes).unwrap();
        gpu.pool
            .write(host, &crate::runtime::executor::f32_to_bytes(&vec![0.5; elems]))
            .unwrap();
        hip.hip_memcpy(dx, host, bytes, memcpy_kind::H2D);

        let (_, module) = hip.hip_module_load("lrn");
        let (_, f) = hip.hip_module_get_function(module, "lrn");
        assert_eq!(
            hip.hip_launch_kernel(f, (32, 1, 1), (64, 1, 1), 0, 0, &[dx, dout]),
            hip_error::SUCCESS
        );
        hip.hip_device_synchronize();
        hip.hip_memcpy(host, dout, bytes, memcpy_kind::D2H);
        let out = crate::runtime::executor::bytes_to_f32(&gpu.pool.read(host, bytes).unwrap());
        // LRN of constant 0.5: out = 0.5 / (1 + alpha/n * n*0.25)^0.75 ≈ 0.5
        assert!(out.iter().all(|&v| (v - 0.4999).abs() < 0.01), "lrn numerics: {}", out[0]);

        let session = uninstall_session().unwrap();
        let trace = crate::tracer::btf::collect(&session, &[]);
        // layering: both hip and ze events must be present
        let md = crate::tracer::btf::parse_metadata(&trace.metadata).unwrap();
        let mut hip_events = 0u64;
        let mut ze_events = 0u64;
        for s in &trace.streams {
            crate::tracer::btf::iter_records(&s.bytes, |id, _, _| {
                let name = &md.classes[&id].name;
                if name.starts_with("lttng_ust_hip") {
                    hip_events += 1;
                }
                if name.starts_with("lttng_ust_ze") {
                    ze_events += 1;
                }
            });
        }
        assert!(hip_events > 10, "hip events: {hip_events}");
        assert!(
            ze_events > hip_events,
            "layering must produce more ze events ({ze_events}) than hip ({hip_events})"
        );
    }

    #[test]
    fn fat_binary_register_unregister() {
        let _g = test_support::lock();
        let hip = hip();
        hip.hip_init(0);
        let (r, handle) = hip.hip_register_fat_binary(&["saxpy", "lrn"]);
        assert_eq!(r, hip_error::SUCCESS);
        assert_eq!(hip.hip_unregister_fat_binary(handle), hip_error::SUCCESS);
        assert_eq!(hip.hip_unregister_fat_binary(handle), hip_error::INVALID_VALUE);
    }
}
