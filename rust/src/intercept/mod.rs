//! Interception frontends: the traced programming-model runtimes.
//!
//! In THAPI these are `LD_PRELOAD` interception libraries generated from
//! the API model; here each frontend is a Rust runtime that *implements*
//! its programming model over the simulated node, with every API function
//! wrapped in generated-descriptor tracepoints: an `_entry` event carrying
//! the full argument list (pointers, sizes, handles, values behind
//! pointers) and an `_exit` event carrying the result and out-pointer
//! values — the paper's core "complete call context" claim.
//!
//! Layering is real: the [`hip`] frontend (HIPLZ, §4.3) and the [`omp`]
//! frontend (§4.1) are implemented **on top of** [`ze`], so a traced
//! `hipMemcpy` produces the nested `ze*` events the paper's case studies
//! analyze.
//!
//! The debug-mode [`Encoder`](crate::tracer::Encoder) asserts every
//! wrapper's fields against the generated trace model, so wrappers cannot
//! drift from the model (the same guarantee THAPI gets by generating the
//! wrapper code itself).

pub mod cuda;
pub mod handles;
pub mod hip;
pub mod mpi;
pub mod omp;
pub mod opencl;
pub mod profiling;
pub mod ze;

pub use handles::HandleAllocator;

use crate::model::EventClass;

/// (entry, exit) event-class pair for one API function.
pub type TpPair = (&'static EventClass, &'static EventClass);

/// Declare a lazily-resolved tracepoint table for a frontend.
///
/// ```ignore
/// declare_tps!(pub(crate) ZeTps, Api::Ze, { init: "zeInit", ... });
/// static TPS: Lazy<ZeTps> = Lazy::new(ZeTps::load);
/// ```
macro_rules! declare_tps {
    ($vis:vis $name:ident, $api:expr, { $($field:ident: $fname:literal),+ $(,)? }) => {
        $vis struct $name {
            $(pub $field: crate::intercept::TpPair,)+
        }
        impl $name {
            pub(crate) fn load() -> Self {
                let r = crate::model::registry();
                Self { $($field: r.tp($api, $fname),)+ }
            }
        }
    };
}
pub(crate) use declare_tps;
