//! MPI frontend: a traced in-process MPI substrate.
//!
//! Ranks are threads (see [`MpiWorld::run`]); point-to-point messages move
//! through per-pair mailboxes, collectives are implemented over them. The
//! SPEChpc-like workloads (MPI + OpenMP offload, paper §5.1) run on this.
//! Every call is traced with buffer addresses, counts, datatypes, peers
//! and tags.

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Barrier, Condvar, Mutex};

/// MPI result codes.
pub mod mpi_result {
    /// MPI_SUCCESS.
    pub const SUCCESS: u64 = 0;
    /// MPI_ERR_OTHER.
    pub const ERR_OTHER: u64 = 1;
}

/// MPI datatypes (sizes in bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datatype {
    /// MPI_BYTE.
    Byte,
    /// MPI_INT.
    Int,
    /// MPI_FLOAT.
    Float,
    /// MPI_DOUBLE.
    Double,
}

impl Datatype {
    /// Wire code (matches the bundled header enum).
    pub fn code(&self) -> u64 {
        match self {
            Datatype::Byte => 0,
            Datatype::Int => 1,
            Datatype::Float => 2,
            Datatype::Double => 3,
        }
    }

    /// Element size.
    pub fn size(&self) -> usize {
        match self {
            Datatype::Byte => 1,
            Datatype::Int | Datatype::Float => 4,
            Datatype::Double => 8,
        }
    }
}

/// Reduction ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// MPI_SUM.
    Sum,
    /// MPI_MAX.
    Max,
    /// MPI_MIN.
    Min,
}

impl Op {
    /// Wire code.
    pub fn code(&self) -> u64 {
        match self {
            Op::Sum => 0,
            Op::Max => 1,
            Op::Min => 2,
        }
    }
}

declare_tps!(pub(crate) MpiTps, Api::Mpi, {
    init: "MPI_Init",
    finalize: "MPI_Finalize",
    comm_size: "MPI_Comm_size",
    comm_rank: "MPI_Comm_rank",
    send: "MPI_Send",
    recv: "MPI_Recv",
    isend: "MPI_Isend",
    irecv: "MPI_Irecv",
    wait: "MPI_Wait",
    test: "MPI_Test",
    allreduce: "MPI_Allreduce",
    barrier: "MPI_Barrier",
});

static TPS: Lazy<MpiTps> = Lazy::new(MpiTps::load);

/// MPI_COMM_WORLD handle value (traced).
pub const COMM_WORLD: u64 = 0x4400_0000;

struct Mailbox {
    queues: Mutex<HashMap<(u32, u32, i32), VecDeque<Vec<u8>>>>, // (src,dst,tag)
    cond: Condvar,
}

struct Shared {
    size: u32,
    mailbox: Mailbox,
    barrier: Barrier,
    // allreduce rendezvous state
    reduce: Mutex<ReduceState>,
    reduce_cond: Condvar,
}

#[derive(Default)]
struct ReduceState {
    round: u64,
    contributions: Vec<Vec<f64>>,
    result: Vec<f64>,
    done_count: u32,
}

/// The world shared by all ranks.
pub struct MpiWorld {
    shared: Arc<Shared>,
}

impl MpiWorld {
    /// Create a world of `size` ranks.
    pub fn new(size: u32) -> Arc<Self> {
        Arc::new(MpiWorld {
            shared: Arc::new(Shared {
                size,
                mailbox: Mailbox { queues: Mutex::new(HashMap::new()), cond: Condvar::new() },
                barrier: Barrier::new(size as usize),
                reduce: Mutex::new(ReduceState::default()),
                reduce_cond: Condvar::new(),
            }),
        })
    }

    /// Run `f(rank_comm)` on `size` threads, one per rank. Each thread's
    /// tracer rank is set so traces are per-rank attributable (§3.2
    /// rank-selective tracing). Panics in any rank propagate.
    pub fn run<F>(self: &Arc<Self>, f: F)
    where
        F: Fn(MpiComm) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let mut handles = Vec::new();
        for rank in 0..self.shared.size {
            let shared = self.shared.clone();
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mpi-rank-{rank}"))
                    .spawn(move || {
                        crate::tracer::set_thread_rank(rank);
                        f(MpiComm { rank, shared, handles: HandleAllocator::new(), requests: Mutex::new(HashMap::new()) });
                        crate::tracer::set_thread_rank(0);
                    })
                    .expect("spawn rank"),
            );
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    }
}

enum PendingRequest {
    /// Isend already delivered (buffered send): wait is a no-op.
    SendDone,
    /// Irecv: receive happens at wait time.
    Recv { src: u32, tag: i32, dst_ptr: usize, max_len: usize },
}

/// One rank's communicator endpoint.
pub struct MpiComm {
    rank: u32,
    shared: Arc<Shared>,
    handles: HandleAllocator,
    requests: Mutex<HashMap<u64, PendingRequest>>,
}

impl MpiComm {
    /// This rank.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// World size.
    pub fn size(&self) -> u32 {
        self.shared.size
    }

    /// `MPI_Init`.
    pub fn mpi_init(&self) -> u64 {
        emit(TPS.init.0, |_e| {});
        emit(TPS.init.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }

    /// `MPI_Finalize`.
    pub fn mpi_finalize(&self) -> u64 {
        emit(TPS.finalize.0, |_e| {});
        emit(TPS.finalize.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }

    /// `MPI_Comm_size`.
    pub fn mpi_comm_size(&self) -> (u64, i32) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.comm_size.0, |e| {
            e.ptr(COMM_WORLD).ptr(p);
        });
        let n = self.shared.size as i32;
        emit(TPS.comm_size.1, |e| {
            e.u64(mpi_result::SUCCESS).i64(n as i64);
        });
        (mpi_result::SUCCESS, n)
    }

    /// `MPI_Comm_rank`.
    pub fn mpi_comm_rank(&self) -> (u64, i32) {
        let p = self.handles.alloc(HandleKind::Desc);
        emit(TPS.comm_rank.0, |e| {
            e.ptr(COMM_WORLD).ptr(p);
        });
        let r = self.rank as i32;
        emit(TPS.comm_rank.1, |e| {
            e.u64(mpi_result::SUCCESS).i64(r as i64);
        });
        (mpi_result::SUCCESS, r)
    }

    fn deliver(&self, dst: u32, tag: i32, data: Vec<u8>) {
        let mut q = self.shared.mailbox.queues.lock().unwrap();
        q.entry((self.rank, dst, tag)).or_default().push_back(data);
        self.shared.mailbox.cond.notify_all();
    }

    fn receive(&self, src: u32, tag: i32) -> Vec<u8> {
        let mut q = self.shared.mailbox.queues.lock().unwrap();
        loop {
            if let Some(dq) = q.get_mut(&(src, self.rank, tag)) {
                if let Some(msg) = dq.pop_front() {
                    return msg;
                }
            }
            q = self.shared.mailbox.cond.wait(q).unwrap();
        }
    }

    /// `MPI_Send` (buffered, non-blocking delivery).
    pub fn mpi_send(&self, buf: &[u8], datatype: Datatype, dest: u32, tag: i32) -> u64 {
        let count = (buf.len() / datatype.size()) as i64;
        emit(TPS.send.0, |e| {
            e.ptr(buf.as_ptr() as u64)
                .i64(count)
                .u64(datatype.code())
                .i64(dest as i64)
                .i64(tag as i64)
                .ptr(COMM_WORLD);
        });
        self.deliver(dest, tag, buf.to_vec());
        emit(TPS.send.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }

    /// `MPI_Recv` (blocking).
    pub fn mpi_recv(&self, buf: &mut [u8], datatype: Datatype, source: u32, tag: i32) -> u64 {
        let count = (buf.len() / datatype.size()) as i64;
        emit(TPS.recv.0, |e| {
            e.ptr(buf.as_ptr() as u64)
                .i64(count)
                .u64(datatype.code())
                .i64(source as i64)
                .i64(tag as i64)
                .ptr(COMM_WORLD);
        });
        let msg = self.receive(source, tag);
        let n = msg.len().min(buf.len());
        buf[..n].copy_from_slice(&msg[..n]);
        emit(TPS.recv.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }

    /// `MPI_Isend` (buffered — completes immediately; request for Wait).
    pub fn mpi_isend(&self, buf: &[u8], datatype: Datatype, dest: u32, tag: i32) -> (u64, u64) {
        let count = (buf.len() / datatype.size()) as i64;
        let preq = self.handles.alloc(HandleKind::Desc);
        emit(TPS.isend.0, |e| {
            e.ptr(buf.as_ptr() as u64)
                .i64(count)
                .u64(datatype.code())
                .i64(dest as i64)
                .i64(tag as i64)
                .ptr(COMM_WORLD)
                .ptr(preq);
        });
        self.deliver(dest, tag, buf.to_vec());
        let req = self.handles.alloc(HandleKind::Request);
        self.requests.lock().unwrap().insert(req, PendingRequest::SendDone);
        emit(TPS.isend.1, |e| {
            e.u64(mpi_result::SUCCESS).ptr(req);
        });
        (mpi_result::SUCCESS, req)
    }

    /// `MPI_Irecv` — the receive is performed at `MPI_Wait`.
    pub fn mpi_irecv(
        &self,
        buf: &mut [u8],
        datatype: Datatype,
        source: u32,
        tag: i32,
    ) -> (u64, u64) {
        let count = (buf.len() / datatype.size()) as i64;
        let preq = self.handles.alloc(HandleKind::Desc);
        emit(TPS.irecv.0, |e| {
            e.ptr(buf.as_ptr() as u64)
                .i64(count)
                .u64(datatype.code())
                .i64(source as i64)
                .i64(tag as i64)
                .ptr(COMM_WORLD)
                .ptr(preq);
        });
        let req = self.handles.alloc(HandleKind::Request);
        self.requests.lock().unwrap().insert(
            req,
            PendingRequest::Recv {
                src: source,
                tag,
                dst_ptr: buf.as_mut_ptr() as usize,
                max_len: buf.len(),
            },
        );
        emit(TPS.irecv.1, |e| {
            e.u64(mpi_result::SUCCESS).ptr(req);
        });
        (mpi_result::SUCCESS, req)
    }

    /// `MPI_Wait`.
    ///
    /// # Safety contract
    /// The buffer passed to the matching `mpi_irecv` must outlive the wait
    /// (guaranteed by the workloads, which keep buffers alive across the
    /// exchange; real MPI has the same requirement).
    pub fn mpi_wait(&self, request: u64) -> u64 {
        let preq = self.handles.alloc(HandleKind::Desc);
        emit(TPS.wait.0, |e| {
            e.ptr(preq);
        });
        let pending = self.requests.lock().unwrap().remove(&request);
        let result = match pending {
            Some(PendingRequest::SendDone) => mpi_result::SUCCESS,
            Some(PendingRequest::Recv { src, tag, dst_ptr, max_len }) => {
                let msg = self.receive(src, tag);
                let n = msg.len().min(max_len);
                // SAFETY: see doc comment — the irecv buffer is alive.
                unsafe {
                    std::ptr::copy_nonoverlapping(msg.as_ptr(), dst_ptr as *mut u8, n);
                }
                mpi_result::SUCCESS
            }
            None => mpi_result::ERR_OTHER,
        };
        emit(TPS.wait.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `MPI_Test` (polling class — excluded from default tracing mode).
    pub fn mpi_test(&self, request: u64) -> (u64, bool) {
        let preq = self.handles.alloc(HandleKind::Desc);
        let pflag = self.handles.alloc(HandleKind::Desc);
        emit(TPS.test.0, |e| {
            e.ptr(preq).ptr(pflag);
        });
        let reqs = self.requests.lock().unwrap();
        let flag = match reqs.get(&request) {
            Some(PendingRequest::SendDone) => true,
            Some(PendingRequest::Recv { src, tag, .. }) => {
                let q = self.shared.mailbox.queues.lock().unwrap();
                q.get(&(*src, self.rank, *tag)).map(|d| !d.is_empty()).unwrap_or(false)
            }
            None => true,
        };
        drop(reqs);
        emit(TPS.test.1, |e| {
            e.u64(mpi_result::SUCCESS).i64(flag as i64);
        });
        (mpi_result::SUCCESS, flag)
    }

    /// `MPI_Allreduce` over f64 values (workloads reduce scalars/vectors of
    /// f64; other dtypes convert at the call site).
    pub fn mpi_allreduce(&self, send: &[f64], recv: &mut [f64], op: Op) -> u64 {
        assert_eq!(send.len(), recv.len());
        emit(TPS.allreduce.0, |e| {
            e.ptr(send.as_ptr() as u64)
                .ptr(recv.as_ptr() as u64)
                .i64(send.len() as i64)
                .u64(Datatype::Double.code())
                .u64(op.code())
                .ptr(COMM_WORLD);
        });
        {
            let mut st = self.shared.reduce.lock().unwrap();
            // wait for previous round to fully finish
            while st.done_count != 0 && st.contributions.len() == self.shared.size as usize {
                st = self.shared.reduce_cond.wait(st).unwrap();
            }
            st.contributions.push(send.to_vec());
            if st.contributions.len() == self.shared.size as usize {
                // last contributor reduces
                let mut acc = st.contributions[0].clone();
                for c in &st.contributions[1..] {
                    for (a, v) in acc.iter_mut().zip(c) {
                        *a = match op {
                            Op::Sum => *a + v,
                            Op::Max => a.max(*v),
                            Op::Min => a.min(*v),
                        };
                    }
                }
                st.result = acc;
                st.round += 1;
                self.shared.reduce_cond.notify_all();
            } else {
                let round = st.round;
                while st.round == round {
                    st = self.shared.reduce_cond.wait(st).unwrap();
                }
            }
            recv.copy_from_slice(&st.result);
            st.done_count += 1;
            if st.done_count == self.shared.size {
                st.contributions.clear();
                st.done_count = 0;
                self.shared.reduce_cond.notify_all();
            }
        }
        emit(TPS.allreduce.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }

    /// `MPI_Barrier`.
    pub fn mpi_barrier(&self) -> u64 {
        emit(TPS.barrier.0, |e| {
            e.ptr(COMM_WORLD);
        });
        self.shared.barrier.wait();
        emit(TPS.barrier.1, |e| {
            e.u64(mpi_result::SUCCESS);
        });
        mpi_result::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn ring_exchange_delivers_data() {
        let world = MpiWorld::new(4);
        let ok = Arc::new(AtomicU64::new(0));
        let ok2 = ok.clone();
        world.run(move |comm| {
            comm.mpi_init();
            let (_, size) = comm.mpi_comm_size();
            let (_, rank) = comm.mpi_comm_rank();
            let right = ((rank + 1) % size) as u32;
            let left = ((rank + size - 1) % size) as u32;
            let payload = vec![rank as u8; 64];
            comm.mpi_send(&payload, Datatype::Byte, right, 7);
            let mut buf = vec![0u8; 64];
            comm.mpi_recv(&mut buf, Datatype::Byte, left, 7);
            assert_eq!(buf, vec![left as u8; 64]);
            ok2.fetch_add(1, Ordering::Relaxed);
            comm.mpi_finalize();
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let world = MpiWorld::new(3);
        world.run(|comm| {
            comm.mpi_init();
            let r = comm.rank() as f64;
            let send = vec![r, 2.0 * r];
            let mut recv = vec![0.0; 2];
            comm.mpi_allreduce(&send, &mut recv, Op::Sum);
            assert_eq!(recv, vec![3.0, 6.0]); // 0+1+2, 0+2+4
            // second round works too (round-trip state machine)
            let mut recv2 = vec![0.0; 1];
            comm.mpi_allreduce(&[1.0], &mut recv2, Op::Max);
            assert_eq!(recv2, vec![1.0]);
            comm.mpi_finalize();
        });
    }

    #[test]
    fn isend_irecv_wait_roundtrip() {
        let world = MpiWorld::new(2);
        world.run(|comm| {
            if comm.rank() == 0 {
                let data = vec![1.5f64.to_le_bytes(), 2.5f64.to_le_bytes()].concat();
                let (_, req) = comm.mpi_isend(&data, Datatype::Double, 1, 3);
                comm.mpi_wait(req);
            } else {
                let mut buf = vec![0u8; 16];
                let (_, req) = comm.mpi_irecv(&mut buf, Datatype::Double, 0, 3);
                let (_, _flag) = comm.mpi_test(req);
                comm.mpi_wait(req);
                let v = f64::from_le_bytes(buf[0..8].try_into().unwrap());
                assert_eq!(v, 1.5);
            }
            comm.mpi_barrier();
        });
    }

    #[test]
    fn barrier_synchronizes_all_ranks() {
        let world = MpiWorld::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let c2 = counter.clone();
        world.run(move |comm| {
            c2.fetch_add(1, Ordering::SeqCst);
            comm.mpi_barrier();
            // after barrier, all 4 increments must be visible
            assert_eq!(c2.load(Ordering::SeqCst), 4);
        });
    }
}
