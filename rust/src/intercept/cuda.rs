//! CUDA driver-API frontend: the traced `cu*` runtime (Polaris-style
//! nodes, Table 1). Streams map to engines: kernel launches run on the
//! compute engine, memcpys on the copy engine; synchronous copies block on
//! a device event like real `cuMemcpy*`.

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use super::profiling;
use crate::device::{AllocKind, Command, DevEvent, Gpu, Node};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// `CUresult` values.
pub mod cu_result {
    /// Success.
    pub const SUCCESS: u64 = 0;
    /// Invalid value.
    pub const INVALID_VALUE: u64 = 1;
    /// Out of memory.
    pub const OUT_OF_MEMORY: u64 = 2;
    /// Not initialized.
    pub const NOT_INITIALIZED: u64 = 3;
    /// Async op not finished.
    pub const NOT_READY: u64 = 600;
}

declare_tps!(pub(crate) CudaTps, Api::Cuda, {
    init: "cuInit",
    device_get_count: "cuDeviceGetCount",
    device_get: "cuDeviceGet",
    ctx_create: "cuCtxCreate",
    ctx_destroy: "cuCtxDestroy",
    ctx_synchronize: "cuCtxSynchronize",
    mem_get_info: "cuMemGetInfo",
    mem_alloc: "cuMemAlloc",
    mem_alloc_host: "cuMemAllocHost",
    mem_free: "cuMemFree",
    memcpy_htod: "cuMemcpyHtoD",
    memcpy_dtoh: "cuMemcpyDtoH",
    memcpy_htod_async: "cuMemcpyHtoDAsync",
    memcpy_dtoh_async: "cuMemcpyDtoHAsync",
    module_load_data: "cuModuleLoadData",
    module_get_function: "cuModuleGetFunction",
    module_unload: "cuModuleUnload",
    stream_create: "cuStreamCreate",
    stream_destroy: "cuStreamDestroy",
    stream_synchronize: "cuStreamSynchronize",
    stream_query: "cuStreamQuery",
    launch_kernel: "cuLaunchKernel",
    event_create: "cuEventCreate",
    event_record: "cuEventRecord",
    event_query: "cuEventQuery",
    event_synchronize: "cuEventSynchronize",
    event_destroy: "cuEventDestroy",
});

static TPS: Lazy<CudaTps> = Lazy::new(CudaTps::load);

struct CuStream {
    gpu: u32,
    fences: Vec<Arc<DevEvent>>,
}

#[derive(Default)]
struct CuState {
    initialized: bool,
    current_device: u32,
    contexts: HashMap<u64, u32>,
    streams: HashMap<u64, CuStream>,
    modules: HashMap<u64, String>,
    functions: HashMap<u64, String>,
    events: HashMap<u64, Arc<DevEvent>>,
}

/// The CUDA driver for one node.
pub struct CudaDriver {
    /// The node.
    pub node: Arc<Node>,
    handles: HandleAllocator,
    state: Mutex<CuState>,
    /// The default (NULL) stream handle.
    pub default_stream: u64,
}

impl CudaDriver {
    /// Create the driver.
    pub fn new(node: Arc<Node>) -> Arc<Self> {
        let handles = HandleAllocator::new();
        let default_stream = handles.alloc(HandleKind::Queue);
        let d = Arc::new(CudaDriver {
            node,
            handles,
            state: Mutex::new(CuState::default()),
            default_stream,
        });
        d.state
            .lock()
            .unwrap()
            .streams
            .insert(default_stream, CuStream { gpu: 0, fences: Vec::new() });
        d
    }

    fn desc(&self) -> u64 {
        self.handles.alloc(HandleKind::Desc)
    }

    fn gpu(&self, index: u32) -> &Arc<Gpu> {
        &self.node.gpus[index as usize % self.node.gpus.len()]
    }

    /// `cuInit`.
    pub fn cu_init(&self, flags: u32) -> u64 {
        emit(TPS.init.0, |e| {
            e.u64(flags as u64);
        });
        self.state.lock().unwrap().initialized = true;
        emit(TPS.init.1, |e| {
            e.u64(cu_result::SUCCESS);
        });
        cu_result::SUCCESS
    }

    /// `cuDeviceGetCount`.
    pub fn cu_device_get_count(&self) -> (u64, i32) {
        let p = self.desc();
        emit(TPS.device_get_count.0, |e| {
            e.ptr(p);
        });
        let n = self.node.gpus.len() as i32;
        emit(TPS.device_get_count.1, |e| {
            e.u64(cu_result::SUCCESS).i64(n as i64);
        });
        (cu_result::SUCCESS, n)
    }

    /// `cuDeviceGet`.
    pub fn cu_device_get(&self, ordinal: i32) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.device_get.0, |e| {
            e.ptr(p).i64(ordinal as i64);
        });
        let (result, dev) = if (ordinal as usize) < self.node.gpus.len() {
            (cu_result::SUCCESS, self.node.gpus[ordinal as usize].handle)
        } else {
            (cu_result::INVALID_VALUE, 0)
        };
        emit(TPS.device_get.1, |e| {
            e.u64(result).ptr(dev);
        });
        (result, dev)
    }

    /// `cuCtxCreate` — also sets the current device.
    pub fn cu_ctx_create(&self, flags: u32, dev: u64) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.ctx_create.0, |e| {
            e.ptr(p).u64(flags as u64).ptr(dev);
        });
        let idx = self.node.gpus.iter().position(|g| g.handle == dev);
        let (result, ctx) = match idx {
            Some(i) => {
                let ctx = self.handles.alloc(HandleKind::Context);
                let mut st = self.state.lock().unwrap();
                st.contexts.insert(ctx, i as u32);
                st.current_device = i as u32;
                (cu_result::SUCCESS, ctx)
            }
            None => (cu_result::INVALID_VALUE, 0),
        };
        emit(TPS.ctx_create.1, |e| {
            e.u64(result).ptr(ctx);
        });
        (result, ctx)
    }

    /// `cuCtxDestroy`.
    pub fn cu_ctx_destroy(&self, ctx: u64) -> u64 {
        emit(TPS.ctx_destroy.0, |e| {
            e.ptr(ctx);
        });
        let ok = self.state.lock().unwrap().contexts.remove(&ctx).is_some();
        let result = if ok { cu_result::SUCCESS } else { cu_result::INVALID_VALUE };
        emit(TPS.ctx_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuCtxSynchronize` — device-wide sync + profiling drain.
    pub fn cu_ctx_synchronize(&self) -> u64 {
        emit(TPS.ctx_synchronize.0, |_e| {});
        let dev = self.state.lock().unwrap().current_device;
        let gpu = self.gpu(dev).clone();
        gpu.synchronize();
        profiling::drain_and_emit(&gpu, None);
        emit(TPS.ctx_synchronize.1, |e| {
            e.u64(cu_result::SUCCESS);
        });
        cu_result::SUCCESS
    }

    /// `cuMemGetInfo` — the paper's Fig. 3 running example.
    pub fn cu_mem_get_info(&self) -> (u64, u64, u64) {
        let pf = self.desc();
        let pt = self.desc();
        emit(TPS.mem_get_info.0, |e| {
            e.ptr(pf).ptr(pt);
        });
        let dev = self.state.lock().unwrap().current_device;
        let (used, total) = self.gpu(dev).pool.device_usage();
        let free = total - used;
        emit(TPS.mem_get_info.1, |e| {
            e.u64(cu_result::SUCCESS).u64(free).u64(total);
        });
        (cu_result::SUCCESS, free, total)
    }

    /// `cuMemAlloc`.
    pub fn cu_mem_alloc(&self, bytesize: u64) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.mem_alloc.0, |e| {
            e.ptr(p).u64(bytesize);
        });
        let dev = self.state.lock().unwrap().current_device;
        let (result, ptr) = match self.gpu(dev).alloc(AllocKind::Device, bytesize) {
            Ok(p) => (cu_result::SUCCESS, p),
            Err(_) => (cu_result::OUT_OF_MEMORY, 0),
        };
        emit(TPS.mem_alloc.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `cuMemAllocHost`.
    pub fn cu_mem_alloc_host(&self, bytesize: u64) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.mem_alloc_host.0, |e| {
            e.ptr(p).u64(bytesize);
        });
        let dev = self.state.lock().unwrap().current_device;
        let (result, ptr) = match self.gpu(dev).alloc(AllocKind::Host, bytesize) {
            Ok(p) => (cu_result::SUCCESS, p),
            Err(_) => (cu_result::OUT_OF_MEMORY, 0),
        };
        emit(TPS.mem_alloc_host.1, |e| {
            e.u64(result).ptr(ptr);
        });
        (result, ptr)
    }

    /// `cuMemFree`.
    pub fn cu_mem_free(&self, dptr: u64) -> u64 {
        emit(TPS.mem_free.0, |e| {
            e.ptr(dptr);
        });
        let mut result = cu_result::INVALID_VALUE;
        for g in &self.node.gpus {
            if g.free(dptr).is_ok() {
                result = cu_result::SUCCESS;
                break;
            }
        }
        emit(TPS.mem_free.1, |e| {
            e.u64(result);
        });
        result
    }

    fn sync_copy(&self, dst: u64, src: u64, bytes: u64) -> u64 {
        let dev = self.state.lock().unwrap().current_device;
        let gpu = self.gpu(dev).clone();
        let ev = Arc::new(DevEvent::new());
        let ordinal = gpu.tiles; // copy engine, tile 0
        gpu.submit(
            ordinal,
            self.default_stream,
            vec![Command::Memcpy { dst, src, bytes, signal: Some(ev.clone()) }],
            None,
        );
        if ev.wait(Duration::from_secs(600)) {
            profiling::drain_and_emit(&gpu, Some(self.default_stream));
            cu_result::SUCCESS
        } else {
            cu_result::NOT_READY
        }
    }

    /// `cuMemcpyHtoD` (synchronous).
    pub fn cu_memcpy_htod(&self, dst: u64, src: u64, bytes: u64) -> u64 {
        emit(TPS.memcpy_htod.0, |e| {
            e.ptr(dst).ptr(src).u64(bytes);
        });
        let result = self.sync_copy(dst, src, bytes);
        emit(TPS.memcpy_htod.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuMemcpyDtoH` (synchronous).
    pub fn cu_memcpy_dtoh(&self, dst: u64, src: u64, bytes: u64) -> u64 {
        emit(TPS.memcpy_dtoh.0, |e| {
            e.ptr(dst).ptr(src).u64(bytes);
        });
        let result = self.sync_copy(dst, src, bytes);
        emit(TPS.memcpy_dtoh.1, |e| {
            e.u64(result);
        });
        result
    }

    fn async_copy(&self, dst: u64, src: u64, bytes: u64, stream: u64) -> u64 {
        let mut st = self.state.lock().unwrap();
        let Some(s) = st.streams.get_mut(&stream) else {
            return cu_result::INVALID_VALUE;
        };
        let gpu = self.node.gpus[s.gpu as usize].clone();
        let fence = Arc::new(DevEvent::new());
        s.fences.push(fence.clone());
        drop(st);
        let ordinal = gpu.tiles;
        gpu.submit(
            ordinal,
            stream,
            vec![Command::Memcpy { dst, src, bytes, signal: None }],
            Some(fence),
        );
        cu_result::SUCCESS
    }

    /// `cuMemcpyHtoDAsync`.
    pub fn cu_memcpy_htod_async(&self, dst: u64, src: u64, bytes: u64, stream: u64) -> u64 {
        emit(TPS.memcpy_htod_async.0, |e| {
            e.ptr(dst).ptr(src).u64(bytes).ptr(stream);
        });
        let result = self.async_copy(dst, src, bytes, stream);
        emit(TPS.memcpy_htod_async.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuMemcpyDtoHAsync`.
    pub fn cu_memcpy_dtoh_async(&self, dst: u64, src: u64, bytes: u64, stream: u64) -> u64 {
        emit(TPS.memcpy_dtoh_async.0, |e| {
            e.ptr(dst).ptr(src).u64(bytes).ptr(stream);
        });
        let result = self.async_copy(dst, src, bytes, stream);
        emit(TPS.memcpy_dtoh_async.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuModuleLoadData` — `image` is the kernel name; compiles the
    /// artifact (real PJRT compile time).
    pub fn cu_module_load_data(&self, image: &str) -> (u64, u64) {
        let pm = self.desc();
        let pi = self.desc();
        emit(TPS.module_load_data.0, |e| {
            e.ptr(pm).ptr(pi);
        });
        let (result, module) = match self.node.executor.compile(image) {
            Ok(_) => {
                let m = self.handles.alloc(HandleKind::Module);
                self.state.lock().unwrap().modules.insert(m, image.to_string());
                (cu_result::SUCCESS, m)
            }
            Err(_) => (cu_result::INVALID_VALUE, 0),
        };
        emit(TPS.module_load_data.1, |e| {
            e.u64(result).ptr(module);
        });
        (result, module)
    }

    /// `cuModuleGetFunction`.
    pub fn cu_module_get_function(&self, module: u64, name: &str) -> (u64, u64) {
        let pf = self.desc();
        emit(TPS.module_get_function.0, |e| {
            e.ptr(pf).ptr(module).str(name);
        });
        let mut st = self.state.lock().unwrap();
        let (result, f) = match st.modules.get(&module) {
            Some(m) if m == name => {
                let f = self.handles.alloc(HandleKind::Kernel);
                st.functions.insert(f, name.to_string());
                (cu_result::SUCCESS, f)
            }
            Some(_) => (cu_result::INVALID_VALUE, 0),
            None => (cu_result::INVALID_VALUE, 0),
        };
        drop(st);
        emit(TPS.module_get_function.1, |e| {
            e.u64(result).ptr(f);
        });
        (result, f)
    }

    /// `cuModuleUnload`.
    pub fn cu_module_unload(&self, module: u64) -> u64 {
        emit(TPS.module_unload.0, |e| {
            e.ptr(module);
        });
        let ok = self.state.lock().unwrap().modules.remove(&module).is_some();
        let result = if ok { cu_result::SUCCESS } else { cu_result::INVALID_VALUE };
        emit(TPS.module_unload.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuStreamCreate`.
    pub fn cu_stream_create(&self, flags: u32) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.stream_create.0, |e| {
            e.ptr(p).u64(flags as u64);
        });
        let stream = self.handles.alloc(HandleKind::Queue);
        let dev = self.state.lock().unwrap().current_device;
        self.state
            .lock()
            .unwrap()
            .streams
            .insert(stream, CuStream { gpu: dev, fences: Vec::new() });
        emit(TPS.stream_create.1, |e| {
            e.u64(cu_result::SUCCESS).ptr(stream);
        });
        (cu_result::SUCCESS, stream)
    }

    /// `cuStreamDestroy`.
    pub fn cu_stream_destroy(&self, stream: u64) -> u64 {
        emit(TPS.stream_destroy.0, |e| {
            e.ptr(stream);
        });
        let ok = self.state.lock().unwrap().streams.remove(&stream).is_some();
        let result = if ok { cu_result::SUCCESS } else { cu_result::INVALID_VALUE };
        emit(TPS.stream_destroy.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuStreamSynchronize`.
    pub fn cu_stream_synchronize(&self, stream: u64) -> u64 {
        emit(TPS.stream_synchronize.0, |e| {
            e.ptr(stream);
        });
        let (fences, gpu_idx) = {
            let mut st = self.state.lock().unwrap();
            match st.streams.get_mut(&stream) {
                Some(s) => (std::mem::take(&mut s.fences), s.gpu),
                None => {
                    drop(st);
                    emit(TPS.stream_synchronize.1, |e| {
                        e.u64(cu_result::INVALID_VALUE);
                    });
                    return cu_result::INVALID_VALUE;
                }
            }
        };
        for f in &fences {
            f.wait(Duration::from_secs(600));
        }
        let gpu = self.gpu(gpu_idx).clone();
        profiling::drain_and_emit(&gpu, Some(stream));
        emit(TPS.stream_synchronize.1, |e| {
            e.u64(cu_result::SUCCESS);
        });
        cu_result::SUCCESS
    }

    /// `cuStreamQuery` (polling class).
    pub fn cu_stream_query(&self, stream: u64) -> u64 {
        emit(TPS.stream_query.0, |e| {
            e.ptr(stream);
        });
        let st = self.state.lock().unwrap();
        let result = match st.streams.get(&stream) {
            Some(s) => {
                if s.fences.iter().all(|f| f.query()) {
                    cu_result::SUCCESS
                } else {
                    cu_result::NOT_READY
                }
            }
            None => cu_result::INVALID_VALUE,
        };
        drop(st);
        emit(TPS.stream_query.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuLaunchKernel`. `params` are the kernel argument pointers
    /// (inputs then output, matching the artifact manifest).
    #[allow(clippy::too_many_arguments)]
    pub fn cu_launch_kernel(
        &self,
        f: u64,
        grid: (u32, u32, u32),
        block: (u32, u32, u32),
        shared_mem: u32,
        stream: u64,
        params: &[u64],
    ) -> u64 {
        let pp = self.desc();
        emit(TPS.launch_kernel.0, |e| {
            e.ptr(f)
                .u64(grid.0 as u64)
                .u64(grid.1 as u64)
                .u64(grid.2 as u64)
                .u64(block.0 as u64)
                .u64(block.1 as u64)
                .u64(block.2 as u64)
                .u64(shared_mem as u64)
                .ptr(stream)
                .ptr(pp)
                .ptr(0);
        });
        let mut st = self.state.lock().unwrap();
        let name = st.functions.get(&f).cloned();
        let result = match (name, st.streams.get_mut(&stream)) {
            (Some(name), Some(s)) => {
                let gpu = self.node.gpus[s.gpu as usize].clone();
                let fence = Arc::new(DevEvent::new());
                s.fences.push(fence.clone());
                drop(st);
                gpu.submit(
                    0, // compute engine
                    stream,
                    vec![Command::Kernel {
                        name,
                        args: params.to_vec(),
                        groups: grid,
                        signal: None,
                    }],
                    Some(fence),
                );
                cu_result::SUCCESS
            }
            _ => cu_result::INVALID_VALUE,
        };
        emit(TPS.launch_kernel.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuEventCreate`.
    pub fn cu_event_create(&self, flags: u32) -> (u64, u64) {
        let p = self.desc();
        emit(TPS.event_create.0, |e| {
            e.ptr(p).u64(flags as u64);
        });
        let ev = self.handles.alloc(HandleKind::Event);
        self.state.lock().unwrap().events.insert(ev, Arc::new(DevEvent::new()));
        emit(TPS.event_create.1, |e| {
            e.u64(cu_result::SUCCESS).ptr(ev);
        });
        (cu_result::SUCCESS, ev)
    }

    /// `cuEventRecord` — signals the event when the stream's work so far
    /// completes (implemented as a barrier command carrying the signal).
    pub fn cu_event_record(&self, event: u64, stream: u64) -> u64 {
        emit(TPS.event_record.0, |e| {
            e.ptr(event).ptr(stream);
        });
        let mut st = self.state.lock().unwrap();
        let dev = match st.streams.get(&stream) {
            Some(s) => s.gpu,
            None => {
                drop(st);
                emit(TPS.event_record.1, |e| {
                    e.u64(cu_result::INVALID_VALUE);
                });
                return cu_result::INVALID_VALUE;
            }
        };
        let signal = st.events.get(&event).cloned();
        let result = match signal {
            Some(signal) => {
                signal.reset();
                let gpu = self.node.gpus[dev as usize].clone();
                drop(st);
                gpu.submit(0, stream, vec![Command::Barrier { signal: Some(signal) }], None);
                cu_result::SUCCESS
            }
            None => cu_result::INVALID_VALUE,
        };
        emit(TPS.event_record.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuEventQuery` (polling class).
    pub fn cu_event_query(&self, event: u64) -> u64 {
        emit(TPS.event_query.0, |e| {
            e.ptr(event);
        });
        let ev = self.state.lock().unwrap().events.get(&event).cloned();
        let result = match ev {
            Some(ev) if ev.query() => cu_result::SUCCESS,
            Some(_) => cu_result::NOT_READY,
            None => cu_result::INVALID_VALUE,
        };
        emit(TPS.event_query.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuEventSynchronize`.
    pub fn cu_event_synchronize(&self, event: u64) -> u64 {
        emit(TPS.event_synchronize.0, |e| {
            e.ptr(event);
        });
        let ev = self.state.lock().unwrap().events.get(&event).cloned();
        let result = match ev {
            Some(ev) => {
                ev.wait(Duration::from_secs(600));
                let dev = self.state.lock().unwrap().current_device;
                profiling::drain_and_emit(self.gpu(dev), None);
                cu_result::SUCCESS
            }
            None => cu_result::INVALID_VALUE,
        };
        emit(TPS.event_synchronize.1, |e| {
            e.u64(result);
        });
        result
    }

    /// `cuEventDestroy`.
    pub fn cu_event_destroy(&self, event: u64) -> u64 {
        emit(TPS.event_destroy.0, |e| {
            e.ptr(event);
        });
        let ok = self.state.lock().unwrap().events.remove(&event).is_some();
        let result = if ok { cu_result::SUCCESS } else { cu_result::INVALID_VALUE };
        emit(TPS.event_destroy.1, |e| {
            e.u64(result);
        });
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    fn cuda() -> Arc<CudaDriver> {
        CudaDriver::new(crate::device::Node::new(NodeConfig {
            gpu_count: 1,
            tiles_per_gpu: 1,
            backend: crate::device::Backend::Cuda,
            ..NodeConfig::test_small()
        }))
    }

    #[test]
    fn end_to_end_matmul_via_cuda_api() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let cu = cuda();
        cu.cu_init(0);
        let (_, n) = cu.cu_device_get_count();
        assert_eq!(n, 1);
        let (_, dev) = cu.cu_device_get(0);
        let (_, _ctx) = cu.cu_ctx_create(0, dev);

        let (m, k, nn) = (256usize, 256usize, 256usize);
        let (_, da) = cu.cu_mem_alloc((m * k * 4) as u64);
        let (_, db) = cu.cu_mem_alloc((k * nn * 4) as u64);
        let (_, dbias) = cu.cu_mem_alloc((nn * 4) as u64);
        let (_, dout) = cu.cu_mem_alloc((m * nn * 4) as u64);
        let (_, ha) = cu.cu_mem_alloc_host((m * k * 4) as u64);

        let gpu = cu.node.gpu(0);
        gpu.pool
            .write(ha, &crate::runtime::executor::f32_to_bytes(&vec![0.01; m * k]))
            .unwrap();
        cu.cu_memcpy_htod(da, ha, (m * k * 4) as u64);
        // b and bias stay zero -> out = gelu(0) = 0
        let (r, module) = cu.cu_module_load_data("matmul");
        assert_eq!(r, cu_result::SUCCESS);
        let (_, f) = cu.cu_module_get_function(module, "matmul");
        let r = cu.cu_launch_kernel(
            f,
            (4, 4, 4),
            (8, 8, 1),
            0,
            cu.default_stream,
            &[da, db, dbias, dout],
        );
        assert_eq!(r, cu_result::SUCCESS);
        cu.cu_ctx_synchronize();

        let out =
            crate::runtime::executor::bytes_to_f32(&gpu.pool.read(dout, (m * nn * 4) as u64).unwrap());
        assert!(out.iter().all(|&v| v.abs() < 1e-5), "zero matmul must be ~zero");

        let (_, free, total) = cu.cu_mem_get_info();
        assert!(free < total);
        let session = uninstall_session().unwrap();
        assert!(session.stats().written > 20);
    }

    #[test]
    fn event_record_and_query_lifecycle() {
        let _g = test_support::lock();
        let cu = cuda();
        cu.cu_init(0);
        let (_, dev) = cu.cu_device_get(0);
        cu.cu_ctx_create(0, dev);
        let (_, ev) = cu.cu_event_create(0);
        let (_, stream) = cu.cu_stream_create(0);
        cu.cu_event_record(ev, stream);
        let mut spins = 0;
        while cu.cu_event_query(ev) != cu_result::SUCCESS {
            spins += 1;
            assert!(spins < 1_000_000);
            std::thread::yield_now();
        }
        assert_eq!(cu.cu_event_synchronize(ev), cu_result::SUCCESS);
        assert_eq!(cu.cu_stream_synchronize(stream), cu_result::SUCCESS);
        assert_eq!(cu.cu_event_destroy(ev), cu_result::SUCCESS);
        assert_eq!(cu.cu_stream_destroy(stream), cu_result::SUCCESS);
    }

    #[test]
    fn async_copies_complete_at_stream_sync() {
        let _g = test_support::lock();
        let cu = cuda();
        cu.cu_init(0);
        let (_, dev) = cu.cu_device_get(0);
        cu.cu_ctx_create(0, dev);
        let (_, stream) = cu.cu_stream_create(0);
        let (_, h) = cu.cu_mem_alloc_host(4096);
        let (_, d) = cu.cu_mem_alloc(4096);
        let gpu = cu.node.gpu(0);
        gpu.pool.write(h, &[9u8; 4096]).unwrap();
        cu.cu_memcpy_htod_async(d, h, 4096, stream);
        cu.cu_stream_synchronize(stream);
        assert_eq!(gpu.pool.read(d, 4096).unwrap(), vec![9u8; 4096]);
    }
}
