//! OpenCL frontend: the traced `cl*` runtime (its trace model comes from
//! the XML registry rather than a C header — paper Fig. 1a).

use super::declare_tps;
use super::handles::{HandleAllocator, HandleKind};
use super::profiling;
use crate::device::{AllocKind, Command, DevEvent, Node};
use crate::model::Api;
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// `cl_int` error codes.
pub mod cl_error {
    /// CL_SUCCESS.
    pub const SUCCESS: i64 = 0;
    /// CL_INVALID_VALUE.
    pub const INVALID_VALUE: i64 = -30;
    /// CL_INVALID_MEM_OBJECT.
    pub const INVALID_MEM_OBJECT: i64 = -38;
    /// CL_OUT_OF_RESOURCES.
    pub const OUT_OF_RESOURCES: i64 = -5;
}

declare_tps!(pub(crate) ClTps, Api::Cl, {
    get_platform_ids: "clGetPlatformIDs",
    get_device_ids: "clGetDeviceIDs",
    create_context: "clCreateContext",
    create_command_queue: "clCreateCommandQueue",
    create_buffer: "clCreateBuffer",
    release_mem_object: "clReleaseMemObject",
    enqueue_write_buffer: "clEnqueueWriteBuffer",
    enqueue_read_buffer: "clEnqueueReadBuffer",
    create_program_with_source: "clCreateProgramWithSource",
    build_program: "clBuildProgram",
    create_kernel: "clCreateKernel",
    set_kernel_arg: "clSetKernelArg",
    enqueue_ndrange_kernel: "clEnqueueNDRangeKernel",
    flush: "clFlush",
    finish: "clFinish",
});

static TPS: Lazy<ClTps> = Lazy::new(ClTps::load);

struct ClQueue {
    gpu: u32,
    fences: Vec<Arc<DevEvent>>,
}

#[derive(Default)]
struct ClState {
    queues: HashMap<u64, ClQueue>,
    buffers: HashMap<u64, (u64, u64)>, // cl_mem -> (device ptr, size)
    programs: HashMap<u64, String>,
    built: HashMap<u64, bool>,
    kernels: HashMap<u64, (String, HashMap<u32, u64>)>,
}

/// The OpenCL platform/runtime for one node.
pub struct ClRuntime {
    /// The node.
    pub node: Arc<Node>,
    handles: HandleAllocator,
    platform: u64,
    state: Mutex<ClState>,
}

impl ClRuntime {
    /// Create the runtime.
    pub fn new(node: Arc<Node>) -> Arc<Self> {
        let handles = HandleAllocator::new();
        let platform = handles.alloc(HandleKind::Driver);
        Arc::new(ClRuntime { node, handles, platform, state: Mutex::new(ClState::default()) })
    }

    fn desc(&self) -> u64 {
        self.handles.alloc(HandleKind::Desc)
    }

    /// `clGetPlatformIDs`.
    pub fn cl_get_platform_ids(&self, platforms: &mut Vec<u64>) -> (i64, u32) {
        let pp = self.desc();
        let pn = self.desc();
        emit(TPS.get_platform_ids.0, |e| {
            e.u64(1).ptr(pp).ptr(pn);
        });
        platforms.clear();
        platforms.push(self.platform);
        emit(TPS.get_platform_ids.1, |e| {
            e.i64(cl_error::SUCCESS).u64(1);
        });
        (cl_error::SUCCESS, 1)
    }

    /// `clGetDeviceIDs`.
    pub fn cl_get_device_ids(&self, platform: u64, devices: &mut Vec<u64>) -> (i64, u32) {
        let pd = self.desc();
        let pn = self.desc();
        emit(TPS.get_device_ids.0, |e| {
            e.ptr(platform).u64(4 /*CL_DEVICE_TYPE_GPU*/).u64(16).ptr(pd).ptr(pn);
        });
        let (result, n) = if platform == self.platform {
            devices.clear();
            devices.extend(self.node.gpus.iter().map(|g| g.handle));
            (cl_error::SUCCESS, devices.len() as u32)
        } else {
            (cl_error::INVALID_VALUE, 0)
        };
        emit(TPS.get_device_ids.1, |e| {
            e.i64(result).u64(n as u64);
        });
        (result, n)
    }

    /// `clCreateContext` (returns the context handle; errcode out-param).
    pub fn cl_create_context(&self, devices: &[u64]) -> (u64, i64) {
        let props = self.desc();
        let pd = self.desc();
        let perr = self.desc();
        emit(TPS.create_context.0, |e| {
            e.ptr(props).u64(devices.len() as u64).ptr(pd).ptr(0).ptr(0).ptr(perr);
        });
        let ctx = self.handles.alloc(HandleKind::Context);
        emit(TPS.create_context.1, |e| {
            e.ptr(ctx).i64(cl_error::SUCCESS);
        });
        (ctx, cl_error::SUCCESS)
    }

    /// `clCreateCommandQueue`.
    pub fn cl_create_command_queue(&self, context: u64, device: u64) -> (u64, i64) {
        let perr = self.desc();
        emit(TPS.create_command_queue.0, |e| {
            e.ptr(context).ptr(device).u64(0).ptr(perr);
        });
        let idx = self.node.gpus.iter().position(|g| g.handle == device);
        let (q, err) = match idx {
            Some(i) => {
                let q = self.handles.alloc(HandleKind::Queue);
                self.state
                    .lock()
                    .unwrap()
                    .queues
                    .insert(q, ClQueue { gpu: i as u32, fences: Vec::new() });
                (q, cl_error::SUCCESS)
            }
            None => (0, cl_error::INVALID_VALUE),
        };
        emit(TPS.create_command_queue.1, |e| {
            e.ptr(q).i64(err);
        });
        (q, err)
    }

    /// `clCreateBuffer`.
    pub fn cl_create_buffer(&self, context: u64, flags: u32, size: u64) -> (u64, i64) {
        let perr = self.desc();
        emit(TPS.create_buffer.0, |e| {
            e.ptr(context).u64(flags as u64).u64(size).ptr(0).ptr(perr);
        });
        let (mem, err) = match self.node.gpus[0].alloc(AllocKind::Device, size) {
            Ok(ptr) => {
                let mem = self.handles.alloc(HandleKind::Buffer);
                self.state.lock().unwrap().buffers.insert(mem, (ptr, size));
                (mem, cl_error::SUCCESS)
            }
            Err(_) => (0, cl_error::OUT_OF_RESOURCES),
        };
        emit(TPS.create_buffer.1, |e| {
            e.ptr(mem).i64(err);
        });
        (mem, err)
    }

    /// `clReleaseMemObject`.
    pub fn cl_release_mem_object(&self, memobj: u64) -> i64 {
        emit(TPS.release_mem_object.0, |e| {
            e.ptr(memobj);
        });
        let entry = self.state.lock().unwrap().buffers.remove(&memobj);
        let result = match entry {
            Some((ptr, _)) => {
                let _ = self.node.gpus[0].free(ptr);
                cl_error::SUCCESS
            }
            None => cl_error::INVALID_MEM_OBJECT,
        };
        emit(TPS.release_mem_object.1, |e| {
            e.i64(result);
        });
        result
    }

    fn enqueue_copy(
        &self,
        queue: u64,
        buffer: u64,
        blocking: bool,
        offset: u64,
        size: u64,
        host_ptr: u64,
        to_device: bool,
    ) -> i64 {
        let (gpu_idx, dev_ptr) = {
            let st = self.state.lock().unwrap();
            let Some(q) = st.queues.get(&queue) else {
                return cl_error::INVALID_VALUE;
            };
            let Some((ptr, bsize)) = st.buffers.get(&buffer).copied() else {
                return cl_error::INVALID_MEM_OBJECT;
            };
            if offset + size > bsize {
                return cl_error::INVALID_VALUE;
            }
            (q.gpu, ptr)
        };
        let gpu = self.node.gpus[gpu_idx as usize].clone();
        let fence = Arc::new(DevEvent::new());
        let (dst, src) = if to_device {
            (dev_ptr + offset, host_ptr)
        } else {
            (host_ptr, dev_ptr + offset)
        };
        gpu.submit(
            gpu.tiles, // copy engine
            queue,
            vec![Command::Memcpy { dst, src, bytes: size, signal: None }],
            Some(fence.clone()),
        );
        if blocking {
            fence.wait(Duration::from_secs(600));
            profiling::drain_and_emit(&gpu, Some(queue));
        } else {
            self.state.lock().unwrap().queues.get_mut(&queue).unwrap().fences.push(fence);
        }
        cl_error::SUCCESS
    }

    /// `clEnqueueWriteBuffer`.
    #[allow(clippy::too_many_arguments)]
    pub fn cl_enqueue_write_buffer(
        &self,
        queue: u64,
        buffer: u64,
        blocking: bool,
        offset: u64,
        size: u64,
        host_ptr: u64,
    ) -> i64 {
        let pe = self.desc();
        emit(TPS.enqueue_write_buffer.0, |e| {
            e.ptr(queue)
                .ptr(buffer)
                .u64(blocking as u64)
                .u64(offset)
                .u64(size)
                .ptr(host_ptr)
                .u64(0)
                .ptr(0)
                .ptr(pe);
        });
        let result = self.enqueue_copy(queue, buffer, blocking, offset, size, host_ptr, true);
        emit(TPS.enqueue_write_buffer.1, |e| {
            e.i64(result).ptr(pe);
        });
        result
    }

    /// `clEnqueueReadBuffer`.
    #[allow(clippy::too_many_arguments)]
    pub fn cl_enqueue_read_buffer(
        &self,
        queue: u64,
        buffer: u64,
        blocking: bool,
        offset: u64,
        size: u64,
        host_ptr: u64,
    ) -> i64 {
        let pe = self.desc();
        emit(TPS.enqueue_read_buffer.0, |e| {
            e.ptr(queue)
                .ptr(buffer)
                .u64(blocking as u64)
                .u64(offset)
                .u64(size)
                .ptr(host_ptr)
                .u64(0)
                .ptr(0)
                .ptr(pe);
        });
        let result = self.enqueue_copy(queue, buffer, blocking, offset, size, host_ptr, false);
        emit(TPS.enqueue_read_buffer.1, |e| {
            e.i64(result).ptr(pe);
        });
        result
    }

    /// `clCreateProgramWithSource` — "source" is the kernel name.
    pub fn cl_create_program_with_source(&self, context: u64, source: &str) -> (u64, i64) {
        let perr = self.desc();
        emit(TPS.create_program_with_source.0, |e| {
            e.ptr(context).u64(1).str(source).ptr(0).ptr(perr);
        });
        let program = self.handles.alloc(HandleKind::Module);
        self.state.lock().unwrap().programs.insert(program, source.to_string());
        emit(TPS.create_program_with_source.1, |e| {
            e.ptr(program).i64(cl_error::SUCCESS);
        });
        (program, cl_error::SUCCESS)
    }

    /// `clBuildProgram` — the real PJRT compile happens here.
    pub fn cl_build_program(&self, program: u64, options: &str) -> i64 {
        let pd = self.desc();
        emit(TPS.build_program.0, |e| {
            e.ptr(program).u64(0).ptr(pd).str(options).ptr(0).ptr(0);
        });
        let name = self.state.lock().unwrap().programs.get(&program).cloned();
        let result = match name {
            Some(n) => match self.node.executor.compile(&n) {
                Ok(_) => {
                    self.state.lock().unwrap().built.insert(program, true);
                    cl_error::SUCCESS
                }
                Err(_) => cl_error::INVALID_VALUE,
            },
            None => cl_error::INVALID_VALUE,
        };
        emit(TPS.build_program.1, |e| {
            e.i64(result);
        });
        result
    }

    /// `clCreateKernel`.
    pub fn cl_create_kernel(&self, program: u64, kernel_name: &str) -> (u64, i64) {
        let perr = self.desc();
        emit(TPS.create_kernel.0, |e| {
            e.ptr(program).str(kernel_name).ptr(perr);
        });
        let st = self.state.lock().unwrap();
        let ok = st.programs.get(&program).map(|n| n == kernel_name).unwrap_or(false)
            && st.built.get(&program).copied().unwrap_or(false);
        drop(st);
        let (k, err) = if ok {
            let k = self.handles.alloc(HandleKind::Kernel);
            self.state
                .lock()
                .unwrap()
                .kernels
                .insert(k, (kernel_name.to_string(), HashMap::new()));
            (k, cl_error::SUCCESS)
        } else {
            (0, cl_error::INVALID_VALUE)
        };
        emit(TPS.create_kernel.1, |e| {
            e.ptr(k).i64(err);
        });
        (k, err)
    }

    /// `clSetKernelArg` — `value` is the cl_mem handle for the argument.
    pub fn cl_set_kernel_arg(&self, kernel: u64, arg_index: u32, value: u64) -> i64 {
        let pv = self.desc();
        emit(TPS.set_kernel_arg.0, |e| {
            e.ptr(kernel).u64(arg_index as u64).u64(8).ptr(pv);
        });
        let mut st = self.state.lock().unwrap();
        let dev_ptr = st.buffers.get(&value).map(|(p, _)| *p);
        let result = match (st.kernels.get_mut(&kernel), dev_ptr) {
            (Some((_, args)), Some(p)) => {
                args.insert(arg_index, p);
                cl_error::SUCCESS
            }
            (Some(_), None) => cl_error::INVALID_MEM_OBJECT,
            (None, _) => cl_error::INVALID_VALUE,
        };
        drop(st);
        emit(TPS.set_kernel_arg.1, |e| {
            e.i64(result);
        });
        result
    }

    /// `clEnqueueNDRangeKernel`.
    pub fn cl_enqueue_ndrange_kernel(
        &self,
        queue: u64,
        kernel: u64,
        global_work_size: (u64, u64, u64),
    ) -> i64 {
        let pg = self.desc();
        let pe = self.desc();
        emit(TPS.enqueue_ndrange_kernel.0, |e| {
            e.ptr(queue).ptr(kernel).u64(3).ptr(0).ptr(pg).ptr(0).u64(0).ptr(0).ptr(pe);
        });
        let mut st = self.state.lock().unwrap();
        let kern = st.kernels.get(&kernel).cloned();
        let result = match (kern, st.queues.get_mut(&queue)) {
            (Some((name, args)), Some(q)) => {
                let mut idx: Vec<_> = args.keys().copied().collect();
                idx.sort_unstable();
                let ptrs: Vec<u64> = idx.iter().map(|i| args[i]).collect();
                let gpu = self.node.gpus[q.gpu as usize].clone();
                let fence = Arc::new(DevEvent::new());
                q.fences.push(fence.clone());
                drop(st);
                gpu.submit(
                    0,
                    queue,
                    vec![Command::Kernel {
                        name,
                        args: ptrs,
                        groups: (
                            global_work_size.0 as u32,
                            global_work_size.1 as u32,
                            global_work_size.2 as u32,
                        ),
                        signal: None,
                    }],
                    Some(fence),
                );
                cl_error::SUCCESS
            }
            (None, _) => cl_error::INVALID_VALUE,
            (_, None) => cl_error::INVALID_VALUE,
        };
        emit(TPS.enqueue_ndrange_kernel.1, |e| {
            e.i64(result).ptr(pe);
        });
        result
    }

    /// `clFlush` (no-op — submission is eager).
    pub fn cl_flush(&self, queue: u64) -> i64 {
        emit(TPS.flush.0, |e| {
            e.ptr(queue);
        });
        let result = if self.state.lock().unwrap().queues.contains_key(&queue) {
            cl_error::SUCCESS
        } else {
            cl_error::INVALID_VALUE
        };
        emit(TPS.flush.1, |e| {
            e.i64(result);
        });
        result
    }

    /// `clFinish` — waits for the queue and emits profiling events.
    pub fn cl_finish(&self, queue: u64) -> i64 {
        emit(TPS.finish.0, |e| {
            e.ptr(queue);
        });
        let (fences, gpu_idx) = {
            let mut st = self.state.lock().unwrap();
            match st.queues.get_mut(&queue) {
                Some(q) => (std::mem::take(&mut q.fences), q.gpu),
                None => {
                    drop(st);
                    emit(TPS.finish.1, |e| {
                        e.i64(cl_error::INVALID_VALUE);
                    });
                    return cl_error::INVALID_VALUE;
                }
            }
        };
        for f in &fences {
            f.wait(Duration::from_secs(600));
        }
        let gpu = self.node.gpus[gpu_idx as usize].clone();
        profiling::drain_and_emit(&gpu, Some(queue));
        emit(TPS.finish.1, |e| {
            e.i64(cl_error::SUCCESS);
        });
        cl_error::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    fn cl() -> Arc<ClRuntime> {
        ClRuntime::new(Node::new(NodeConfig::test_small()))
    }

    #[test]
    fn end_to_end_conv1d_via_opencl() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let cl = cl();
        let mut platforms = vec![];
        cl.cl_get_platform_ids(&mut platforms);
        let mut devices = vec![];
        cl.cl_get_device_ids(platforms[0], &mut devices);
        let (ctx, _) = cl.cl_create_context(&devices);
        let (queue, err) = cl.cl_create_command_queue(ctx, devices[0]);
        assert_eq!(err, cl_error::SUCCESS);

        let (b, n, k) = (64usize, 4096usize, 33usize);
        let xb = (b * n * 4) as u64;
        let wb = (k * 4) as u64;
        let (bx, _) = cl.cl_create_buffer(ctx, 0, xb);
        let (bw, _) = cl.cl_create_buffer(ctx, 0, wb);
        let (bbias, _) = cl.cl_create_buffer(ctx, 0, xb);
        let (bout, _) = cl.cl_create_buffer(ctx, 0, xb);

        let gpu = cl.node.gpu(0);
        let hx = gpu.pool.alloc(AllocKind::Host, xb).unwrap();
        let hw = gpu.pool.alloc(AllocKind::Host, wb).unwrap();
        gpu.pool
            .write(hx, &crate::runtime::executor::f32_to_bytes(&vec![1.0; b * n]))
            .unwrap();
        // identity tap
        let mut taps = vec![0.0f32; k];
        taps[k / 2] = 1.0;
        gpu.pool.write(hw, &crate::runtime::executor::f32_to_bytes(&taps)).unwrap();
        assert_eq!(cl.cl_enqueue_write_buffer(queue, bx, true, 0, xb, hx), cl_error::SUCCESS);
        assert_eq!(cl.cl_enqueue_write_buffer(queue, bw, true, 0, wb, hw), cl_error::SUCCESS);

        let (program, _) = cl.cl_create_program_with_source(ctx, "conv1d");
        assert_eq!(cl.cl_build_program(program, "-O2"), cl_error::SUCCESS);
        let (kernel, err) = cl.cl_create_kernel(program, "conv1d");
        assert_eq!(err, cl_error::SUCCESS);
        cl.cl_set_kernel_arg(kernel, 0, bx);
        cl.cl_set_kernel_arg(kernel, 1, bw);
        cl.cl_set_kernel_arg(kernel, 2, bbias);
        cl.cl_set_kernel_arg(kernel, 3, bout);
        assert_eq!(
            cl.cl_enqueue_ndrange_kernel(queue, kernel, (b as u64, 1, 1)),
            cl_error::SUCCESS
        );
        assert_eq!(cl.cl_finish(queue), cl_error::SUCCESS);

        let hout = gpu.pool.alloc(AllocKind::Host, xb).unwrap();
        cl.cl_enqueue_read_buffer(queue, bout, true, 0, xb, hout);
        let out = crate::runtime::executor::bytes_to_f32(&gpu.pool.read(hout, xb).unwrap());
        // relu(conv_identity(ones) + 0) = 1
        assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-5));

        let session = uninstall_session().unwrap();
        assert!(session.stats().written > 20);
    }

    #[test]
    fn unbuilt_program_cannot_create_kernel() {
        let _g = test_support::lock();
        let cl = cl();
        let (ctx, _) = cl.cl_create_context(&[]);
        let (program, _) = cl.cl_create_program_with_source(ctx, "saxpy");
        let (_, err) = cl.cl_create_kernel(program, "saxpy");
        assert_eq!(err, cl_error::INVALID_VALUE);
    }

    #[test]
    fn buffer_release_and_errors() {
        let _g = test_support::lock();
        let cl = cl();
        let (ctx, _) = cl.cl_create_context(&[]);
        let (mem, err) = cl.cl_create_buffer(ctx, 0, 4096);
        assert_eq!(err, cl_error::SUCCESS);
        assert_eq!(cl.cl_release_mem_object(mem), cl_error::SUCCESS);
        assert_eq!(cl.cl_release_mem_object(mem), cl_error::INVALID_MEM_OBJECT);
    }
}
