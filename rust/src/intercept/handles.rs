//! Handle allocation for the simulated runtimes.
//!
//! Real drivers hand out opaque pointers; we hand out tagged u64s so the
//! traces remain readable (`0x0c00…` contexts, `0x5100…` queues, ...) and
//! collisions across object kinds are impossible.

use std::sync::atomic::{AtomicU64, Ordering};

/// Handle kinds (the tag occupies the top 16 bits below the sign area).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandleKind {
    /// Driver handles.
    Driver,
    /// Device handles.
    Device,
    /// Context handles.
    Context,
    /// Command queues / streams.
    Queue,
    /// Command lists.
    List,
    /// Event pools.
    EventPool,
    /// Events.
    Event,
    /// Modules / programs / fat binaries.
    Module,
    /// Kernels / functions.
    Kernel,
    /// Descriptor pseudo-pointers (traced `desc*` values).
    Desc,
    /// MPI requests.
    Request,
    /// OpenCL buffers.
    Buffer,
}

impl HandleKind {
    fn base(&self) -> u64 {
        match self {
            HandleKind::Driver => 0x0d00_0000_0000,
            HandleKind::Device => 0x0de0_0000_0000,
            HandleKind::Context => 0x0c00_0000_0000,
            HandleKind::Queue => 0x5100_0000_0000,
            HandleKind::List => 0x1150_0000_0000,
            HandleKind::EventPool => 0xe900_0000_0000,
            HandleKind::Event => 0xe000_0000_0000,
            HandleKind::Module => 0x3300_0000_0000,
            HandleKind::Kernel => 0x6e00_0000_0000,
            HandleKind::Desc => 0x7ffe_0000_0000,
            HandleKind::Request => 0x4e00_0000_0000,
            HandleKind::Buffer => 0xbf00_0000_0000,
        }
    }
}

/// Process-wide handle allocator.
#[derive(Debug, Default)]
pub struct HandleAllocator {
    next: AtomicU64,
}

impl HandleAllocator {
    /// Create an allocator.
    pub fn new() -> Self {
        Self { next: AtomicU64::new(0x10) }
    }

    /// Allocate a fresh handle of `kind`.
    pub fn alloc(&self, kind: HandleKind) -> u64 {
        kind.base() + self.next.fetch_add(0x10, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_unique_and_tagged() {
        let h = HandleAllocator::new();
        let a = h.alloc(HandleKind::Queue);
        let b = h.alloc(HandleKind::Queue);
        let c = h.alloc(HandleKind::Event);
        assert_ne!(a, b);
        assert_eq!(a >> 40, 0x51);
        assert_eq!(c >> 40, 0xe0);
    }
}
