//! GPU-profiling helpers: turn engine completion records into
//! `lttng_ust_profiling:command_completed` events.
//!
//! THAPI's generated "Helper Functions" capture GPU timings by reading
//! backend profiling data at synchronization points (paper Fig. 2,
//! Scenario 2: "Level-Zero profiling / get the info during wait"). The
//! frontends call [`emit_completions`] from every synchronize-style API
//! after draining the device's completion log.

use crate::device::{CompletionRecord, Gpu};
use crate::model::class_by_name;
use crate::model::EventClass;
use crate::tracer::emit;
use once_cell::sync::Lazy;

static COMMAND_COMPLETED: Lazy<&'static EventClass> =
    Lazy::new(|| class_by_name("lttng_ust_profiling:command_completed").unwrap());

/// Emit one profiling event per completion record.
pub fn emit_completions(device_handle: u64, records: &[CompletionRecord]) {
    for r in records {
        emit(&COMMAND_COMPLETED, |e| {
            e.ptr(device_handle)
                .u32(r.engine_ordinal)
                .u32(r.engine_kind.code())
                .str(r.kind)
                .str(&r.name)
                .ptr(r.queue)
                .u64(r.ts_start)
                .u64(r.ts_end)
                .u64(r.bytes);
        });
    }
}

/// Drain a GPU's completions (optionally for one queue) and emit them.
/// Returns the drained records so callers can also inspect errors.
pub fn drain_and_emit(gpu: &Gpu, queue: Option<u64>) -> Vec<CompletionRecord> {
    let recs = gpu.drain_completions(queue);
    emit_completions(gpu.handle, &recs);
    recs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::EngineKind;

    #[test]
    fn emits_one_event_per_record() {
        let _g = crate::tracer::session::test_support::lock();
        crate::tracer::install_session(Default::default());
        let recs = vec![
            CompletionRecord {
                queue: 1,
                engine_ordinal: 0,
                engine_kind: EngineKind::Compute,
                kind: "kernel",
                name: "lrn".into(),
                ts_start: 10,
                ts_end: 20,
                bytes: 0,
                error: None,
            },
            CompletionRecord {
                queue: 1,
                engine_ordinal: 2,
                engine_kind: EngineKind::Copy,
                kind: "memcpy",
                name: String::new(),
                ts_start: 20,
                ts_end: 30,
                bytes: 4096,
                error: None,
            },
        ];
        emit_completions(0xdead, &recs);
        let session = crate::tracer::uninstall_session().unwrap();
        assert_eq!(session.stats().written, 2);
    }
}
