//! On-node processing and multi-node aggregation (paper §3.7).
//!
//! In aggregate-only mode the per-rank traces live in "scratchpad" memory,
//! are reduced to serialized tallies (kilobytes), and flow up a two-level
//! master tree: each node's **local master** merges its ranks' tallies,
//! then sends one aggregate to the **global master**, which combines them
//! into the composite profile. The paper scales this to 512 nodes; the
//! `aggregate_scale` bench reproduces that scaling curve.

use crate::analysis::{self, Tally};
use crate::tracer::btf::TraceData;
use anyhow::Result;

/// One rank's contribution: a serialized tally (what would travel over
/// the wire; kilobytes, per the paper).
#[derive(Debug, Clone)]
pub struct RankAggregate {
    /// Node id.
    pub node: u32,
    /// Rank id.
    pub rank: u32,
    /// Serialized tally.
    pub payload: String,
}

impl RankAggregate {
    /// Build from a tally.
    pub fn new(node: u32, rank: u32, tally: &Tally) -> Self {
        RankAggregate { node, rank, payload: tally.serialize() }
    }

    /// Payload size in bytes (the per-rank network cost).
    pub fn size_bytes(&self) -> usize {
        self.payload.len()
    }

    /// Build a rank's aggregate straight from its raw trace in one
    /// streaming pass: the scratchpad trace is reduced to the kilobyte
    /// tally (aggregate-only mode, §3.7) without ever materializing a
    /// merged `Vec<EventMsg>`.
    pub fn from_trace(node: u32, rank: u32, trace: &TraceData) -> Result<Self> {
        let parsed = analysis::parse_trace(trace)?;
        Ok(RankAggregate::new(node, rank, &Tally::from_parsed(&parsed)))
    }
}

/// Local master: merge all rank aggregates of one node into the node
/// aggregate.
pub fn local_master_merge(node: u32, ranks: &[RankAggregate]) -> Result<RankAggregate> {
    let mut combined = Tally::default();
    for r in ranks {
        debug_assert_eq!(r.node, node);
        combined.merge(&Tally::deserialize(&r.payload)?);
    }
    Ok(RankAggregate { node, rank: 0, payload: combined.serialize() })
}

/// Global master: merge node aggregates into the composite profile.
pub fn global_master_merge(nodes: &[RankAggregate]) -> Result<Tally> {
    let mut composite = Tally::default();
    for n in nodes {
        composite.merge(&Tally::deserialize(&n.payload)?);
    }
    Ok(composite)
}

/// Convenience: full two-level aggregation for `nodes × ranks_per_node`
/// tallies, returning (composite, total bytes moved over the "network").
pub fn aggregate_tree(per_rank: &[(u32, u32, Tally)]) -> Result<(Tally, usize)> {
    use std::collections::BTreeMap;
    let mut by_node: BTreeMap<u32, Vec<RankAggregate>> = BTreeMap::new();
    let mut bytes = 0usize;
    for (node, rank, tally) in per_rank {
        let agg = RankAggregate::new(*node, *rank, tally);
        bytes += agg.size_bytes(); // rank -> local master
        by_node.entry(*node).or_default().push(agg);
    }
    let mut node_aggs = Vec::with_capacity(by_node.len());
    for (node, ranks) in &by_node {
        let merged = local_master_merge(*node, ranks)?;
        bytes += merged.size_bytes(); // local master -> global master
        node_aggs.push(merged);
    }
    Ok((global_master_merge(&node_aggs)?, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::TallyRow;

    fn tally_with(name: &str, api: &str, time_ns: u64, calls: u64, rank: u32) -> Tally {
        let mut t = Tally::default();
        t.host.insert(
            (api.to_string(), name.to_string()),
            TallyRow {
                name: name.into(),
                api: api.into(),
                time_ns,
                calls,
                min_ns: time_ns / calls.max(1),
                max_ns: time_ns / calls.max(1),
            },
        );
        t.hostnames.insert(format!("node{rank}"));
        t.processes.insert(rank);
        t.threads.insert((rank, rank));
        t
    }

    #[test]
    fn two_level_merge_sums_everything() {
        let per_rank: Vec<(u32, u32, Tally)> = (0..4)
            .flat_map(|node| {
                (0..6).map(move |rank| {
                    (node, rank, tally_with("zeInit", "ZE", 1000, 2, node * 6 + rank))
                })
            })
            .collect();
        let (composite, bytes) = aggregate_tree(&per_rank).unwrap();
        let row = &composite.host[&("ZE".to_string(), "zeInit".to_string())];
        assert_eq!(row.calls, 48); // 24 ranks x 2 calls
        assert_eq!(row.time_ns, 24_000);
        assert_eq!(composite.processes.len(), 24);
        assert!(bytes > 0);
    }

    #[test]
    fn scales_to_512_nodes() {
        // the paper's §3.7 claim: successfully scaled to 512 nodes
        let per_rank: Vec<(u32, u32, Tally)> = (0..512)
            .flat_map(|node| {
                (0..6).map(move |rank| (node, rank, tally_with("hipMemcpy", "HIP", 500, 1, node)))
            })
            .collect();
        let (composite, bytes) = aggregate_tree(&per_rank).unwrap();
        let row = &composite.host[&("HIP".to_string(), "hipMemcpy".to_string())];
        assert_eq!(row.calls, 512 * 6);
        // aggregates stay kilobytes per hop, not trace-sized
        let per_hop = bytes / (512 * 6 + 512);
        assert!(per_hop < 4096, "per-hop aggregate should be small, got {per_hop}");
    }

    #[test]
    fn rank_aggregate_streams_straight_from_trace() {
        use crate::model::class_by_name;
        use crate::tracer::session::test_support;
        use crate::tracer::{btf, emit, install_session, uninstall_session, SessionConfig};
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..4 {
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = btf::collect(&session, &[]);
        let agg = RankAggregate::from_trace(2, 5, &trace).unwrap();
        assert_eq!(agg.node, 2);
        assert_eq!(agg.rank, 5);
        let tally = Tally::deserialize(&agg.payload).unwrap();
        assert_eq!(tally.host[&("ZE".to_string(), "zeInit".to_string())].calls, 4);
        assert!(agg.size_bytes() < 4096, "aggregate must stay kilobytes");
    }

    #[test]
    fn composite_preserves_min_max() {
        let mut a = tally_with("f", "ZE", 100, 1, 0);
        a.host.get_mut(&("ZE".into(), "f".into())).unwrap().min_ns = 10;
        let mut b = tally_with("f", "ZE", 900, 1, 1);
        b.host.get_mut(&("ZE".into(), "f".into())).unwrap().max_ns = 900;
        let (composite, _) =
            aggregate_tree(&[(0, 0, a), (1, 0, b)]).unwrap();
        let row = &composite.host[&("ZE".to_string(), "f".to_string())];
        assert_eq!(row.min_ns, 10);
        assert_eq!(row.max_ns, 900);
        assert_eq!(row.time_ns, 1000);
    }
}
