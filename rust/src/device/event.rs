//! Device events: signalable completion markers with device timestamps.
//!
//! The simulated analogue of `ze_event_handle_t` / `CUevent`. Engines
//! signal events when commands complete, recording device-clock start/end
//! timestamps; hosts wait with a timeout (enabling the spin-wait pattern
//! HIPLZ exhibits: `hipDeviceSynchronize` → `zeEventHostSynchronize`
//! polling loop, paper §4.3).

use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, Default, Clone)]
struct State {
    signaled: bool,
    ts_start: u64,
    ts_end: u64,
}

/// A device event.
#[derive(Debug, Default)]
pub struct DevEvent {
    state: Mutex<State>,
    cond: Condvar,
}

impl DevEvent {
    /// Create an unsignaled event.
    pub fn new() -> Self {
        Self::default()
    }

    /// Signal completion with device start/end timestamps (host-ns domain
    /// after conversion by the engine).
    pub fn signal(&self, ts_start: u64, ts_end: u64) {
        let mut s = self.state.lock().unwrap();
        s.signaled = true;
        s.ts_start = ts_start;
        s.ts_end = ts_end;
        self.cond.notify_all();
    }

    /// Non-blocking status query (`zeEventQueryStatus` / `cuEventQuery`).
    pub fn query(&self) -> bool {
        self.state.lock().unwrap().signaled
    }

    /// Block until signaled or `timeout` elapses. Returns `true` if
    /// signaled. A zero timeout is a pure poll.
    pub fn wait(&self, timeout: Duration) -> bool {
        let s = self.state.lock().unwrap();
        if s.signaled {
            return true;
        }
        if timeout.is_zero() {
            return false;
        }
        let (s, _r) = self
            .cond
            .wait_timeout_while(s, timeout, |st| !st.signaled)
            .unwrap();
        s.signaled
    }

    /// Device timestamps (start, end); zeros until signaled.
    pub fn timestamps(&self) -> (u64, u64) {
        let s = self.state.lock().unwrap();
        (s.ts_start, s.ts_end)
    }

    /// Reset to unsignaled (`zeEventHostReset`).
    pub fn reset(&self) {
        let mut s = self.state.lock().unwrap();
        s.signaled = false;
        s.ts_start = 0;
        s.ts_end = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn signal_then_wait_is_immediate() {
        let e = DevEvent::new();
        e.signal(10, 20);
        assert!(e.query());
        assert!(e.wait(Duration::ZERO));
        assert_eq!(e.timestamps(), (10, 20));
    }

    #[test]
    fn zero_timeout_poll_does_not_block() {
        let e = DevEvent::new();
        assert!(!e.wait(Duration::ZERO));
        assert!(!e.query());
    }

    #[test]
    fn wait_wakes_on_signal_from_other_thread() {
        let e = Arc::new(DevEvent::new());
        let e2 = e.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            e2.signal(1, 2);
        });
        assert!(e.wait(Duration::from_secs(5)));
        t.join().unwrap();
    }

    #[test]
    fn timeout_expires_without_signal() {
        let e = DevEvent::new();
        assert!(!e.wait(Duration::from_millis(3)));
    }

    #[test]
    fn reset_clears_state() {
        let e = DevEvent::new();
        e.signal(1, 2);
        e.reset();
        assert!(!e.query());
        assert_eq!(e.timestamps(), (0, 0));
    }
}
