//! Node configurations: the simulated testbeds.
//!
//! Encodes the paper's Table 1 system configurations as node descriptors:
//! Aurora nodes carry six 2-tile PVC GPUs behind Level-Zero; Polaris nodes
//! carry four A100s behind CUDA.

use super::gpu::Gpu;
use super::telemetry::TelemetryModel;
use crate::runtime::{global_executor, Executor};
use std::sync::Arc;

/// Native programming-model backend of a node (Table 1, last row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Intel GPUs: Level-Zero.
    LevelZero,
    /// NVIDIA GPUs: CUDA.
    Cuda,
}

/// Node descriptor.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// Hostname prefix.
    pub hostname: String,
    /// GPUs per node.
    pub gpu_count: u32,
    /// Tiles per GPU.
    pub tiles_per_gpu: u32,
    /// GPU marketing name.
    pub gpu_name: String,
    /// Device memory per GPU (bytes).
    pub device_mem: u64,
    /// Native backend.
    pub backend: Backend,
}

impl NodeConfig {
    /// Aurora node (Table 1): 6× Intel Data Center GPU Max 1550, 2 tiles,
    /// Level-Zero backend.
    pub fn aurora() -> Self {
        NodeConfig {
            hostname: "x1921c5s4b0n0".into(),
            gpu_count: 6,
            tiles_per_gpu: 2,
            gpu_name: "Intel Data Center GPU Max 1550".into(),
            device_mem: 8 << 30,
            backend: Backend::LevelZero,
        }
    }

    /// Polaris node (Table 1): 4× NVIDIA A100, CUDA backend.
    pub fn polaris() -> Self {
        NodeConfig {
            hostname: "x3006c0s13b0n0".into(),
            gpu_count: 4,
            tiles_per_gpu: 1,
            gpu_name: "NVIDIA A100".into(),
            device_mem: 8 << 30,
            backend: Backend::Cuda,
        }
    }

    /// Small single-GPU node for unit tests (fewer worker threads).
    pub fn test_small() -> Self {
        NodeConfig {
            hostname: "testnode".into(),
            gpu_count: 1,
            tiles_per_gpu: 2,
            gpu_name: "Test GPU".into(),
            device_mem: 2 << 30,
            backend: Backend::LevelZero,
        }
    }

    fn telemetry_model(&self) -> TelemetryModel {
        match self.backend {
            Backend::LevelZero => TelemetryModel::pvc(),
            Backend::Cuda => TelemetryModel::a100(),
        }
    }
}

/// A live simulated node: GPUs with running engines.
pub struct Node {
    /// Configuration.
    pub config: NodeConfig,
    /// GPUs.
    pub gpus: Vec<Arc<Gpu>>,
    /// The PJRT executor serving this node's kernels.
    pub executor: Arc<Executor>,
}

impl Node {
    /// Bring up a node using the process-global PJRT executor.
    pub fn new(config: NodeConfig) -> Arc<Self> {
        Self::with_executor(config, global_executor())
    }

    /// Bring up a node with an explicit executor.
    pub fn with_executor(config: NodeConfig, executor: Arc<Executor>) -> Arc<Self> {
        let model = config.telemetry_model();
        let gpus = (0..config.gpu_count)
            .map(|i| {
                Gpu::new(
                    i,
                    &config.gpu_name,
                    config.tiles_per_gpu,
                    config.device_mem,
                    model.clone(),
                    executor.clone(),
                )
            })
            .collect();
        Arc::new(Node { config, gpus, executor })
    }

    /// GPU by index.
    pub fn gpu(&self, index: u32) -> &Arc<Gpu> {
        &self.gpus[index as usize]
    }

    /// Wait for every GPU to drain.
    pub fn synchronize(&self) {
        for g in &self.gpus {
            g.synchronize();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_matches_table1() {
        let c = NodeConfig::aurora();
        assert_eq!(c.gpu_count, 6);
        assert_eq!(c.tiles_per_gpu, 2);
        assert_eq!(c.backend, Backend::LevelZero);
    }

    #[test]
    fn polaris_matches_table1() {
        let c = NodeConfig::polaris();
        assert_eq!(c.gpu_count, 4);
        assert_eq!(c.tiles_per_gpu, 1);
        assert_eq!(c.backend, Backend::Cuda);
    }

    #[test]
    fn node_brings_up_gpus_with_unique_handles() {
        let n = Node::new(NodeConfig::test_small());
        assert_eq!(n.gpus.len(), 1);
        assert_eq!(n.gpu(0).engines.len(), 4);
        n.synchronize();
    }
}
