//! Device/host/shared memory pool with address-space tagging.
//!
//! Allocations carry real backing bytes (copies and kernels move real
//! data) and live in distinct virtual ranges so traces show the same
//! address-space distinction the paper reads off `zeCommandListAppendMemoryCopy`
//! arguments: host pointers start `0x00007f…`, device pointers `0xff…`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Allocation kind (address range + semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocKind {
    /// Host-pinned memory (`zeMemAllocHost`, `cuMemAllocHost`).
    Host,
    /// Device memory (`zeMemAllocDevice`, `cuMemAlloc`).
    Device,
    /// Shared/USM memory.
    Shared,
}

impl AllocKind {
    /// Base virtual address of this kind's range.
    pub fn base(&self) -> u64 {
        match self {
            AllocKind::Host => 0x0000_7f00_0000_0000,
            AllocKind::Device => 0xff00_0000_0000_0000,
            AllocKind::Shared => 0x0000_5500_0000_0000,
        }
    }

    /// Classify a pointer by its range.
    pub fn of_ptr(ptr: u64) -> AllocKind {
        if ptr >= AllocKind::Device.base() {
            AllocKind::Device
        } else if ptr >= AllocKind::Host.base() {
            AllocKind::Host
        } else {
            AllocKind::Shared
        }
    }
}

struct Allocation {
    size: u64,
    kind: AllocKind,
    data: Arc<Mutex<Vec<u8>>>,
}

/// One GPU's memory pool (host allocations live here too — the simulated
/// host pins through the same pool for simplicity).
pub struct MemoryPool {
    allocs: Mutex<BTreeMap<u64, Allocation>>,
    next: [AtomicU64; 3],
    used_device: AtomicU64,
    total_device: u64,
}

impl MemoryPool {
    /// Create a pool advertising `total_device` bytes of device memory.
    pub fn new(total_device: u64) -> Self {
        MemoryPool {
            allocs: Mutex::new(BTreeMap::new()),
            next: [
                AtomicU64::new(AllocKind::Host.base()),
                AtomicU64::new(AllocKind::Device.base()),
                AtomicU64::new(AllocKind::Shared.base()),
            ],
            used_device: AtomicU64::new(0),
            total_device,
        }
    }

    fn slot(kind: AllocKind) -> usize {
        match kind {
            AllocKind::Host => 0,
            AllocKind::Device => 1,
            AllocKind::Shared => 2,
        }
    }

    /// Allocate `size` bytes; returns the virtual base pointer.
    pub fn alloc(&self, kind: AllocKind, size: u64) -> Result<u64> {
        if size == 0 {
            bail!("zero-size allocation");
        }
        if kind == AllocKind::Device {
            let used = self.used_device.fetch_add(size, Ordering::Relaxed) + size;
            if used > self.total_device {
                self.used_device.fetch_sub(size, Ordering::Relaxed);
                bail!("device out of memory ({used} > {})", self.total_device);
            }
        }
        let aligned = (size + 255) & !255;
        let ptr = self.next[Self::slot(kind)].fetch_add(aligned, Ordering::Relaxed);
        self.allocs.lock().unwrap().insert(
            ptr,
            Allocation { size, kind, data: Arc::new(Mutex::new(vec![0u8; size as usize])) },
        );
        Ok(ptr)
    }

    /// Free a pointer returned by [`alloc`](Self::alloc).
    pub fn free(&self, ptr: u64) -> Result<()> {
        let mut allocs = self.allocs.lock().unwrap();
        let a = allocs.remove(&ptr).with_context(|| format!("free of unknown ptr {ptr:#x}"))?;
        if a.kind == AllocKind::Device {
            self.used_device.fetch_sub(a.size, Ordering::Relaxed);
        }
        Ok(())
    }

    fn find(&self, ptr: u64) -> Result<(u64, Arc<Mutex<Vec<u8>>>, u64)> {
        let allocs = self.allocs.lock().unwrap();
        let (base, a) = allocs
            .range(..=ptr)
            .next_back()
            .with_context(|| format!("pointer {ptr:#x} not in any allocation"))?;
        if ptr >= base + a.size {
            bail!("pointer {ptr:#x} past end of allocation at {base:#x}");
        }
        Ok((*base, a.data.clone(), a.size))
    }

    /// Copy `len` bytes from `src` to `dst` (real memmove between backing
    /// stores; overlapping same-allocation copies are handled).
    pub fn copy(&self, dst: u64, src: u64, len: u64) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        let (sbase, sdata, ssize) = self.find(src)?;
        let (dbase, ddata, dsize) = self.find(dst)?;
        let soff = (src - sbase) as usize;
        let doff = (dst - dbase) as usize;
        if soff + len as usize > ssize as usize || doff + len as usize > dsize as usize {
            bail!("copy of {len} bytes overruns an allocation");
        }
        if Arc::ptr_eq(&sdata, &ddata) {
            let mut d = sdata.lock().unwrap();
            d.copy_within(soff..soff + len as usize, doff);
        } else {
            let s = sdata.lock().unwrap();
            let mut d = ddata.lock().unwrap();
            d[doff..doff + len as usize].copy_from_slice(&s[soff..soff + len as usize]);
        }
        Ok(())
    }

    /// Read the full backing bytes at `ptr` (must be an allocation base and
    /// at least `len` long) — used by kernel launches to feed PJRT.
    pub fn read(&self, ptr: u64, len: u64) -> Result<Vec<u8>> {
        let (base, data, size) = self.find(ptr)?;
        let off = (ptr - base) as usize;
        if off + len as usize > size as usize {
            bail!("read of {len} bytes overruns allocation");
        }
        let d = data.lock().unwrap();
        Ok(d[off..off + len as usize].to_vec())
    }

    /// Write `bytes` at `ptr`.
    pub fn write(&self, ptr: u64, bytes: &[u8]) -> Result<()> {
        let (base, data, size) = self.find(ptr)?;
        let off = (ptr - base) as usize;
        if off + bytes.len() > size as usize {
            bail!("write of {} bytes overruns allocation", bytes.len());
        }
        data.lock().unwrap()[off..off + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    /// (used, total) device bytes — for `cuMemGetInfo` and telemetry.
    pub fn device_usage(&self) -> (u64, u64) {
        (self.used_device.load(Ordering::Relaxed), self.total_device)
    }

    /// Number of live allocations.
    pub fn live_allocations(&self) -> usize {
        self.allocs.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_spaces_are_tagged() {
        let p = MemoryPool::new(1 << 30);
        let h = p.alloc(AllocKind::Host, 64).unwrap();
        let d = p.alloc(AllocKind::Device, 64).unwrap();
        let s = p.alloc(AllocKind::Shared, 64).unwrap();
        assert_eq!(AllocKind::of_ptr(h), AllocKind::Host);
        assert_eq!(AllocKind::of_ptr(d), AllocKind::Device);
        assert_eq!(AllocKind::of_ptr(s), AllocKind::Shared);
        assert!(d >= 0xff00_0000_0000_0000, "device ptr must start 0xff");
        assert!(h >> 40 == 0x7f, "host ptr must start 0x00007f");
    }

    #[test]
    fn copy_moves_real_bytes() {
        let p = MemoryPool::new(1 << 30);
        let h = p.alloc(AllocKind::Host, 1024).unwrap();
        let d = p.alloc(AllocKind::Device, 1024).unwrap();
        p.write(h, &[7u8; 1024]).unwrap();
        p.copy(d, h, 1024).unwrap();
        assert_eq!(p.read(d, 1024).unwrap(), vec![7u8; 1024]);
    }

    #[test]
    fn copy_with_offsets() {
        let p = MemoryPool::new(1 << 30);
        let a = p.alloc(AllocKind::Host, 100).unwrap();
        let b = p.alloc(AllocKind::Host, 100).unwrap();
        p.write(a, &(0..100u8).collect::<Vec<_>>()).unwrap();
        p.copy(b + 10, a + 50, 20).unwrap();
        assert_eq!(p.read(b + 10, 20).unwrap(), (50..70u8).collect::<Vec<_>>());
    }

    #[test]
    fn device_oom_is_reported() {
        let p = MemoryPool::new(1000);
        assert!(p.alloc(AllocKind::Device, 800).is_ok());
        assert!(p.alloc(AllocKind::Device, 800).is_err());
        let (used, total) = p.device_usage();
        assert_eq!(used, 800);
        assert_eq!(total, 1000);
    }

    #[test]
    fn free_releases_device_bytes() {
        let p = MemoryPool::new(1000);
        let d = p.alloc(AllocKind::Device, 800).unwrap();
        p.free(d).unwrap();
        assert!(p.alloc(AllocKind::Device, 800).is_ok());
        assert!(p.free(0xdead).is_err());
    }

    #[test]
    fn out_of_bounds_ops_error() {
        let p = MemoryPool::new(1 << 20);
        let a = p.alloc(AllocKind::Host, 64).unwrap();
        assert!(p.read(a, 65).is_err());
        assert!(p.write(a + 60, &[0u8; 8]).is_err());
        assert!(p.read(0x1234, 1).is_err());
        let b = p.alloc(AllocKind::Host, 64).unwrap();
        assert!(p.copy(b, a + 32, 64).is_err());
    }

    #[test]
    fn overlapping_copy_same_allocation() {
        let p = MemoryPool::new(1 << 20);
        let a = p.alloc(AllocKind::Host, 32).unwrap();
        p.write(a, &(0..32u8).collect::<Vec<_>>()).unwrap();
        p.copy(a + 8, a, 16).unwrap();
        assert_eq!(p.read(a + 8, 16).unwrap(), (0..16u8).collect::<Vec<_>>());
    }
}
