//! GPU telemetry model: per-domain power, frequency, engine utilization,
//! memory and fabric counters (what the §3.5 Sysman daemon samples).
//!
//! Drives the Fig. 5 timeline rows: Power Domain 0 is the whole card,
//! Domains 1/2 are the tiles; Frequency Domains 0/1 are per-tile clocks;
//! ComputeEngine/CopyEngine % are per-tile busy fractions. The model maps
//! engine busy-time deltas (real wall time the worker threads spent
//! executing commands) onto a simple but physically-shaped power model:
//! idle floor + utilization-proportional draw, with clock droop under load.

use super::engine::{Engine, EngineKind};
use crate::util::Rng;
use std::sync::Arc;
use std::sync::Mutex;

/// Telemetry shape parameters (per GPU model).
#[derive(Debug, Clone)]
pub struct TelemetryModel {
    /// Card idle power (W).
    pub card_idle_w: f64,
    /// Tile idle power (W).
    pub tile_idle_w: f64,
    /// Max extra power per tile at full compute utilization (W).
    pub tile_compute_w: f64,
    /// Max extra power per tile at full copy utilization (W).
    pub tile_copy_w: f64,
    /// Max clock (MHz).
    pub freq_max_mhz: f64,
    /// Clock droop fraction at full load (0..1).
    pub freq_droop: f64,
}

impl TelemetryModel {
    /// Intel Data Center GPU Max 1550 (PVC)-shaped model.
    pub fn pvc() -> Self {
        TelemetryModel {
            card_idle_w: 100.0,
            tile_idle_w: 75.0,
            tile_compute_w: 225.0,
            tile_copy_w: 50.0,
            freq_max_mhz: 1600.0,
            freq_droop: 0.25,
        }
    }

    /// NVIDIA A100-shaped model (single "tile").
    pub fn a100() -> Self {
        TelemetryModel {
            card_idle_w: 60.0,
            tile_idle_w: 40.0,
            tile_compute_w: 260.0,
            tile_copy_w: 40.0,
            freq_max_mhz: 1410.0,
            freq_droop: 0.18,
        }
    }
}

/// One telemetry snapshot for a GPU.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySample {
    /// (domain, watts); domain 0 = card, 1.. = tiles.
    pub power: Vec<(u32, f64)>,
    /// (domain, accumulated energy in µJ).
    pub energy_uj: Vec<(u32, u64)>,
    /// (domain, MHz) per tile.
    pub freq: Vec<(u32, f64)>,
    /// (engine kind, tile, utilization 0..1).
    pub engine_util: Vec<(EngineKind, u32, f64)>,
    /// Device memory (used, total).
    pub memory: (u64, u64),
    /// Fabric counters (tx, rx bytes, cumulative).
    pub fabric: (u64, u64),
}

struct PrevState {
    t_ns: u64,
    busy_ns: Vec<u64>,
    energy_uj: Vec<f64>,
    rng: Rng,
}

/// Telemetry sampler state for one GPU.
pub struct Telemetry {
    model: TelemetryModel,
    tiles: u32,
    prev: Mutex<PrevState>,
}

impl Telemetry {
    /// Create sampler state. `engines` fixes the busy-counter layout.
    pub fn new(model: TelemetryModel, tiles: u32, n_engines: usize, seed: u64) -> Self {
        Telemetry {
            model,
            tiles,
            prev: Mutex::new(PrevState {
                t_ns: crate::tracer::now_ns(),
                busy_ns: vec![0; n_engines],
                energy_uj: vec![0.0; tiles as usize + 1],
                rng: Rng::new(seed),
            }),
        }
    }

    /// Take a sample given current engine counters and memory usage.
    pub fn sample(
        &self,
        now_ns: u64,
        engines: &[Arc<Engine>],
        memory: (u64, u64),
    ) -> TelemetrySample {
        let mut prev = self.prev.lock().unwrap();
        let dt_ns = now_ns.saturating_sub(prev.t_ns).max(1);

        // Per-engine utilization over the window.
        let mut utils = Vec::with_capacity(engines.len());
        for (i, e) in engines.iter().enumerate() {
            let (total, since) = e.busy_counters();
            let in_progress = if since > 0 { now_ns.saturating_sub(since) } else { 0 };
            let cur = total + in_progress;
            let delta = cur.saturating_sub(prev.busy_ns[i]);
            prev.busy_ns[i] = cur;
            utils.push((e.kind, e.tile, (delta as f64 / dt_ns as f64).min(1.0)));
        }

        // Aggregate per (kind, tile).
        let mut util_by = vec![[0.0f64; 2]; self.tiles as usize]; // [compute, copy]
        let mut counts = vec![[0u32; 2]; self.tiles as usize];
        for (kind, tile, u) in &utils {
            let k = kind.code() as usize;
            util_by[*tile as usize][k] += u;
            counts[*tile as usize][k] += 1;
        }
        for t in 0..self.tiles as usize {
            for k in 0..2 {
                if counts[t][k] > 0 {
                    util_by[t][k] /= counts[t][k] as f64;
                }
            }
        }

        let m = &self.model;
        let mut power = Vec::new();
        let mut freq = Vec::new();
        let mut card_w = m.card_idle_w;
        for t in 0..self.tiles {
            let uc = util_by[t as usize][0];
            let ux = util_by[t as usize][1];
            let jitter = 1.0 + 0.02 * (prev.rng.f64() - 0.5);
            let tile_w = (m.tile_idle_w + m.tile_compute_w * uc + m.tile_copy_w * ux) * jitter;
            card_w += tile_w;
            power.push((t + 1, tile_w));
            let f = m.freq_max_mhz * (1.0 - m.freq_droop * uc) * (1.0 + 0.01 * (prev.rng.f64() - 0.5));
            freq.push((t, f));
        }
        power.insert(0, (0, card_w));

        // Integrate energy.
        let dt_s = dt_ns as f64 / 1e9;
        let mut energy = Vec::new();
        for (i, (_, w)) in power.iter().enumerate() {
            prev.energy_uj[i] += w * dt_s * 1e6;
            energy.push((power[i].0, prev.energy_uj[i] as u64));
        }

        let mut engine_util = Vec::new();
        for t in 0..self.tiles {
            engine_util.push((EngineKind::Compute, t, util_by[t as usize][0]));
            engine_util.push((EngineKind::Copy, t, util_by[t as usize][1]));
        }

        let tx: u64 = engines
            .iter()
            .filter(|e| e.kind == EngineKind::Copy)
            .map(|e| e.bytes_copied.load(std::sync::atomic::Ordering::Relaxed))
            .sum();

        prev.t_ns = now_ns;
        TelemetrySample {
            power,
            energy_uj: energy,
            freq,
            engine_util,
            memory,
            fabric: (tx, tx / 2),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::memory::MemoryPool;
    use crate::runtime::{Executor, Manifest};

    fn engines(n: usize) -> Vec<Arc<Engine>> {
        let dir = crate::runtime::default_artifacts_dir();
        let manifest = Manifest::load(&dir).expect("artifacts required");
        let executor = Executor::start(manifest);
        let pool = Arc::new(MemoryPool::new(1 << 30));
        (0..n)
            .map(|i| {
                Engine::new(
                    if i % 2 == 0 { EngineKind::Compute } else { EngineKind::Copy },
                    i as u32,
                    (i / 2) as u32,
                    pool.clone(),
                    executor.clone(),
                )
            })
            .collect()
    }

    #[test]
    fn idle_gpu_has_idle_power_and_max_freq() {
        let t = Telemetry::new(TelemetryModel::pvc(), 2, 4, 1);
        let es = engines(4);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let s = t.sample(crate::tracer::now_ns(), &es, (0, 1 << 30));
        // card power = idle + 2 tiles idle (±2% jitter)
        let (d0, w0) = s.power[0];
        assert_eq!(d0, 0);
        assert!((w0 - 250.0).abs() < 15.0, "idle card power {w0}");
        for (_, f) in &s.freq {
            assert!(*f > 1500.0, "idle freq should be near max, got {f}");
        }
        for (_, _, u) in &s.engine_util {
            assert!(*u < 0.05, "idle util {u}");
        }
    }

    #[test]
    fn busy_copy_engine_shows_utilization() {
        use crate::device::engine::Command;
        use crate::device::memory::AllocKind;
        let es = engines(2);
        let t = Telemetry::new(TelemetryModel::pvc(), 1, 2, 2);
        // prime a window start
        t.sample(crate::tracer::now_ns(), &es, (0, 1));
        // hammer the copy engine (index 1)
        let pool = MemoryPool::new(1 << 30);
        let a = pool.alloc(AllocKind::Host, 1 << 20).unwrap();
        let _ = a;
        // The engines were built over their own pool; just use busy time via
        // barrier commands instead (they're ~instant), so simulate business by
        // sleeping while an engine runs many tiny commands.
        let copy = &es[1];
        for _ in 0..50 {
            copy.submit(1, vec![Command::Barrier { signal: None }], None);
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        let s = t.sample(crate::tracer::now_ns(), &es, (0, 1));
        // barriers are near-instant; utilization is small but the sample
        // machinery must still report consistent domains
        assert_eq!(s.engine_util.len(), 2);
        assert_eq!(s.power.len(), 2);
    }

    #[test]
    fn energy_accumulates_monotonically() {
        let t = Telemetry::new(TelemetryModel::a100(), 1, 2, 3);
        let es = engines(2);
        let mut last = 0u64;
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(3));
            let s = t.sample(crate::tracer::now_ns(), &es, (0, 1));
            let e0 = s.energy_uj[0].1;
            assert!(e0 >= last);
            last = e0;
        }
        assert!(last > 0);
    }
}
