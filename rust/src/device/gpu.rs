//! A simulated GPU: memory pool + per-tile engines + telemetry.

use super::engine::{Command, CompletionRecord, Engine, EngineKind};
use super::event::DevEvent;
use super::memory::{AllocKind, MemoryPool};
use super::telemetry::{Telemetry, TelemetryModel, TelemetrySample};
use crate::runtime::Executor;
use anyhow::Result;
use std::sync::Arc;

/// One GPU.
pub struct Gpu {
    /// Index within the node.
    pub index: u32,
    /// Marketing name (traces/telemetry labels).
    pub name: String,
    /// Device handle as it appears in traces (stable, per node).
    pub handle: u64,
    /// Tile count (PVC: 2, A100: 1).
    pub tiles: u32,
    /// Memory pool.
    pub pool: Arc<MemoryPool>,
    /// Engines: for each tile a compute engine, then for each tile a copy
    /// engine. Ordinals: `0..tiles` = compute, `tiles..2*tiles` = copy.
    pub engines: Vec<Arc<Engine>>,
    telemetry: Telemetry,
}

impl Gpu {
    /// Build a GPU with its engine worker threads.
    pub fn new(
        index: u32,
        name: &str,
        tiles: u32,
        device_mem: u64,
        model: TelemetryModel,
        executor: Arc<Executor>,
    ) -> Arc<Self> {
        let pool = Arc::new(MemoryPool::new(device_mem));
        let mut engines = Vec::new();
        for t in 0..tiles {
            engines.push(Engine::new(EngineKind::Compute, t, t, pool.clone(), executor.clone()));
        }
        for t in 0..tiles {
            engines.push(Engine::new(
                EngineKind::Copy,
                tiles + t,
                t,
                pool.clone(),
                executor.clone(),
            ));
        }
        let telemetry = Telemetry::new(model, tiles, engines.len(), 0x5eed ^ index as u64);
        Arc::new(Gpu {
            index,
            name: name.into(),
            handle: 0x1000_0000u64 + (index as u64) * 0x100,
            tiles,
            pool,
            engines,
            telemetry,
        })
    }

    /// The engine for a queue ordinal (Level-Zero style: the ordinal picks
    /// the engine group). Out-of-range ordinals wrap.
    pub fn engine(&self, ordinal: u32) -> &Arc<Engine> {
        &self.engines[(ordinal as usize) % self.engines.len()]
    }

    /// First compute engine (tile 0).
    pub fn compute_engine(&self) -> &Arc<Engine> {
        &self.engines[0]
    }

    /// First copy engine (tile 0). This is the engine the *fixed* OpenMP
    /// runtime uses for transfers; the buggy one (paper §4.1) uses
    /// [`compute_engine`](Self::compute_engine) instead.
    pub fn copy_engine(&self) -> &Arc<Engine> {
        &self.engines[self.tiles as usize]
    }

    /// Allocate memory.
    pub fn alloc(&self, kind: AllocKind, size: u64) -> Result<u64> {
        self.pool.alloc(kind, size)
    }

    /// Free memory.
    pub fn free(&self, ptr: u64) -> Result<()> {
        self.pool.free(ptr)
    }

    /// Submit a batch to engine `ordinal`.
    pub fn submit(
        &self,
        ordinal: u32,
        queue: u64,
        commands: Vec<Command>,
        fence: Option<Arc<DevEvent>>,
    ) {
        self.engine(ordinal).submit(queue, commands, fence);
    }

    /// Wait until every engine is idle (device-wide synchronize).
    pub fn synchronize(&self) {
        for e in &self.engines {
            e.wait_idle();
        }
    }

    /// Drain completion records from all engines (profiling helpers call
    /// this at synchronize points).
    pub fn drain_completions(&self, queue: Option<u64>) -> Vec<CompletionRecord> {
        let mut out = Vec::new();
        for e in &self.engines {
            out.extend(e.drain_completions(queue));
        }
        out.sort_by_key(|r| r.ts_start);
        out
    }

    /// Take a Sysman-style telemetry sample.
    pub fn sysman_sample(&self) -> TelemetrySample {
        self.telemetry
            .sample(crate::tracer::now_ns(), &self.engines, self.pool.device_usage())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::time::Duration;

    fn test_gpu(tiles: u32) -> Arc<Gpu> {
        let dir = crate::runtime::default_artifacts_dir();
        let executor = Executor::start(Manifest::load(&dir).expect("artifacts required"));
        Gpu::new(0, "Test GPU", tiles, 1 << 30, TelemetryModel::pvc(), executor)
    }

    #[test]
    fn engine_layout_matches_tiles() {
        let g = test_gpu(2);
        assert_eq!(g.engines.len(), 4);
        assert_eq!(g.compute_engine().kind, EngineKind::Compute);
        assert_eq!(g.copy_engine().kind, EngineKind::Copy);
        assert_eq!(g.engine(0).ordinal, 0);
        assert_eq!(g.engine(2).kind, EngineKind::Copy);
        assert_eq!(g.engine(99).ordinal, 99 % 4);
    }

    #[test]
    fn synchronize_waits_for_submitted_work() {
        let g = test_gpu(1);
        let src = g.alloc(AllocKind::Host, 1 << 16).unwrap();
        let dst = g.alloc(AllocKind::Device, 1 << 16).unwrap();
        for _ in 0..20 {
            g.submit(
                1,
                0x1,
                vec![Command::Memcpy { dst, src, bytes: 1 << 16, signal: None }],
                None,
            );
        }
        g.synchronize();
        assert_eq!(g.drain_completions(None).len(), 20);
    }

    #[test]
    fn device_wide_sync_with_fence() {
        let g = test_gpu(1);
        let ev = Arc::new(DevEvent::new());
        g.submit(0, 1, vec![Command::Barrier { signal: None }], Some(ev.clone()));
        assert!(ev.wait(Duration::from_secs(10)));
        g.synchronize();
    }

    #[test]
    fn sysman_sample_has_all_domains() {
        let g = test_gpu(2);
        std::thread::sleep(Duration::from_millis(2));
        let s = g.sysman_sample();
        assert_eq!(s.power.len(), 3); // card + 2 tiles
        assert_eq!(s.freq.len(), 2);
        assert_eq!(s.engine_util.len(), 4);
        assert_eq!(s.memory.1, 1 << 30);
    }
}
