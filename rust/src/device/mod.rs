//! Simulated heterogeneous node: the hardware substrate the programming
//! model frontends drive.
//!
//! The paper's testbeds (Aurora: 6× Intel PVC with 2 tiles each; Polaris:
//! 4× NVIDIA A100) are replaced by software GPUs that preserve everything
//! the tracer can observe:
//!
//! * **memory** ([`memory`]) — host/device/shared allocations in distinct
//!   address ranges (device pointers start `0xff…`, host `0x00007f…`, the
//!   very detail the paper's §1.1 example reads off the trace);
//! * **engines** ([`engine`]) — per-tile compute and copy engines with
//!   their own worker threads, executing commands asynchronously: kernel
//!   launches run **real PJRT-compiled HLO** via [`crate::runtime`],
//!   memory copies move real bytes;
//! * **events** ([`event`]) — signalable device events with device-clock
//!   start/end timestamps, the raw material of GPU profiling;
//! * **telemetry** ([`telemetry`]) — per-domain power/frequency/utilization
//!   derived from engine activity, sampled by the §3.5 daemon.

pub mod engine;
pub mod event;
pub mod gpu;
pub mod memory;
pub mod node;
pub mod telemetry;

pub use engine::{Command, CompletionRecord, Engine, EngineKind};
pub use event::DevEvent;
pub use gpu::Gpu;
pub use memory::{AllocKind, MemoryPool};
pub use node::{Backend, Node, NodeConfig};
