//! Device engines: asynchronous command execution on worker threads.
//!
//! Each simulated GPU exposes per-tile **compute** and **copy** engines
//! (the PVC layout the paper's timeline shows: ComputeEngine Domain 0/1,
//! CopyEngine Domain 0/1). Commands are submitted in batches (one
//! `zeCommandQueueExecuteCommandLists`) and executed in order; kernel
//! commands run real PJRT executables through [`crate::runtime::Executor`],
//! copies move real bytes through the [`MemoryPool`]. Completion records
//! (with device start/end timestamps) accumulate per queue and are drained
//! by the frontends' profiling helpers at synchronize time — exactly when
//! THAPI's generated GPU-profiling code reads Level-Zero timestamps.

use super::memory::MemoryPool;
use crate::device::event::DevEvent;
use crate::runtime::Executor;
use crate::tracer::now_ns;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Engine kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Kernel execution (MXU/VPU work).
    Compute,
    /// Memory transfers (BLT/copy engine).
    Copy,
}

impl EngineKind {
    /// Wire encoding used in trace events (0 = compute, 1 = copy).
    pub fn code(&self) -> u32 {
        match self {
            EngineKind::Compute => 0,
            EngineKind::Copy => 1,
        }
    }
}

/// One device command.
#[derive(Debug, Clone)]
pub enum Command {
    /// Launch a named kernel. `args` are pointers: the kernel's N inputs
    /// followed by the output pointer. `groups` is the launch geometry
    /// (traced, and sanity-checked against the manifest).
    Kernel {
        /// Kernel name (manifest key).
        name: String,
        /// N input pointers + 1 output pointer.
        args: Vec<u64>,
        /// Group counts (gx, gy, gz).
        groups: (u32, u32, u32),
        /// Signal event.
        signal: Option<Arc<DevEvent>>,
    },
    /// Copy `bytes` from `src` to `dst`.
    Memcpy {
        /// Destination pointer.
        dst: u64,
        /// Source pointer.
        src: u64,
        /// Byte count.
        bytes: u64,
        /// Signal event.
        signal: Option<Arc<DevEvent>>,
    },
    /// Execution barrier (ordering marker).
    Barrier {
        /// Signal event.
        signal: Option<Arc<DevEvent>>,
    },
}

impl Command {
    fn signal_event(&self) -> Option<&Arc<DevEvent>> {
        match self {
            Command::Kernel { signal, .. }
            | Command::Memcpy { signal, .. }
            | Command::Barrier { signal } => signal.as_ref(),
        }
    }
}

/// Completion record: what the profiling helpers emit as
/// `lttng_ust_profiling:command_completed`.
#[derive(Debug, Clone)]
pub struct CompletionRecord {
    /// Queue handle the batch was submitted on.
    pub queue: u64,
    /// Engine ordinal within the GPU.
    pub engine_ordinal: u32,
    /// Engine kind.
    pub engine_kind: EngineKind,
    /// `"kernel"`, `"memcpy"` or `"barrier"`.
    pub kind: &'static str,
    /// Kernel name (empty for non-kernels).
    pub name: String,
    /// Device start timestamp (host-ns domain).
    pub ts_start: u64,
    /// Device end timestamp.
    pub ts_end: u64,
    /// Bytes moved (memcpy) or 0.
    pub bytes: u64,
    /// Error message if the command failed (kernel errors surface at sync).
    pub error: Option<String>,
}

struct Batch {
    queue: u64,
    commands: Vec<Command>,
    fence: Option<Arc<DevEvent>>,
}

/// An engine with its worker thread.
pub struct Engine {
    /// Kind (compute/copy).
    pub kind: EngineKind,
    /// Ordinal within the GPU (matches queue-creation ordinal).
    pub ordinal: u32,
    /// Tile (telemetry domain) this engine belongs to.
    pub tile: u32,
    tx: Mutex<mpsc::Sender<Batch>>,
    /// Total busy nanoseconds (telemetry).
    busy_ns: AtomicU64,
    /// If currently executing, the host-ns the current command started.
    busy_since: AtomicU64,
    /// Commands completed.
    pub commands_done: AtomicU64,
    /// Bytes copied (fabric/copy counters).
    pub bytes_copied: AtomicU64,
    /// Pending completion records, drained at synchronize.
    completions: Mutex<Vec<CompletionRecord>>,
    /// In-flight batches + wakeup for blocking synchronize (a yield-spin
    /// here starves the engine worker on small core counts).
    inflight: Mutex<u64>,
    idle_cond: Condvar,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Engine {
    /// Spawn an engine worker.
    pub fn new(
        kind: EngineKind,
        ordinal: u32,
        tile: u32,
        pool: Arc<MemoryPool>,
        executor: Arc<Executor>,
    ) -> Arc<Self> {
        let (tx, rx) = mpsc::channel::<Batch>();
        let engine = Arc::new(Engine {
            kind,
            ordinal,
            tile,
            tx: Mutex::new(tx),
            busy_ns: AtomicU64::new(0),
            busy_since: AtomicU64::new(0),
            commands_done: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            completions: Mutex::new(Vec::new()),
            inflight: Mutex::new(0),
            idle_cond: Condvar::new(),
            handle: Mutex::new(None),
        });
        let worker = engine.clone();
        let handle = std::thread::Builder::new()
            .name(format!("engine-{ordinal}-{kind:?}"))
            .spawn(move || worker.run(rx, pool, executor))
            .expect("spawn engine");
        *engine.handle.lock().unwrap() = Some(handle);
        engine
    }

    /// Submit a command batch (non-blocking). `fence` is signaled when the
    /// whole batch completed.
    pub fn submit(&self, queue: u64, commands: Vec<Command>, fence: Option<Arc<DevEvent>>) {
        *self.inflight.lock().unwrap() += 1;
        self.tx
            .lock()
            .unwrap()
            .send(Batch { queue, commands, fence })
            .expect("engine worker gone");
    }

    /// True when no batch is queued or executing.
    pub fn idle(&self) -> bool {
        *self.inflight.lock().unwrap() == 0
    }

    /// Block until the engine drains (no yield-spin: the waiter must not
    /// steal cycles from the worker on small machines).
    pub fn wait_idle(&self) {
        let mut inflight = self.inflight.lock().unwrap();
        while *inflight > 0 {
            inflight = self.idle_cond.wait(inflight).unwrap();
        }
    }

    /// Busy-time counters for telemetry: (total busy ns, busy-since ns or 0).
    pub fn busy_counters(&self) -> (u64, u64) {
        (self.busy_ns.load(Ordering::Relaxed), self.busy_since.load(Ordering::Relaxed))
    }

    /// Drain completion records for `queue` (None = all).
    pub fn drain_completions(&self, queue: Option<u64>) -> Vec<CompletionRecord> {
        let mut c = self.completions.lock().unwrap();
        match queue {
            None => std::mem::take(&mut *c),
            Some(q) => {
                let (take, keep): (Vec<_>, Vec<_>) = c.drain(..).partition(|r| r.queue == q);
                *c = keep;
                take
            }
        }
    }

    fn run(self: Arc<Self>, rx: mpsc::Receiver<Batch>, pool: Arc<MemoryPool>, executor: Arc<Executor>) {
        while let Ok(batch) = rx.recv() {
            for cmd in &batch.commands {
                let t0 = now_ns();
                self.busy_since.store(t0, Ordering::Relaxed);
                let (kind, name, bytes, error) = match cmd {
                    Command::Kernel { name, args, groups, .. } => {
                        let err = self.run_kernel(&pool, &executor, name, args, *groups);
                        ("kernel", name.clone(), 0u64, err)
                    }
                    Command::Memcpy { dst, src, bytes, .. } => {
                        let err = pool.copy(*dst, *src, *bytes).err().map(|e| e.to_string());
                        self.bytes_copied.fetch_add(*bytes, Ordering::Relaxed);
                        ("memcpy", String::new(), *bytes, err)
                    }
                    Command::Barrier { .. } => ("barrier", String::new(), 0, None),
                };
                let t1 = now_ns();
                self.busy_since.store(0, Ordering::Relaxed);
                self.busy_ns.fetch_add(t1 - t0, Ordering::Relaxed);
                self.commands_done.fetch_add(1, Ordering::Relaxed);
                if let Some(ev) = cmd.signal_event() {
                    ev.signal(t0, t1);
                }
                self.completions.lock().unwrap().push(CompletionRecord {
                    queue: batch.queue,
                    engine_ordinal: self.ordinal,
                    engine_kind: self.kind,
                    kind,
                    name,
                    ts_start: t0,
                    ts_end: t1,
                    bytes,
                    error,
                });
            }
            // Retire the batch before signaling its fence so that a waiter
            // woken by the fence observes the engine idle.
            {
                let mut inflight = self.inflight.lock().unwrap();
                *inflight -= 1;
                if *inflight == 0 {
                    self.idle_cond.notify_all();
                }
            }
            if let Some(f) = &batch.fence {
                let t = now_ns();
                f.signal(t, t);
            }
        }
    }

    fn run_kernel(
        &self,
        pool: &MemoryPool,
        executor: &Executor,
        name: &str,
        args: &[u64],
        _groups: (u32, u32, u32),
    ) -> Option<String> {
        let spec = match executor.manifest().kernel(name) {
            Some(s) => s.clone(),
            None => return Some(format!("unknown kernel {name}")),
        };
        if args.len() != spec.params.len() + 1 {
            return Some(format!(
                "kernel {name}: {} args, expected {} inputs + 1 output",
                args.len(),
                spec.params.len()
            ));
        }
        let mut inputs = Vec::with_capacity(spec.params.len());
        for (ptr, p) in args[..spec.params.len()].iter().zip(&spec.params) {
            match pool.read(*ptr, p.bytes() as u64) {
                Ok(b) => inputs.push(b),
                Err(e) => return Some(format!("kernel {name}: {e}")),
            }
        }
        match executor.execute(name, inputs) {
            Ok(out) => match pool.write(args[spec.params.len()], &out) {
                Ok(()) => None,
                Err(e) => Some(format!("kernel {name}: writeback: {e}")),
            },
            Err(e) => Some(format!("kernel {name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::memory::AllocKind;
    use crate::runtime::{Executor, Manifest};
    use std::time::Duration;

    fn test_engine(kind: EngineKind) -> (Arc<Engine>, Arc<MemoryPool>) {
        let dir = crate::runtime::default_artifacts_dir();
        let manifest = Manifest::load(&dir).expect("artifacts required: run `make artifacts`");
        let executor = Executor::start(manifest);
        let pool = Arc::new(MemoryPool::new(4 << 30));
        (Engine::new(kind, 0, 0, pool.clone(), executor), pool)
    }

    #[test]
    fn memcpy_command_executes_and_signals() {
        let (engine, pool) = test_engine(EngineKind::Copy);
        let src = pool.alloc(AllocKind::Host, 4096).unwrap();
        let dst = pool.alloc(AllocKind::Device, 4096).unwrap();
        pool.write(src, &[42u8; 4096]).unwrap();
        let ev = Arc::new(DevEvent::new());
        engine.submit(
            0x100,
            vec![Command::Memcpy { dst, src, bytes: 4096, signal: Some(ev.clone()) }],
            None,
        );
        assert!(ev.wait(Duration::from_secs(10)));
        assert_eq!(pool.read(dst, 4096).unwrap(), vec![42u8; 4096]);
        let (s, e) = ev.timestamps();
        assert!(e >= s);
        let recs = engine.drain_completions(Some(0x100));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kind, "memcpy");
        assert_eq!(recs[0].bytes, 4096);
        assert!(recs[0].error.is_none());
    }

    #[test]
    fn kernel_command_runs_real_pjrt_compute() {
        let (engine, pool) = test_engine(EngineKind::Compute);
        let n = 1usize << 20;
        let a = pool.alloc(AllocKind::Device, 4).unwrap();
        let x = pool.alloc(AllocKind::Device, (n * 4) as u64).unwrap();
        let y = pool.alloc(AllocKind::Device, (n * 4) as u64).unwrap();
        let out = pool.alloc(AllocKind::Device, (n * 4) as u64).unwrap();
        pool.write(a, &2.0f32.to_le_bytes()).unwrap();
        pool.write(x, &crate::runtime::executor::f32_to_bytes(&vec![3.0; n])).unwrap();
        pool.write(y, &crate::runtime::executor::f32_to_bytes(&vec![1.0; n])).unwrap();
        let ev = Arc::new(DevEvent::new());
        engine.submit(
            0x200,
            vec![Command::Kernel {
                name: "saxpy".into(),
                args: vec![a, x, y, out],
                groups: (16, 1, 1),
                signal: Some(ev.clone()),
            }],
            None,
        );
        assert!(ev.wait(Duration::from_secs(60)));
        let got = crate::runtime::executor::bytes_to_f32(&pool.read(out, (n * 4) as u64).unwrap());
        assert!(got.iter().all(|&v| (v - 7.0).abs() < 1e-6), "saxpy numerics wrong");
        let recs = engine.drain_completions(None);
        assert_eq!(recs[0].name, "saxpy");
        assert!(recs[0].error.is_none(), "{:?}", recs[0].error);
    }

    #[test]
    fn kernel_errors_surface_in_completions() {
        let (engine, _pool) = test_engine(EngineKind::Compute);
        let fence = Arc::new(DevEvent::new());
        engine.submit(
            1,
            vec![Command::Kernel {
                name: "no_such_kernel".into(),
                args: vec![0],
                groups: (1, 1, 1),
                signal: None,
            }],
            Some(fence.clone()),
        );
        assert!(fence.wait(Duration::from_secs(10)));
        let recs = engine.drain_completions(None);
        assert!(recs[0].error.is_some());
    }

    #[test]
    fn batch_fence_signals_after_all_commands() {
        let (engine, pool) = test_engine(EngineKind::Copy);
        let a = pool.alloc(AllocKind::Host, 1024).unwrap();
        let b = pool.alloc(AllocKind::Device, 1024).unwrap();
        let fence = Arc::new(DevEvent::new());
        let cmds: Vec<Command> = (0..10)
            .map(|_| Command::Memcpy { dst: b, src: a, bytes: 1024, signal: None })
            .collect();
        engine.submit(7, cmds, Some(fence.clone()));
        assert!(fence.wait(Duration::from_secs(10)));
        assert!(engine.idle());
        assert_eq!(engine.drain_completions(Some(7)).len(), 10);
        assert_eq!(engine.commands_done.load(Ordering::Relaxed), 10);
    }
}
