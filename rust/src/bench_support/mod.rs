//! In-crate benchmark harness (criterion substitute — no network, so no
//! external bench crates). Used by every `benches/*.rs` target
//! (`harness = false`) to produce the paper's tables and figures.

use std::time::{Duration, Instant};

pub mod alloc_track {
    //! Heap-usage tracking for benchmarks: a counting [`GlobalAlloc`]
    //! wrapper around the system allocator. A bench binary opts in with
    //!
    //! ```ignore
    //! #[global_allocator]
    //! static ALLOC: thapi::bench_support::alloc_track::CountingAlloc =
    //!     thapi::bench_support::alloc_track::CountingAlloc;
    //! ```
    //!
    //! and then brackets a phase with [`reset_peak`] + [`peak_bytes`] to
    //! read the phase's peak resident heap (e.g. streaming vs
    //! materialized analysis in `benches/fig8_space.rs`).

    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicUsize, Ordering};

    static LIVE: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);

    /// Counting allocator; zero-cost pass-through to [`System`] plus two
    /// relaxed atomics per alloc/free.
    pub struct CountingAlloc;

    fn on_alloc(size: usize) {
        let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(live, Ordering::Relaxed);
    }

    fn on_free(size: usize) {
        LIVE.fetch_sub(size, Ordering::Relaxed);
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            on_free(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                // count the new block before releasing the old one: during
                // a growing realloc both buffers coexist, and PEAK must see
                // that instant
                on_alloc(new_size);
                on_free(layout.size());
            }
            p
        }
    }

    /// Currently live heap bytes.
    pub fn live_bytes() -> usize {
        LIVE.load(Ordering::Relaxed)
    }

    /// Peak live heap bytes since the last [`reset_peak`].
    pub fn peak_bytes() -> usize {
        PEAK.load(Ordering::Relaxed)
    }

    /// Start a new measurement phase: peak := current live.
    pub fn reset_peak() {
        PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// `1` / `true` in `THAPI_BENCH_QUICK` selects the bounded quick mode:
/// benches shrink their workloads to a few seconds total so CI can smoke
/// them on every push. Full runs (the numbers recorded in
/// `BENCH_*.json`) leave it unset.
pub fn quick_mode() -> bool {
    matches!(
        std::env::var("THAPI_BENCH_QUICK").as_deref(),
        Ok("1") | Ok("true") | Ok("yes")
    )
}

/// Minimal JSON emitter for the `BENCH_<name>.json` result files the
/// benches check in (no serde in-tree; the format is flat on purpose:
/// one `meta` object and one `results` array of uniform metric rows, so
/// a later PR can diff before/after numbers mechanically).
pub struct BenchJson {
    name: String,
    meta: Vec<(String, String)>,
    results: Vec<Vec<(String, String)>>,
}

/// Quote and escape a JSON string value.
pub fn js_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a finite number as a JSON value (NaN/inf become null).
pub fn js_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".into()
    }
}

impl BenchJson {
    /// Start a result file for bench `name` (file: `BENCH_<name>.json`).
    pub fn new(name: &str) -> BenchJson {
        BenchJson { name: name.to_string(), meta: Vec::new(), results: Vec::new() }
    }

    /// Add a top-level meta field; `raw` must already be valid JSON
    /// (use [`js_str`] / [`js_num`]).
    pub fn meta(&mut self, key: &str, raw: String) -> &mut Self {
        self.meta.push((key.to_string(), raw));
        self
    }

    /// Append one metric row; values must already be valid JSON.
    pub fn result(&mut self, fields: &[(&str, String)]) -> &mut Self {
        self.results
            .push(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
        self
    }

    /// Render the document.
    pub fn render(&self) -> String {
        let obj = |fields: &[(String, String)], indent: &str| -> String {
            let body: Vec<String> =
                fields.iter().map(|(k, v)| format!("{indent}  {}: {v}", js_str(k))).collect();
            format!("{{\n{}\n{indent}}}", body.join(",\n"))
        };
        let rows: Vec<String> =
            self.results.iter().map(|r| format!("    {}", obj(r, "    "))).collect();
        let mut meta = vec![("bench".to_string(), js_str(&self.name))];
        meta.extend(self.meta.iter().cloned());
        let meta_body: Vec<String> =
            meta.iter().map(|(k, v)| format!("  {}: {v}", js_str(k))).collect();
        format!(
            "{{\n{},\n  \"results\": [\n{}\n  ]\n}}\n",
            meta_body.join(",\n"),
            rows.join(",\n")
        )
    }

    /// Write `BENCH_<name>.json` into `$THAPI_BENCH_JSON_DIR` (default:
    /// the working directory — the repo root under `cargo bench`) and
    /// return the path.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("THAPI_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        let path = std::path::Path::new(&dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

/// Simple timing statistics over repeated measurements.
#[derive(Debug, Clone)]
pub struct Stats {
    /// All samples.
    pub samples: Vec<Duration>,
}

impl Stats {
    /// Gather `n` samples of `f` after `warmup` unrecorded calls.
    pub fn measure<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Stats {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        Stats { samples }
    }

    /// Mean duration.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    /// Median duration.
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    /// Minimum.
    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }

    /// Maximum.
    pub fn max(&self) -> Duration {
        self.samples.iter().max().copied().unwrap_or_default()
    }

    /// Sample standard deviation (seconds).
    pub fn stddev_s(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean().as_secs_f64();
        let var: f64 = self
            .samples
            .iter()
            .map(|s| (s.as_secs_f64() - m).powi(2))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }
}

/// Summary statistics over a set of per-benchmark values (the mean /
/// median lines in Fig. 7).
pub fn mean_of(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Median of a value set.
pub fn median_of(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() % 2 == 1 {
        v[v.len() / 2]
    } else {
        (v[v.len() / 2 - 1] + v[v.len() / 2]) / 2.0
    }
}

/// Markdown-ish table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Add a row (must match header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
        self
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::measure(1, 5, || std::thread::sleep(Duration::from_micros(100)));
        assert_eq!(s.samples.len(), 5);
        assert!(s.mean() >= Duration::from_micros(100));
        assert!(s.min() <= s.median() && s.median() <= s.max());
    }

    #[test]
    fn mean_median_of_values() {
        assert_eq!(mean_of(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median_of(&[5.0, 1.0, 3.0]), 3.0);
        assert_eq!(median_of(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(mean_of(&[]), 0.0);
    }

    #[test]
    fn bench_json_renders_valid_flat_documents() {
        let mut j = BenchJson::new("demo");
        j.meta("events", js_num(100.0));
        j.meta("app", js_str("with \"quotes\"\nand newline"));
        j.result(&[("name", js_str("encode")), ("rate", js_num(1.5))]);
        j.result(&[("name", js_str("decode")), ("rate", js_num(f64::NAN))]);
        let doc = j.render();
        assert!(doc.contains("\"bench\": \"demo\""));
        assert!(doc.contains("\"events\": 100.000"));
        assert!(doc.contains("\\\"quotes\\\"\\nand newline"));
        assert!(doc.contains("\"rate\": null"), "non-finite numbers become null");
        // structurally balanced (cheap stand-in for a JSON parser)
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
        assert_eq!(doc.matches('"').count() % 2, 0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("| name   | value |"));
        assert!(r.lines().count() == 4);
    }
}
