//! In-crate benchmark harness (criterion substitute — no network, so no
//! external bench crates). Used by every `benches/*.rs` target
//! (`harness = false`) to produce the paper's tables and figures.

use std::time::{Duration, Instant};

pub mod alloc_track {
    //! Heap-usage tracking for benchmarks: a counting [`GlobalAlloc`]
    //! wrapper around the system allocator. A bench binary opts in with
    //!
    //! ```ignore
    //! #[global_allocator]
    //! static ALLOC: thapi::bench_support::alloc_track::CountingAlloc =
    //!     thapi::bench_support::alloc_track::CountingAlloc;
    //! ```
    //!
    //! and then brackets a phase with [`reset_peak`] + [`peak_bytes`] to
    //! read the phase's peak resident heap (e.g. streaming vs
    //! materialized analysis in `benches/fig8_space.rs`).

    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicUsize, Ordering};

    static LIVE: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);

    /// Counting allocator; zero-cost pass-through to [`System`] plus two
    /// relaxed atomics per alloc/free.
    pub struct CountingAlloc;

    fn on_alloc(size: usize) {
        let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(live, Ordering::Relaxed);
    }

    fn on_free(size: usize) {
        LIVE.fetch_sub(size, Ordering::Relaxed);
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            on_free(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                // count the new block before releasing the old one: during
                // a growing realloc both buffers coexist, and PEAK must see
                // that instant
                on_alloc(new_size);
                on_free(layout.size());
            }
            p
        }
    }

    /// Currently live heap bytes.
    pub fn live_bytes() -> usize {
        LIVE.load(Ordering::Relaxed)
    }

    /// Peak live heap bytes since the last [`reset_peak`].
    pub fn peak_bytes() -> usize {
        PEAK.load(Ordering::Relaxed)
    }

    /// Start a new measurement phase: peak := current live.
    pub fn reset_peak() {
        PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Simple timing statistics over repeated measurements.
#[derive(Debug, Clone)]
pub struct Stats {
    /// All samples.
    pub samples: Vec<Duration>,
}

impl Stats {
    /// Gather `n` samples of `f` after `warmup` unrecorded calls.
    pub fn measure<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Stats {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        Stats { samples }
    }

    /// Mean duration.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    /// Median duration.
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    /// Minimum.
    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }

    /// Maximum.
    pub fn max(&self) -> Duration {
        self.samples.iter().max().copied().unwrap_or_default()
    }

    /// Sample standard deviation (seconds).
    pub fn stddev_s(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean().as_secs_f64();
        let var: f64 = self
            .samples
            .iter()
            .map(|s| (s.as_secs_f64() - m).powi(2))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }
}

/// Summary statistics over a set of per-benchmark values (the mean /
/// median lines in Fig. 7).
pub fn mean_of(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Median of a value set.
pub fn median_of(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() % 2 == 1 {
        v[v.len() / 2]
    } else {
        (v[v.len() / 2 - 1] + v[v.len() / 2]) / 2.0
    }
}

/// Markdown-ish table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Add a row (must match header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
        self
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::measure(1, 5, || std::thread::sleep(Duration::from_micros(100)));
        assert_eq!(s.samples.len(), 5);
        assert!(s.mean() >= Duration::from_micros(100));
        assert!(s.min() <= s.median() && s.median() <= s.max());
    }

    #[test]
    fn mean_median_of_values() {
        assert_eq!(mean_of(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median_of(&[5.0, 1.0, 3.0]), 3.0);
        assert_eq!(median_of(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(mean_of(&[]), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("| name   | value |"));
        assert!(r.lines().count() == 4);
    }
}
