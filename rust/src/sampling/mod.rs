//! Device-telemetry sampling daemon (paper §3.5).
//!
//! A background thread samples every GPU's Sysman-style counters (energy,
//! power, frequency, memory, fabric, engine utilization) at a user-defined
//! interval — default 50 ms like THAPI — and streams the samples into the
//! LTTng-substitute trace as `lttng_ust_sampling:*` events. Enabled with
//! `iprof --sample` (the TS-* configurations of §5.2).

use crate::device::Node;
use crate::model::{class_by_name, EventClass};
use crate::tracer::emit;
use once_cell::sync::Lazy;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SamplingConfig {
    /// Sampling period (THAPI default: 50 ms).
    pub interval: Duration,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { interval: Duration::from_millis(50) }
    }
}

struct SamplingTps {
    power: &'static EventClass,
    freq: &'static EventClass,
    util: &'static EventClass,
    memory: &'static EventClass,
    fabric: &'static EventClass,
}

static TPS: Lazy<SamplingTps> = Lazy::new(|| SamplingTps {
    power: class_by_name("lttng_ust_sampling:gpu_power").unwrap(),
    freq: class_by_name("lttng_ust_sampling:gpu_frequency").unwrap(),
    util: class_by_name("lttng_ust_sampling:gpu_engine_util").unwrap(),
    memory: class_by_name("lttng_ust_sampling:gpu_memory").unwrap(),
    fabric: class_by_name("lttng_ust_sampling:gpu_fabric").unwrap(),
});

/// Take one sample of every GPU on `node` and emit the events.
/// Returns the number of events emitted.
pub fn sample_once(node: &Node) -> usize {
    let mut n = 0;
    for gpu in &node.gpus {
        let s = gpu.sysman_sample();
        for (i, (domain, watts)) in s.power.iter().enumerate() {
            let energy = s.energy_uj.get(i).map(|(_, e)| *e).unwrap_or(0);
            emit(TPS.power, |e| {
                e.ptr(gpu.handle).u32(*domain).f64(*watts).u64(energy);
            });
            n += 1;
        }
        for (domain, mhz) in &s.freq {
            emit(TPS.freq, |e| {
                e.ptr(gpu.handle).u32(*domain).f64(*mhz);
            });
            n += 1;
        }
        for (kind, domain, util) in &s.engine_util {
            emit(TPS.util, |e| {
                e.ptr(gpu.handle).u32(kind.code()).u32(*domain).f64(*util);
            });
            n += 1;
        }
        emit(TPS.memory, |e| {
            e.ptr(gpu.handle).u64(s.memory.0).u64(s.memory.1);
        });
        emit(TPS.fabric, |e| {
            e.ptr(gpu.handle).u64(s.fabric.0).u64(s.fabric.1);
        });
        n += 2;
    }
    n
}

/// Handle to a running sampling daemon.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<u64>>,
}

impl Sampler {
    /// Start the daemon for `node`.
    pub fn start(node: Arc<Node>, config: SamplingConfig) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("thapi-sampler".into())
            .spawn(move || {
                // The sampler is its own "rank" stream; tag distinctly so
                // per-rank selection doesn't confuse it with rank 0 apps.
                let mut total = 0u64;
                while !stop2.load(Ordering::Acquire) {
                    total += sample_once(&node) as u64;
                    std::thread::sleep(config.interval);
                }
                total
            })
            .expect("spawn sampler");
        Sampler { stop, handle: Some(handle) }
    }

    /// Stop the daemon; returns the number of samples emitted.
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Release);
        self.handle.take().map(|h| h.join().unwrap_or(0)).unwrap_or(0)
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;
    use crate::tracer::{install_session, uninstall_session, SessionConfig};

    #[test]
    fn sample_once_emits_all_domains() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let node = Node::new(NodeConfig::test_small()); // 1 GPU, 2 tiles
        let n = sample_once(&node);
        // power: 3 domains, freq: 2, util: 4, memory+fabric: 2
        assert_eq!(n, 3 + 2 + 4 + 2);
        let session = uninstall_session().unwrap();
        assert_eq!(session.stats().written, n as u64);
    }

    #[test]
    fn daemon_samples_at_interval() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let node = Node::new(NodeConfig::test_small());
        let sampler = Sampler::start(node, SamplingConfig { interval: Duration::from_millis(5) });
        std::thread::sleep(Duration::from_millis(40));
        let emitted = sampler.stop();
        // ~8 rounds of 11 events; allow generous slack for CI jitter
        assert!(emitted >= 22, "expected >=2 rounds, got {emitted}");
        let session = uninstall_session().unwrap();
        assert!(session.stats().written >= emitted);
    }

    #[test]
    fn minimal_mode_still_records_samples_when_daemon_on() {
        // sampling classes are structurally enabled in every mode; whether
        // samples exist depends only on the daemon (TS-min vs T-min).
        let _g = test_support::lock();
        install_session(SessionConfig {
            mode: crate::tracer::TracingMode::Minimal,
            ..Default::default()
        });
        let node = Node::new(NodeConfig::test_small());
        let n = sample_once(&node);
        let session = uninstall_session().unwrap();
        assert_eq!(session.stats().written, n as u64);
    }
}
