//! Artifact manifest parsing (`artifacts/manifest.txt`).
//!
//! Line-based catalog emitted by `python/compile/aot.py`:
//!
//! ```text
//! kernel conv1d conv1d.hlo.txt
//! param f32 64x4096
//! param f32 33
//! param f32 64x4096
//! result f32 64x4096
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Element dtype of a tensor parameter/result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn size(&self) -> usize {
        4
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// Total byte size.
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }
}

/// One kernel entry: HLO file + signature.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    /// Kernel name (registry key; also the simulated `ze_kernel` name).
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: PathBuf,
    /// Parameters in order.
    pub params: Vec<TensorSpec>,
    /// Result tensor.
    pub result: TensorSpec,
}

/// Parsed manifest: kernel catalog.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Kernels by name.
    pub kernels: HashMap<String, KernelSpec>,
    /// The artifacts directory the manifest was read from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `manifest.txt` in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("no manifest.txt in {} (run `make artifacts`)", dir.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut kernels = HashMap::new();
        let mut current: Option<KernelSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let tag = it.next().unwrap();
            match tag {
                "kernel" => {
                    if let Some(k) = current.take() {
                        kernels.insert(k.name.clone(), k);
                    }
                    let name = it.next().context("kernel missing name")?;
                    let file = it.next().context("kernel missing file")?;
                    current = Some(KernelSpec {
                        name: name.into(),
                        file: PathBuf::from(file),
                        params: Vec::new(),
                        result: TensorSpec { dtype: DType::F32, dims: vec![] },
                    });
                }
                "param" | "result" => {
                    let k = current.as_mut().with_context(|| format!("line {lineno}: {tag} before kernel"))?;
                    let dtype = DType::parse(it.next().context("missing dtype")?)?;
                    let shape = it.next().context("missing shape")?;
                    let dims = if shape == "scalar" {
                        vec![]
                    } else {
                        shape
                            .split('x')
                            .map(|d| d.parse::<usize>().context("bad dim"))
                            .collect::<Result<Vec<_>>>()?
                    };
                    let spec = TensorSpec { dtype, dims };
                    if tag == "param" {
                        k.params.push(spec);
                    } else {
                        k.result = spec;
                    }
                }
                other => bail!("line {lineno}: unknown tag {other}"),
            }
        }
        if let Some(k) = current.take() {
            kernels.insert(k.name.clone(), k);
        }
        if kernels.is_empty() {
            bail!("manifest has no kernels");
        }
        Ok(Manifest { kernels, dir: dir.to_path_buf() })
    }

    /// Kernel lookup.
    pub fn kernel(&self, name: &str) -> Option<&KernelSpec> {
        self.kernels.get(name)
    }

    /// Sorted kernel names.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.kernels.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
kernel saxpy saxpy.hlo.txt
param f32 1
param f32 1048576
param f32 1048576
result f32 1048576
kernel xent xent.hlo.txt
param f32 256x2048
param i32 256
result f32 1
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(m.names(), vec!["saxpy", "xent"]);
        let s = m.kernel("saxpy").unwrap();
        assert_eq!(s.params.len(), 3);
        assert_eq!(s.params[1].elements(), 1 << 20);
        assert_eq!(s.params[1].bytes(), 4 << 20);
        let x = m.kernel("xent").unwrap();
        assert_eq!(x.params[0].dims, vec![256, 2048]);
        assert_eq!(x.params[1].dtype, DType::I32);
        assert_eq!(x.result.dims, vec![1]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("param f32 4", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("", Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["saxpy", "conv1d", "lrn", "stencil", "matmul", "xent"] {
                assert!(m.kernel(name).is_some(), "{name} missing from manifest");
            }
        }
    }
}
