//! PJRT runtime: loads AOT artifacts and executes them for the simulated
//! GPU compute engines.
//!
//! `python/compile/aot.py` lowers every L2 model to HLO **text** (the only
//! interchange format xla_extension 0.5.1 accepts from jax ≥ 0.5 — see
//! DESIGN.md) plus `manifest.txt` describing parameter/result shapes. This
//! module parses the manifest ([`manifest`]) and runs a dedicated executor
//! thread ([`executor`]) that owns the (non-`Send`) `PjRtClient`; engines
//! submit execution requests over a channel. Compilation is lazy per
//! kernel and its wall time is reported back — that is the *real* cost a
//! `zeModuleCreate` interception reports (the paper's §4.3 table shows
//! zeModuleCreate at 256 ms for exactly this reason).

pub mod executor;
pub mod manifest;

pub use executor::{ExecStats, Executor};
pub use manifest::{DType, KernelSpec, Manifest, TensorSpec};

use once_cell::sync::Lazy;
use std::path::PathBuf;
use std::sync::Arc;

/// Default artifacts directory: `$THAPI_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("THAPI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

static GLOBAL_EXECUTOR: Lazy<Arc<Executor>> = Lazy::new(|| {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap_or_else(|e| {
        panic!("cannot load artifacts from {}: {e:#}. Run `make artifacts`.", dir.display())
    });
    Executor::start(manifest)
});

/// The process-global PJRT executor (one compiled-executable cache shared
/// by every simulated node — like a driver-level kernel cache).
pub fn global_executor() -> Arc<Executor> {
    GLOBAL_EXECUTOR.clone()
}
