//! The PJRT executor thread.
//!
//! `xla::PjRtClient` holds an `Rc` internally and is not `Send`, so one
//! dedicated thread owns the client and all compiled executables; the
//! simulated GPU engines talk to it over an mpsc channel. This also
//! serializes kernel execution, which is a reasonable model of a single
//! physical accelerator.
//!
//! Requests:
//! * `Compile(name)` — lazily compile an artifact; returns the real
//!   compile wall-time (surfaced as `zeModuleCreate` / `cuModuleLoadData`
//!   duration by the frontends).
//! * `Execute(name, inputs)` — run with raw little-endian input buffers;
//!   returns the raw result buffer.

use super::manifest::{DType, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

enum Request {
    Compile { name: String, reply: mpsc::Sender<Result<Duration>> },
    Execute { name: String, inputs: Vec<Vec<u8>>, reply: mpsc::Sender<Result<Vec<u8>>> },
    Shutdown,
}

/// Cumulative executor statistics.
#[derive(Debug, Default)]
pub struct ExecStats {
    /// Kernels compiled.
    pub compiled: AtomicU64,
    /// Executions performed.
    pub executed: AtomicU64,
    /// Total execution nanoseconds (on the executor thread).
    pub exec_ns: AtomicU64,
}

/// Handle to the executor thread. Clone-able via `Arc`.
pub struct Executor {
    tx: Mutex<mpsc::Sender<Request>>,
    manifest: Manifest,
    /// Statistics.
    pub stats: Arc<ExecStats>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Executor {
    /// Start the executor for the artifacts in `manifest`.
    pub fn start(manifest: Manifest) -> Arc<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(ExecStats::default());
        let thread_manifest = manifest.clone();
        let thread_stats = stats.clone();
        let handle = std::thread::Builder::new()
            .name("thapi-pjrt".into())
            .spawn(move || executor_loop(rx, thread_manifest, thread_stats))
            .expect("spawn pjrt executor");
        Arc::new(Executor {
            tx: Mutex::new(tx),
            manifest,
            stats,
            handle: Mutex::new(Some(handle)),
        })
    }

    /// The manifest this executor serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or no-op if cached); returns the compile wall time.
    pub fn compile(&self, name: &str) -> Result<Duration> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Compile { name: name.into(), reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().context("executor died")?
    }

    /// Execute a kernel with raw LE input buffers; returns raw result bytes.
    pub fn execute(&self, name: &str, inputs: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute { name: name.into(), inputs, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().context("executor died")?
    }

    /// Stop the executor thread.
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn executor_loop(rx: mpsc::Receiver<Request>, manifest: Manifest, stats: Arc<ExecStats>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Answer every request with an error; don't crash the process.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Compile { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client failed: {e}")));
                    }
                    Request::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client failed: {e}")));
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Compile { name, reply } => {
                let t0 = Instant::now();
                let r = ensure_compiled(&client, &manifest, &mut exes, &name)
                    .map(|_| t0.elapsed());
                if r.is_ok() {
                    stats.compiled.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(r);
            }
            Request::Execute { name, inputs, reply } => {
                let t0 = Instant::now();
                let r = (|| -> Result<Vec<u8>> {
                    ensure_compiled(&client, &manifest, &mut exes, &name)?;
                    let exe = exes.get(&name).unwrap();
                    run(exe, &manifest, &name, inputs)
                })();
                stats.executed.fetch_add(1, Ordering::Relaxed);
                stats
                    .exec_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure_compiled(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    exes: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
) -> Result<()> {
    if exes.contains_key(name) {
        return Ok(());
    }
    let spec = manifest.kernel(name).with_context(|| format!("unknown kernel {name}"))?;
    let path = manifest.dir.join(&spec.file);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .map_err(|e| anyhow!("load {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e}"))?;
    exes.insert(name.to_string(), exe);
    Ok(())
}

fn literal_from_bytes(dtype: DType, dims: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    let ty = match dtype {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
        .map_err(|e| anyhow!("literal: {e}"))
}

fn run(
    exe: &xla::PjRtLoadedExecutable,
    manifest: &Manifest,
    name: &str,
    inputs: Vec<Vec<u8>>,
) -> Result<Vec<u8>> {
    let spec = manifest.kernel(name).unwrap();
    if inputs.len() != spec.params.len() {
        bail!(
            "{name}: expected {} inputs, got {}",
            spec.params.len(),
            inputs.len()
        );
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (i, (bytes, p)) in inputs.iter().zip(&spec.params).enumerate() {
        if bytes.len() != p.bytes() {
            bail!("{name}: input {i} is {} bytes, expected {}", bytes.len(), p.bytes());
        }
        literals.push(literal_from_bytes(p.dtype, &p.dims, bytes)?);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("execute {name}: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch {name}: {e}"))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e}"))?;
    let mut bytes = vec![0u8; spec.result.bytes()];
    match spec.result.dtype {
        DType::F32 => {
            let v = out.to_vec::<f32>().map_err(|e| anyhow!("tovec {name}: {e}"))?;
            for (chunk, val) in bytes.chunks_exact_mut(4).zip(&v) {
                chunk.copy_from_slice(&val.to_le_bytes());
            }
        }
        DType::I32 => {
            let v = out.to_vec::<i32>().map_err(|e| anyhow!("tovec {name}: {e}"))?;
            for (chunk, val) in bytes.chunks_exact_mut(4).zip(&v) {
                chunk.copy_from_slice(&val.to_le_bytes());
            }
        }
    }
    Ok(bytes)
}

/// Convert an f32 slice to LE bytes (helper for apps/tests).
pub fn f32_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; v.len() * 4];
    for (chunk, val) in out.chunks_exact_mut(4).zip(v) {
        chunk.copy_from_slice(&val.to_le_bytes());
    }
    out
}

/// Convert LE bytes back to f32 (helper for apps/tests).
pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

/// Convert an i32 slice to LE bytes.
pub fn i32_to_bytes(v: &[i32]) -> Vec<u8> {
    let mut out = vec![0u8; v.len() * 4];
    for (chunk, val) in out.chunks_exact_mut(4).zip(v) {
        chunk.copy_from_slice(&val.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_executor() -> Option<Arc<Executor>> {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(Executor::start(Manifest::load(&dir).unwrap()))
    }

    #[test]
    fn saxpy_executes_with_correct_numerics() {
        let Some(exec) = artifacts_executor() else { return };
        let n = 1 << 20;
        let a = f32_to_bytes(&[2.0]);
        let x = f32_to_bytes(&vec![3.0f32; n]);
        let y = f32_to_bytes(&vec![1.0f32; n]);
        let out = exec.execute("saxpy", vec![a, x, y]).unwrap();
        let vals = bytes_to_f32(&out);
        assert_eq!(vals.len(), n);
        assert!(vals.iter().all(|&v| (v - 7.0).abs() < 1e-6));
    }

    #[test]
    fn compile_is_cached_and_timed() {
        let Some(exec) = artifacts_executor() else { return };
        let d1 = exec.compile("lrn").unwrap();
        let d2 = exec.compile("lrn").unwrap();
        assert!(d1.as_micros() > 0);
        // cached second compile is much faster
        assert!(d2 < d1 || d2.as_millis() < 5);
        assert!(exec.stats.compiled.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn bad_kernel_name_errors() {
        let Some(exec) = artifacts_executor() else { return };
        assert!(exec.execute("nope", vec![]).is_err());
    }

    #[test]
    fn wrong_input_arity_errors() {
        let Some(exec) = artifacts_executor() else { return };
        assert!(exec.execute("saxpy", vec![]).is_err());
    }

    #[test]
    fn byte_conversions_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
        let b = i32_to_bytes(&[1, -7]);
        assert_eq!(b.len(), 8);
    }
}
