//! Invariant oracles judging a [`Scenario`] run.
//!
//! * [`check_conservation`] — the accounting law, per origin path:
//!   `merged + known_dropped == published`. Every event a leaf
//!   published is either merged at the root exactly once or booked in
//!   exactly one ledger (a leaf's resume gaps never leak into a
//!   sibling's ledger, the relay's, or nowhere). Cross-layer agreement
//!   is part of the law: the gap count the root holds against a leaf
//!   equals the count the relay booked, which equals the count the
//!   leaf's own publisher reports.
//! * [`check_determinism`] — same seed, same answer: two runs of one
//!   scenario must produce identical merged streams, identical
//!   normalized ledgers ([`LedgerSnapshot`] — timing-dependent
//!   counters like beacons and batch segmentation excluded), and
//!   identical per-leaf gap totals.
//! * [`post_mortem_golden`] — when a run lost nothing
//!   ([`total_known_loss`]` == 0`), its merged stream must be
//!   byte-identical to a local post-mortem merge of the same scripted
//!   events: the live chaos path may reorder nothing and invent
//!   nothing relative to the offline answer.

use crate::live::{LiveHub, LiveSource, OriginStats, SubOriginStats};
use std::sync::Arc;

use super::scenario::{class_name, reg_msg, AttachOutcome, Merged, RunReport, Scenario};

macro_rules! check {
    ($errs:expr, $cond:expr, $($arg:tt)+) => {
        if !$cond {
            $errs.push(format!($($arg)+));
        }
    };
}

/// An [`OriginStats`] with the timing-dependent counters (beacons,
/// batch segmentation) stripped — what two runs of one seed must agree
/// on exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LedgerSnapshot {
    pub label: String,
    pub channels: usize,
    pub received: u64,
    pub dropped: u64,
    pub remote_dropped: u64,
    pub resume_gaps: u64,
    pub eos: Option<(u64, u64)>,
    pub closed: bool,
    pub wire_version: u32,
    pub children: Vec<SubOriginStats>,
}

impl LedgerSnapshot {
    fn of(o: &OriginStats) -> LedgerSnapshot {
        LedgerSnapshot {
            label: o.label.clone(),
            channels: o.channels,
            received: o.received,
            dropped: o.dropped,
            remote_dropped: o.remote_dropped,
            resume_gaps: o.resume_gaps,
            eos: o.eos,
            closed: o.closed,
            wire_version: o.wire_version,
            children: o.children.clone(),
        }
    }
}

/// Best known loss across the whole run: every root-side origin ledger
/// plus every leaf publisher's own gap count (saturating). Zero means
/// the run was lossless end to end — and the golden oracle applies.
pub fn total_known_loss(rep: &RunReport) -> u64 {
    let ledgers = rep
        .attaches
        .iter()
        .flat_map(|a| a.origins.iter())
        .fold(0u64, |acc, o| acc.saturating_add(o.known_dropped()));
    let leaves = rep.leaf_stats.iter().fold(0u64, |acc, s| acc.saturating_add(s.gaps));
    ledgers.saturating_add(leaves)
}

/// The conservation oracle. Returns every violated clause, or `Ok` if
/// the run's accounting is exact.
pub fn check_conservation(sc: &Scenario, rep: &RunReport) -> Result<(), String> {
    let mut errs: Vec<String> = Vec::new();

    check!(
        errs,
        rep.leaf_stats.len() == sc.leaves.len(),
        "leaf stats count {} != leaves {}",
        rep.leaf_stats.len(),
        sc.leaves.len()
    );
    check!(
        errs,
        rep.relay_reports.len() == sc.relays.len(),
        "relay reports count {} != relays {}",
        rep.relay_reports.len(),
        sc.relays.len()
    );
    check!(
        errs,
        rep.attaches.len() == sc.root_attaches,
        "attach count {} != root_attaches {}",
        rep.attaches.len(),
        sc.root_attaches
    );
    if !errs.is_empty() {
        return Err(errs.join("\n"));
    }

    for (ai, attach) in rep.attaches.iter().enumerate() {
        check_attach(sc, rep, ai, attach, &mut errs);
    }

    // every concurrent subscriber of one broadcast session sees the
    // same merged stream — a same-run invariant, not just determinism
    for (ai, attach) in rep.attaches.iter().enumerate().skip(1) {
        if attach.merged != rep.attaches[0].merged {
            let at = first_divergence(&rep.attaches[0].merged, &attach.merged);
            errs.push(format!("attach {ai} merged diverges from attach 0 at index {at}"));
        }
    }

    // the relay's own books agree with the leaves below it
    for (k, rel) in rep.relay_reports.iter().enumerate() {
        let spec = &sc.relays[k];
        check!(errs, rel.label == spec.label, "relay {k} label {:?} != {:?}", rel.label, spec.label);
        check!(
            errs,
            rel.downstream.failed() == 0,
            "relay {k} downstream failures: {:?}",
            rel.downstream
        );
        let hosts: Vec<String> =
            spec.leaves.iter().map(|&i| sc.leaves[i].hostname.clone()).collect();
        check!(errs, rel.hostnames == hosts, "relay {k} hostnames {:?} != {hosts:?}", rel.hostnames);
        check!(
            errs,
            rel.origins.len() == spec.leaves.len(),
            "relay {k} has {} downstream origins, expected {}",
            rel.origins.len(),
            spec.leaves.len()
        );
        let mut part_total = 0u64;
        let mut part_gaps = 0u64;
        for (j, (&li, o)) in spec.leaves.iter().zip(rel.origins.iter()).enumerate() {
            let total = sc.leaf_total(li);
            let gaps = rep.leaf_stats[li].gaps;
            part_total += total;
            part_gaps += gaps;
            check!(
                errs,
                o.label == sc.leaves[li].hostname,
                "relay {k} origin {j} label {:?} != leaf {li} host {:?}",
                o.label,
                sc.leaves[li].hostname
            );
            check!(
                errs,
                o.resume_gaps == gaps,
                "relay {k} origin {j}: booked {} gap(s), leaf {li} publisher reports {}",
                o.resume_gaps,
                gaps
            );
            check!(
                errs,
                o.eos == Some((total, 0)),
                "relay {k} origin {j} eos {:?} != Some(({total}, 0))",
                o.eos
            );
            check!(
                errs,
                o.received.saturating_add(o.known_dropped()) == total,
                "relay {k} origin {j}: received {} + known_dropped {} != published {total}",
                o.received,
                o.known_dropped()
            );
        }
        check!(
            errs,
            rel.known_dropped() == part_gaps,
            "relay {k} known_dropped() {} != sum of its leaves' gaps {part_gaps}",
            rel.known_dropped()
        );
        check!(
            errs,
            rel.local.dropped == 0,
            "relay {k} hub dropped locally ({}): the fan-in feed must be lossless",
            rel.local.dropped
        );
        check!(
            errs,
            rel.local.received.saturating_add(part_gaps) == part_total,
            "relay {k} hub received {} + gaps {part_gaps} != published {part_total}",
            rel.local.received
        );
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("\n"))
    }
}

/// Conservation clauses for one root attach.
fn check_attach(
    sc: &Scenario,
    rep: &RunReport,
    ai: usize,
    attach: &AttachOutcome,
    errs: &mut Vec<String>,
) {
    check!(
        errs,
        attach.stats.failed() == 0,
        "attach {ai}: {} connection(s) died unaccounted: {:?}",
        attach.stats.failed(),
        attach.stats
    );
    let expect_origins = sc.relays.len() + sc.direct.len();
    check!(
        errs,
        attach.origins.len() == expect_origins,
        "attach {ai}: {} origins, expected {expect_origins}",
        attach.origins.len()
    );
    if attach.origins.len() != expect_origins {
        return;
    }

    let mut merged_expect = 0u64; // sum of per-origin received
    for (k, (spec, o)) in sc.relays.iter().zip(attach.origins.iter()).enumerate() {
        let part_total: u64 = spec.leaves.iter().map(|&i| sc.leaf_total(i)).sum();
        merged_expect = merged_expect.saturating_add(o.received);
        check!(errs, o.label == spec.label, "attach {ai} origin {k} label {:?} != relay {:?}", o.label, spec.label);
        check!(errs, o.closed, "attach {ai} relay origin {k} never closed");
        check!(
            errs,
            o.received.saturating_add(o.known_dropped()) == part_total,
            "attach {ai} relay origin {k}: received {} + known_dropped {} != published {part_total}",
            o.received,
            o.known_dropped()
        );
        // per-leaf clauses are only exact when the root↔relay hop
        // itself lost nothing — otherwise that hop's loss cannot be
        // attributed to single leaves and only the sum above holds
        if o.resume_gaps == 0 && o.remote_dropped == 0 {
            check!(
                errs,
                o.children.len() == spec.leaves.len(),
                "attach {ai} relay origin {k}: {} child ledgers, expected {}",
                o.children.len(),
                spec.leaves.len()
            );
            for (j, (&li, c)) in spec.leaves.iter().zip(o.children.iter()).enumerate() {
                let total = sc.leaf_total(li);
                let gaps = rep.leaf_stats[li].gaps;
                let want_path = format!("{j}:{}", sc.leaves[li].hostname);
                check!(
                    errs,
                    c.path == want_path,
                    "attach {ai} origin {k} child {j} path {:?} != {want_path:?}",
                    c.path
                );
                check!(
                    errs,
                    c.hostname == sc.leaves[li].hostname,
                    "attach {ai} origin {k} child {j} hostname {:?} != {:?}",
                    c.hostname,
                    sc.leaves[li].hostname
                );
                check!(
                    errs,
                    c.resume_gaps == gaps,
                    "attach {ai} origin {k} child {j}: root books {} gap(s), leaf {li} reports {}",
                    c.resume_gaps,
                    gaps
                );
                check!(
                    errs,
                    c.received.saturating_add(c.known_dropped()) == total,
                    "attach {ai} origin {k} child {j}: received {} + known_dropped {} != published {total}",
                    c.received,
                    c.known_dropped()
                );
                if gaps == 0 {
                    check!(
                        errs,
                        c.eos == Some((total, 0)),
                        "attach {ai} origin {k} child {j} eos {:?} != Some(({total}, 0))",
                        c.eos
                    );
                }
            }
        }
    }
    for (d, (&li, o)) in
        sc.direct.iter().zip(attach.origins.iter().skip(sc.relays.len())).enumerate()
    {
        let total = sc.leaf_total(li);
        let gaps = rep.leaf_stats[li].gaps;
        merged_expect = merged_expect.saturating_add(o.received);
        check!(
            errs,
            o.label == sc.leaves[li].hostname,
            "attach {ai} direct origin {d} label {:?} != leaf {li} host {:?}",
            o.label,
            sc.leaves[li].hostname
        );
        check!(errs, o.closed, "attach {ai} direct origin {d} never closed");
        check!(errs, o.children.is_empty(), "attach {ai} direct origin {d} grew child ledgers");
        check!(
            errs,
            o.eos == Some((total, 0)),
            "attach {ai} direct origin {d} eos {:?} != Some(({total}, 0))",
            o.eos
        );
        check!(
            errs,
            o.resume_gaps == gaps,
            "attach {ai} direct origin {d}: root books {} gap(s), leaf {li} reports {}",
            o.resume_gaps,
            gaps
        );
        check!(
            errs,
            o.received.saturating_add(o.known_dropped()) == total,
            "attach {ai} direct origin {d}: received {} + known_dropped {} != published {total}",
            o.received,
            o.known_dropped()
        );
    }

    // the global law: everything published is merged once or booked once
    check!(
        errs,
        attach.merged.len() as u64 == merged_expect,
        "attach {ai}: merged {} events, origin ledgers say {merged_expect}",
        attach.merged.len()
    );
    let known: u64 =
        attach.origins.iter().fold(0u64, |a, o| a.saturating_add(o.known_dropped()));
    check!(
        errs,
        (attach.merged.len() as u64).saturating_add(known) == sc.total_events(),
        "attach {ai}: merged {} + known_dropped {known} != published {}",
        attach.merged.len(),
        sc.total_events()
    );
    check!(
        errs,
        attach.merged.windows(2).all(|w| w[0].0 <= w[1].0),
        "attach {ai}: merged stream is not time-ordered"
    );
}

/// The determinism oracle: two runs of the same scenario must agree on
/// everything the scenario scripts.
pub fn check_determinism(r1: &RunReport, r2: &RunReport) -> Result<(), String> {
    let mut errs: Vec<String> = Vec::new();
    check!(
        errs,
        r1.attaches.len() == r2.attaches.len(),
        "attach counts differ: {} vs {}",
        r1.attaches.len(),
        r2.attaches.len()
    );
    for (ai, (a, b)) in r1.attaches.iter().zip(r2.attaches.iter()).enumerate() {
        if a.merged != b.merged {
            let at = first_divergence(&a.merged, &b.merged);
            errs.push(format!(
                "attach {ai}: merged streams diverge at index {at} ({} vs {} events): {:?} vs {:?}",
                a.merged.len(),
                b.merged.len(),
                a.merged.get(at),
                b.merged.get(at)
            ));
        }
        let s1: Vec<LedgerSnapshot> = a.origins.iter().map(LedgerSnapshot::of).collect();
        let s2: Vec<LedgerSnapshot> = b.origins.iter().map(LedgerSnapshot::of).collect();
        check!(
            errs,
            s1 == s2,
            "attach {ai}: origin ledgers differ between reruns:\n  {s1:?}\nvs\n  {s2:?}"
        );
    }
    let g1: Vec<u64> = r1.leaf_stats.iter().map(|s| s.gaps).collect();
    let g2: Vec<u64> = r2.leaf_stats.iter().map(|s| s.gaps).collect();
    check!(errs, g1 == g2, "per-leaf gap totals differ between reruns: {g1:?} vs {g2:?}");

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("\n"))
    }
}

fn first_divergence(a: &[Merged], b: &[Merged]) -> usize {
    let n = a.len().min(b.len());
    (0..n).find(|&i| a[i] != b[i]).unwrap_or(n)
}

/// The answer a local post-mortem merge of the scenario's scripted
/// events gives: one hub, one origin per leaf in attach connection
/// order (relay partitions first, then direct leaves — so channel
/// order, and with it the cross-stream tie-break, matches the live
/// run), every event fed losslessly, drained through [`LiveSource`].
pub fn post_mortem_golden(sc: &Scenario) -> Vec<Merged> {
    let depth = 1 << 16; // soft cap far above any scenario's event count
    let hub = LiveHub::new("root", depth, false);
    let order: Vec<usize> = sc
        .relays
        .iter()
        .flat_map(|r| r.leaves.iter().copied())
        .chain(sc.direct.iter().copied())
        .collect();
    for &li in &order {
        let leaf = &sc.leaves[li];
        let origin = hub.register_origin(&leaf.hostname);
        hub.ensure_origin_channels(origin, leaf.streams.len());
        let map = hub.origin_map(origin);
        for (si, evs) in leaf.streams.iter().enumerate() {
            for (j, e) in evs.iter().enumerate() {
                let mut msg = reg_msg(&hub, class_name(j), e.ts, e.rank, e.tid);
                // a remote merge stamps the publisher's hostname
                msg.hostname = Arc::from(leaf.hostname.as_str());
                hub.feed_remote(map[si], msg, depth);
            }
        }
        hub.close_origin(origin);
    }
    hub.close_all();
    LiveSource::new(hub)
        .map(|m| (m.ts, m.rank, m.tid, m.hostname.to_string(), m.class.name.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::scenario::{EventSpec, LeafSpec, Scenario};
    use super::*;

    fn leaf(host: &str, streams: Vec<Vec<(u64, u32, u32)>>) -> LeafSpec {
        LeafSpec {
            hostname: host.to_string(),
            epoch: 1,
            wire: 3,
            resume_buffer: 1 << 20,
            streams: streams
                .into_iter()
                .map(|s| {
                    s.into_iter().map(|(ts, rank, tid)| EventSpec { ts, rank, tid }).collect()
                })
                .collect(),
            serve_faults: Vec::new(),
            redial_refusals: Vec::new(),
        }
    }

    /// The golden merges by (ts, channel order) with leaf hostnames
    /// stamped — pinned against a hand-computed answer, including a
    /// cross-stream tie broken by channel (= connection) order.
    #[test]
    fn golden_merges_by_time_then_channel_order() {
        let sc = Scenario {
            seed: 0,
            leaves: vec![
                leaf("b-first-by-ts", vec![vec![(12, 0, 1), (20, 0, 1)]]),
                // ts 12 ties with leaf 0: leaf 0's channel was
                // registered first, so its event merges first
                leaf("a-second-by-channel", vec![vec![(11, 1, 1), (12, 1, 1)]]),
            ],
            relays: Vec::new(),
            direct: vec![0, 1],
            root_attaches: 1,
            depth: 64,
        };
        let got: Vec<(u64, u32, String)> =
            post_mortem_golden(&sc).into_iter().map(|(ts, rank, _, h, _)| (ts, rank, h)).collect();
        assert_eq!(
            got,
            vec![
                (11, 1, "a-second-by-channel".to_string()),
                (12, 0, "b-first-by-ts".to_string()),
                (12, 1, "a-second-by-channel".to_string()),
                (20, 0, "b-first-by-ts".to_string()),
            ]
        );
    }

    /// Snapshots strip exactly the timing-dependent counters: two
    /// origin stats differing only in beacons/batches snapshot equal.
    #[test]
    fn ledger_snapshot_ignores_timing_counters() {
        let hub = LiveHub::new("root", 64, false);
        let o = hub.register_origin("n");
        hub.ensure_origin_channels(o, 1);
        let a = hub.origin_stats().remove(0);
        let mut b = a.clone();
        b.beacons += 7;
        b.batches += 3;
        assert_ne!(a, b);
        assert_eq!(LedgerSnapshot::of(&a), LedgerSnapshot::of(&b));
    }
}
