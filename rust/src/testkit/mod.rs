//! Deterministic chaos testkit for the THRL stack.
//!
//! The remote layer's correctness claims — resume gaps are booked
//! exactly once, per-leaf ledgers never alias, a tree merges like the
//! flat attach — are easy to pin for one hand-written topology and
//! hard to trust in general. This module makes the general case
//! testable: **one `u64` seed expands into a full scenario** (leaf
//! publishers with scripted event streams, optional relays, a root
//! attach, and a composed fault schedule), the scenario runs the *real*
//! [`crate::remote::Publisher`] / `Broadcaster` / [`crate::remote::FanIn`]
//! / [`crate::coordinator::run_relay`] code over an in-process
//! fault-injecting transport, and two oracles judge the result:
//!
//! * **Conservation** ([`check_conservation`]) — for every origin path,
//!   `merged + known_dropped == published`, with the parent/child
//!   ledgers disjoint; loss is *accounted*, never silent.
//! * **Determinism** ([`check_determinism`]) — the same seed produces
//!   the same merged stream and the same ledgers on every rerun, so a
//!   failing seed printed by the sweep is a one-command repro. When a
//!   run lost nothing, the merged stream must additionally be
//!   byte-identical to the [`post_mortem_golden`] — the answer a local
//!   post-mortem analysis of the same events would give.
//!
//! Determinism is engineered, not hoped for: leaf hubs are sealed
//! before serving (one deterministic drain, so the wire bytes are a
//! pure function of the scenario), every fault in [`FaultSpec`]
//! triggers on byte positions rather than timers, and the generator
//! only emits topologies whose merge order is timing-independent
//! (unique global timestamps whenever relays are present; cross-stream
//! timestamp ties only in flat no-relay scenarios where channel order
//! is fixed at handshake time).
//!
//! Driven by `rust/tests/chaos.rs`; knobs: `THAPI_CHAOS_SEEDS` (comma
//! list, exact repro) and `THAPI_CHAOS_QUICK` (CI-sized sweep).

mod chaos;
mod oracle;
mod scenario;

pub use chaos::{
    chaos_listener, pipe_pair, refusing_connector, ChaosConn, ChaosEndpoint, ChaosListener,
    FaultSpec, PipeEnd,
};
pub use oracle::{
    check_conservation, check_determinism, post_mortem_golden, total_known_loss, LedgerSnapshot,
};
pub use scenario::{
    class_name, event_len, hello_wire_len, policy, AttachOutcome, EventSpec, LeafSpec, Merged,
    RelaySpec, RunReport, Scenario, RELAY_RING,
};
