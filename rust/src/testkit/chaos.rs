//! The fault-injecting transport: an in-process pipe that looks like a
//! socket, plus [`ChaosConn`] — a `Read + Write` wrapper that injects
//! *byte-deterministic* faults into the write path.
//!
//! Every fault here is a pure function of the byte stream, never of
//! wall-clock time or write-call chunking:
//!
//! * **kill-at-byte** — allow exactly N bytes through (the boundary
//!   write is partial), then fail with `BrokenPipe`. Mirrors
//!   [`crate::remote::KillAfter`], for non-socket transports.
//! * **kill-at-frame-kind** — scan the THRL stream (8-byte preamble,
//!   then `len:u32 LE` + type-byte headers) and cut immediately after
//!   the header of the Nth frame of a given kind, before its body.
//!   The cut position depends only on the bytes written so far, so a
//!   throttled, delayed or short-write-split stream cuts at the same
//!   event as a single `write_all`.
//! * **throttle** — cap every write call at N bytes, forcing the
//!   publisher's short-write resume paths to run constantly.
//! * **delay** — sleep a few microseconds every N bytes (slows the
//!   stream without changing it).
//! * **stall** — one long sleep once N bytes have passed (a frozen
//!   peer that comes back).
//!
//! The pipe itself ([`pipe_pair`], [`chaos_listener`]) gives scenario
//! code loopback-socket semantics without ports: blocking reads, EOF
//! after the writer drops, `BrokenPipe` after the reader drops, and a
//! dialable endpoint that starts refusing once its listener is gone —
//! which is what lets [`refusing_connector`] script
//! connection-refused-K-times redial schedules.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------------------
// In-process duplex pipe
// ---------------------------------------------------------------------------

/// One direction of the pipe: a byte queue plus both ends' liveness.
#[derive(Default)]
struct Flow {
    buf: VecDeque<u8>,
    /// The writing end dropped: readers drain the queue, then see EOF.
    write_closed: bool,
    /// The reading end dropped: writers fail with `BrokenPipe`.
    read_closed: bool,
}

#[derive(Default)]
struct Channel {
    flow: Mutex<Flow>,
    ready: Condvar,
}

/// One end of an in-process duplex pipe (socket stand-in). Reads block
/// until data arrives or the peer's write side closes (then EOF);
/// writes fail with `BrokenPipe` once the peer has dropped.
pub struct PipeEnd {
    rx: Arc<Channel>,
    tx: Arc<Channel>,
}

/// Build a connected pair of pipe ends — what one accepted connection
/// looks like to both sides.
pub fn pipe_pair() -> (PipeEnd, PipeEnd) {
    let a = Arc::new(Channel::default());
    let b = Arc::new(Channel::default());
    (PipeEnd { rx: a.clone(), tx: b.clone() }, PipeEnd { rx: b, tx: a })
}

impl Read for PipeEnd {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut flow = self.rx.flow.lock().unwrap();
        loop {
            if !flow.buf.is_empty() {
                let n = buf.len().min(flow.buf.len());
                for slot in buf.iter_mut().take(n) {
                    *slot = flow.buf.pop_front().unwrap();
                }
                return Ok(n);
            }
            if flow.write_closed {
                return Ok(0);
            }
            flow = self.rx.ready.wait(flow).unwrap();
        }
    }
}

impl Write for PipeEnd {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut flow = self.tx.flow.lock().unwrap();
        if flow.read_closed {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "chaos pipe: peer closed"));
        }
        flow.buf.extend(buf.iter().copied());
        drop(flow);
        self.tx.ready.notify_all();
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeEnd {
    fn drop(&mut self) {
        // our outgoing direction ends (the peer drains, then sees EOF)…
        self.tx.flow.lock().unwrap().write_closed = true;
        self.tx.ready.notify_all();
        // …and nothing will drain the incoming direction again
        self.rx.flow.lock().unwrap().read_closed = true;
        self.rx.ready.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Listener / endpoint: dialable in-process "addresses"
// ---------------------------------------------------------------------------

#[derive(Default)]
struct AcceptState {
    pending: VecDeque<PipeEnd>,
    closed: bool,
}

#[derive(Default)]
struct AcceptQueue {
    q: Mutex<AcceptState>,
    ready: Condvar,
}

/// The accepting side of an in-process listening "address".
pub struct ChaosListener {
    shared: Arc<AcceptQueue>,
}

/// The dialing side: clone freely and hand to connectors. Dials refuse
/// with `ConnectionRefused` once the listener has dropped.
#[derive(Clone)]
pub struct ChaosEndpoint {
    shared: Arc<AcceptQueue>,
}

/// Bind an in-process listener; returns the accept side and a dialable
/// endpoint (the "address").
pub fn chaos_listener() -> (ChaosListener, ChaosEndpoint) {
    let shared = Arc::new(AcceptQueue::default());
    (ChaosListener { shared: shared.clone() }, ChaosEndpoint { shared })
}

impl ChaosListener {
    /// Block until a connection arrives (or the listener is closed —
    /// which only this end's drop does, so in-scenario this blocks).
    pub fn accept(&self) -> io::Result<PipeEnd> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(conn) = st.pending.pop_front() {
                return Ok(conn);
            }
            if st.closed {
                return Err(io::Error::new(
                    io::ErrorKind::ConnectionAborted,
                    "chaos listener closed",
                ));
            }
            st = self.shared.ready.wait(st).unwrap();
        }
    }

    /// Non-blocking accept, for poll loops like the relay's.
    pub fn try_accept(&self) -> Option<PipeEnd> {
        self.shared.q.lock().unwrap().pending.pop_front()
    }
}

impl Drop for ChaosListener {
    fn drop(&mut self) {
        self.shared.q.lock().unwrap().closed = true;
        self.shared.ready.notify_all();
    }
}

impl ChaosEndpoint {
    /// Dial: hand the listener one end of a fresh pipe, keep the other.
    pub fn dial(&self) -> io::Result<PipeEnd> {
        let (client, server) = pipe_pair();
        let mut st = self.shared.q.lock().unwrap();
        if st.closed {
            return Err(io::Error::new(
                io::ErrorKind::ConnectionRefused,
                "chaos endpoint: listener closed",
            ));
        }
        st.pending.push_back(server);
        drop(st);
        self.shared.ready.notify_all();
        Ok(client)
    }
}

/// A connector closure for [`crate::remote::FanIn::open_resumable`] /
/// `run_relay` that refuses `refusals[k]` times before letting the
/// `k`-th successful dial through — a scripted flaky network between
/// kills. Keep every quota below the `ReconnectPolicy` attempt budget
/// or the dialer legitimately gives up.
pub fn refusing_connector(
    ep: ChaosEndpoint,
    refusals: Vec<u32>,
) -> impl FnMut() -> io::Result<PipeEnd> + Send + 'static {
    let mut dialed = 0usize; // successful dials so far
    let mut refused = 0u32; // refusals burned toward the current dial
    move || {
        let quota = refusals.get(dialed).copied().unwrap_or(0);
        if refused < quota {
            refused += 1;
            return Err(io::Error::new(
                io::ErrorKind::ConnectionRefused,
                "chaos: refused by schedule",
            ));
        }
        refused = 0;
        dialed += 1;
        ep.dial()
    }
}

// ---------------------------------------------------------------------------
// Fault specification
// ---------------------------------------------------------------------------

/// One connection's fault schedule. `Default` is a clean connection;
/// at most one of the two kill triggers should be set (if both are,
/// whichever byte position comes first wins).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Kill the connection after exactly this many written bytes (the
    /// boundary write is partial, the next write fails `BrokenPipe`).
    pub kill_at_byte: Option<usize>,
    /// Kill right after the 5-byte header of the `n`-th frame of this
    /// THRL kind completes `(kind, n)` — the body never goes out.
    pub kill_at_frame: Option<(u8, u32)>,
    /// Cap every write call at this many bytes (short-write storm).
    pub throttle: Option<usize>,
    /// Sleep `µs` after every `every` written bytes `(every, µs)`.
    pub delay: Option<(usize, u64)>,
    /// One long sleep of `ms` once `after` bytes have passed
    /// `(after, ms)` — a peer that freezes, then recovers.
    pub stall: Option<(usize, u64)>,
}

impl FaultSpec {
    /// Does this schedule ever sever the connection?
    pub fn is_lethal(&self) -> bool {
        self.kill_at_byte.is_some() || self.kill_at_frame.is_some()
    }
}

/// Incremental THRL stream scanner: consumes the 8-byte preamble, then
/// alternating 5-byte frame headers (`len:u32 LE` counting the type
/// byte, plus the type byte itself) and `len - 1`-byte bodies. Fires
/// once the target kind's `nth` header completes.
#[derive(Clone)]
struct FrameScan {
    kind: u8,
    nth: u32,
    seen: u32,
    preamble_left: usize,
    header: [u8; 5],
    have: usize,
    body_left: usize,
    triggered: bool,
}

impl FrameScan {
    fn new(kind: u8, nth: u32) -> FrameScan {
        FrameScan {
            kind,
            nth: nth.max(1),
            seen: 0,
            preamble_left: 8,
            header: [0; 5],
            have: 0,
            body_left: 0,
            triggered: false,
        }
    }

    /// Scan the next chunk the connection wants to write. Returns how
    /// many of its bytes may pass: `bytes.len()` when the trigger does
    /// not fire inside this chunk, the cut offset when it does (and 0
    /// forever after).
    fn admit(&mut self, bytes: &[u8]) -> usize {
        if self.triggered {
            return 0;
        }
        let mut i = 0;
        while i < bytes.len() {
            if self.preamble_left > 0 {
                let take = self.preamble_left.min(bytes.len() - i);
                self.preamble_left -= take;
                i += take;
                continue;
            }
            if self.body_left > 0 {
                let take = self.body_left.min(bytes.len() - i);
                self.body_left -= take;
                i += take;
                continue;
            }
            self.header[self.have] = bytes[i];
            self.have += 1;
            i += 1;
            if self.have == 5 {
                let len = u32::from_le_bytes([
                    self.header[0],
                    self.header[1],
                    self.header[2],
                    self.header[3],
                ]) as usize;
                let kind = self.header[4];
                self.have = 0;
                self.body_left = len.saturating_sub(1);
                if kind == self.kind {
                    self.seen += 1;
                    if self.seen == self.nth {
                        self.triggered = true;
                        return i;
                    }
                }
            }
        }
        bytes.len()
    }
}

/// A `Read + Write` wrapper executing a [`FaultSpec`] on the write
/// path (reads pass through untouched). All triggers are functions of
/// the cumulative written byte count, so the fault lands on the same
/// wire byte no matter how the caller chunks its writes.
pub struct ChaosConn<S> {
    inner: S,
    written: usize,
    budget: usize,
    scan: Option<FrameScan>,
    throttle: usize,
    delay_every: usize,
    delay: Duration,
    since_delay: usize,
    stall_at: usize,
    stall: Duration,
    stalled: bool,
}

impl<S> ChaosConn<S> {
    /// Wrap `inner` under `fault`. A default (empty) spec passes every
    /// byte through unchanged.
    pub fn new(inner: S, fault: &FaultSpec) -> ChaosConn<S> {
        let (delay_every, delay_us) = fault.delay.unwrap_or((0, 0));
        let (stall_at, stall_ms) = fault.stall.unwrap_or((usize::MAX, 0));
        ChaosConn {
            inner,
            written: 0,
            budget: fault.kill_at_byte.unwrap_or(usize::MAX),
            scan: fault.kill_at_frame.map(|(kind, nth)| FrameScan::new(kind, nth)),
            throttle: match fault.throttle {
                Some(0) | None => usize::MAX,
                Some(n) => n,
            },
            delay_every,
            delay: Duration::from_micros(delay_us),
            since_delay: 0,
            stall_at,
            stall: Duration::from_millis(stall_ms),
            stalled: false,
        }
    }
}

impl<S: Read> Read for ChaosConn<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.inner.read(buf)
    }
}

impl<S: Write> Write for ChaosConn<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        if !self.stalled && self.written >= self.stall_at {
            self.stalled = true;
            std::thread::sleep(self.stall);
        }
        if self.budget == 0 {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "chaos: killed at byte budget",
            ));
        }
        let mut n = buf.len().min(self.throttle).min(self.budget);
        if let Some(scan) = &mut self.scan {
            // peek with a clone: the real scanner only advances over
            // bytes the inner write actually accepts, so a short write
            // cannot desynchronize the cut position
            let admitted = scan.clone().admit(&buf[..n]);
            if admitted == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "chaos: killed at frame kind",
                ));
            }
            n = admitted;
        }
        let m = self.inner.write(&buf[..n])?;
        if let Some(scan) = &mut self.scan {
            scan.admit(&buf[..m]);
        }
        self.written += m;
        self.budget -= m.min(self.budget);
        if self.delay_every > 0 {
            self.since_delay += m;
            if self.since_delay >= self.delay_every {
                self.since_delay = 0;
                std::thread::sleep(self.delay);
            }
        }
        Ok(m)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::remote::frame::{T_BEACON, T_EVENT};
    use crate::remote::{encode, write_preamble, Frame};

    #[test]
    fn pipe_delivers_then_eofs_after_writer_drop() {
        let (mut a, mut b) = pipe_pair();
        a.write_all(b"hello").unwrap();
        drop(a);
        let mut out = Vec::new();
        b.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"hello");
        assert_eq!(b.read(&mut [0u8; 4]).unwrap(), 0, "EOF after drain");
    }

    #[test]
    fn pipe_write_breaks_after_reader_drop() {
        let (mut a, b) = pipe_pair();
        drop(b);
        let err = a.write(b"x").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn endpoint_refuses_after_listener_drop() {
        let (listener, ep) = chaos_listener();
        assert!(ep.dial().is_ok());
        assert!(listener.try_accept().is_some());
        drop(listener);
        assert_eq!(ep.dial().unwrap_err().kind(), io::ErrorKind::ConnectionRefused);
    }

    #[test]
    fn refusing_connector_burns_quota_then_dials() {
        let (listener, ep) = chaos_listener();
        let mut connect = refusing_connector(ep, vec![2, 0, 1]);
        assert_eq!(connect().unwrap_err().kind(), io::ErrorKind::ConnectionRefused);
        assert_eq!(connect().unwrap_err().kind(), io::ErrorKind::ConnectionRefused);
        assert!(connect().is_ok(), "dial 0 after 2 refusals");
        assert!(connect().is_ok(), "dial 1 straight through");
        assert_eq!(connect().unwrap_err().kind(), io::ErrorKind::ConnectionRefused);
        assert!(connect().is_ok(), "dial 2 after 1 refusal");
        assert!(connect().is_ok(), "past the schedule: clean dials");
        drop(listener);
    }

    #[test]
    fn kill_at_byte_allows_exactly_the_budget() {
        let (a, mut b) = pipe_pair();
        let fault = FaultSpec { kill_at_byte: Some(7), ..Default::default() };
        let mut conn = ChaosConn::new(a, &fault);
        assert_eq!(conn.write(b"0123456789").unwrap(), 7, "boundary write is partial");
        let err = conn.write(b"89").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        drop(conn);
        let mut out = Vec::new();
        b.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"0123456", "exactly the budget went through");
    }

    #[test]
    fn throttle_caps_every_write_call() {
        let (a, mut b) = pipe_pair();
        let fault = FaultSpec { throttle: Some(3), ..Default::default() };
        let mut conn = ChaosConn::new(a, &fault);
        assert_eq!(conn.write(b"abcdefgh").unwrap(), 3);
        conn.write_all(b"abcdefgh").unwrap();
        drop(conn);
        let mut out = Vec::new();
        b.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"abcabcdefgh");
    }

    /// The frame-kind cut position is chunking-independent: writing the
    /// stream byte-at-a-time under throttle cuts at the same offset as
    /// one big write.
    #[test]
    fn frame_kind_cut_is_chunking_independent() {
        // preamble + Streams + Beacon + Beacon: target Beacon #2
        let mut wire = Vec::new();
        write_preamble(&mut wire);
        encode(&Frame::Streams { count: 3 }, &mut wire);
        let beacon_start_2 = {
            encode(&Frame::Beacon { stream: 0, watermark: 1 }, &mut wire);
            wire.len()
        };
        encode(&Frame::Beacon { stream: 1, watermark: 2 }, &mut wire);
        let expect_cut = beacon_start_2 + 5; // 4 len bytes + the type byte

        for throttle in [None, Some(1), Some(3)] {
            let (a, mut b) = pipe_pair();
            let fault = FaultSpec {
                kill_at_frame: Some((T_BEACON, 2)),
                throttle,
                ..Default::default()
            };
            let mut conn = ChaosConn::new(a, &fault);
            let mut sent = 0usize;
            let err = loop {
                match conn.write(&wire[sent..]) {
                    Ok(n) => sent += n,
                    Err(e) => break e,
                }
            };
            assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
            assert_eq!(sent, expect_cut, "throttle {throttle:?} moved the cut");
            drop(conn);
            let mut out = Vec::new();
            b.read_to_end(&mut out).unwrap();
            assert_eq!(out, wire[..expect_cut], "bytes through == bytes before the cut");
        }
    }

    #[test]
    fn frame_kind_scan_ignores_other_kinds_and_bodies() {
        // an Event body containing the Beacon type byte must not count
        let mut wire = Vec::new();
        write_preamble(&mut wire);
        encode(&Frame::Streams { count: T_BEACON as u32 }, &mut wire);
        let clean_len = wire.len();
        encode(&Frame::Beacon { stream: T_BEACON as u32, watermark: u64::MAX }, &mut wire);

        let mut scan = FrameScan::new(T_EVENT, 1);
        assert_eq!(scan.admit(&wire), wire.len(), "no Event frame: never triggers");

        let mut scan = FrameScan::new(T_BEACON, 1);
        assert_eq!(scan.admit(&wire), clean_len + 5, "cut after the Beacon header");
    }
}
