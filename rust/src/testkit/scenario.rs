//! Seeded scenario builder: one `u64` expands into a full THRL
//! topology plus a composed fault schedule, and [`Scenario::run`]
//! executes it with the *real* stack — [`Publisher`] leaves,
//! [`run_relay`] relay nodes, [`FanIn`] root attaches — wired over the
//! in-process chaos transport.
//!
//! # Determinism contract
//!
//! A scenario must produce the same merged stream and the same ledgers
//! on every rerun, because the sweep's only repro artifact is the seed.
//! Three generator rules make that hold despite real threads:
//!
//! 1. **Leaf hubs are sealed before serving.** Every event is pushed
//!    and the hub closed before the first connection is accepted, so a
//!    leaf's wire bytes are a pure function of its spec — which makes
//!    the byte-positioned faults of [`FaultSpec`] land on the same
//!    event every run. On a lost connection the publisher immediately
//!    drains the remainder into its replay ring, so the resumed stream
//!    is a pure ring replay, again byte-deterministic.
//! 2. **Unique global timestamps whenever relays are present.** A
//!    relay republishes streams it learns over time, so the *global
//!    channel order* at the root can depend on arrival timing; with
//!    unique timestamps the merge order never consults it. Cross-stream
//!    timestamp ties (which exercise the channel-id tie-break) are only
//!    generated for flat no-relay topologies, where every channel is
//!    allocated at handshake time in connection order.
//! 3. **Relay replay rings are always roomy** (`RELAY_RING`), so a
//!    killed relay→root connection resumes with gap zero and the merged
//!    output does not depend on *where* in the (timing-dependent) relay
//!    byte stream the cut landed. Leaf rings may be tight — leaf bytes
//!    are deterministic, so the resulting gaps are too.
//!
//! Multiple root attaches (`root_attaches == 2`) are only generated
//! when every leaf sits behind a relay: a `Publisher` leaf serves
//! exactly one complete session, a relay's `Broadcaster` serves many.

use crate::analysis::EventMsg;
use crate::coordinator::{run_relay, RelayReport};
use crate::live::LiveHub;
use crate::live::OriginStats;
use crate::remote::frame::{T_CLOSE, T_EOS, T_EVENT, T_EVENT_BATCH, T_HELLO, T_ORIGIN};
use crate::remote::{
    encode, FanIn, FanInStats, Frame, PublishStats, Publisher, ReconnectPolicy, ServeOutcome,
    WireEvent,
};
use crate::tracer::btf::generate_metadata;
use crate::tracer::encoder::FieldValue;
use crate::util::Rng;
use std::fmt;
use std::io;
use std::sync::Arc;
use std::time::Duration;

use super::chaos::{
    chaos_listener, refusing_connector, ChaosConn, ChaosListener, FaultSpec, PipeEnd,
};

/// Relay replay rings are always roomy (determinism rule 3).
pub const RELAY_RING: usize = 1 << 20;

/// Per-stream event cap: must stay below the hub depth used by
/// [`Scenario::run`] so sealing a leaf hub never drops locally.
const MAX_EVENTS_PER_STREAM: usize = 28;

/// One merged event as the oracles compare it: `(ts, rank, tid,
/// hostname, class name)`.
pub type Merged = (u64, u32, u32, String, String);

/// One scripted leaf event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EventSpec {
    pub ts: u64,
    pub rank: u32,
    pub tid: u32,
}

/// One leaf publisher: a sealed hub's worth of events plus the fault
/// schedule its serve side executes, connection by connection.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub hostname: String,
    /// Resume epoch (nonzero: the publisher is resumable).
    pub epoch: u64,
    /// THRL wire version this leaf publishes (2 or 3).
    pub wire: u32,
    /// Replay ring bytes — tight rings create resume gaps under kills.
    pub resume_buffer: usize,
    /// Events per stream, pre-scripted (stream index = channel index).
    pub streams: Vec<Vec<EventSpec>>,
    /// `serve_faults[k]` applies to the `k`-th accepted connection;
    /// connections beyond the schedule are clean.
    pub serve_faults: Vec<FaultSpec>,
    /// `redial_refusals[k]` dial attempts are refused before the `k`-th
    /// successful dial to this leaf (whoever dials it — relay or root).
    pub redial_refusals: Vec<u32>,
}

/// One relay node: which leaves it fans in, and the fault schedule on
/// its own upstream (relay→root) serve side.
#[derive(Debug, Clone)]
pub struct RelaySpec {
    pub label: String,
    /// Indices into [`Scenario::leaves`].
    pub leaves: Vec<usize>,
    pub serve_faults: Vec<FaultSpec>,
    pub redial_refusals: Vec<u32>,
}

/// A complete generated topology + fault schedule. `Display` prints
/// the scenario script a failing seed reports.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub seed: u64,
    pub leaves: Vec<LeafSpec>,
    pub relays: Vec<RelaySpec>,
    /// Leaf indices the root attaches to directly (not via a relay).
    pub direct: Vec<usize>,
    /// Concurrent root subscribers (2 only when all leaves are relayed).
    pub root_attaches: usize,
    /// Live channel depth at every fan-in.
    pub depth: usize,
}

/// What one root attach saw: the merged stream, the root hub's
/// per-origin ledgers, and the fan-in connection stats.
#[derive(Debug)]
pub struct AttachOutcome {
    pub merged: Vec<Merged>,
    pub origins: Vec<OriginStats>,
    pub stats: FanInStats,
}

/// Everything a scenario run produced, for the oracles.
#[derive(Debug)]
pub struct RunReport {
    pub attaches: Vec<AttachOutcome>,
    /// Final publisher stats per leaf, in [`Scenario::leaves`] order.
    pub leaf_stats: Vec<PublishStats>,
    /// Relay self-reports, in [`Scenario::relays`] order.
    pub relay_reports: Vec<RelayReport>,
}

/// The redial budget every dialer in a scenario uses. Generated
/// refusal quotas stay well below `attempts` so a scripted flaky dial
/// can never exhaust the budget.
pub fn policy() -> ReconnectPolicy {
    ReconnectPolicy { attempts: 10, backoff: Duration::from_millis(1) }
}

/// Alternating entry/exit registry classes, like a real traced API.
pub fn class_name(j: usize) -> &'static str {
    if j % 2 == 0 {
        "lttng_ust_ze:zeInit_entry"
    } else {
        "lttng_ust_ze:zeInit_exit"
    }
}

/// Decode a registry-class message through `hub` (the class id then
/// resolves on the attach side exactly like a real consumer's would).
pub(crate) fn reg_msg(hub: &LiveHub, name: &str, ts: u64, rank: u32, tid: u32) -> EventMsg {
    let class = crate::model::class_by_name(name).unwrap();
    hub.decode(rank, tid, class.id, ts, &0u64.to_le_bytes()).unwrap()
}

/// Wire size of one per-event v2 `Event` frame for our registry
/// payloads — sizes kill budgets and tight rings in whole events.
pub fn event_len() -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Event {
            stream: 0,
            event: WireEvent {
                ts: 10,
                rank: 0,
                tid: 1,
                class_id: crate::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap().id,
                fields: vec![FieldValue::U64(0)],
            },
        },
        &mut buf,
    );
    buf.len()
}

/// Wire size of the Hello a publisher sends (only the hostname length
/// varies) — lets a kill budget aim past the handshake.
pub fn hello_wire_len(hostname: &str) -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Hello {
            hostname: hostname.into(),
            metadata: generate_metadata(&[]),
            streams: 0,
            epoch: 0,
        },
        &mut buf,
    );
    buf.len()
}

/// Build and seal a leaf's hub from its spec (determinism rule 1).
pub(crate) fn build_leaf_hub(leaf: &LeafSpec) -> Arc<LiveHub> {
    let hub = LiveHub::new(&leaf.hostname, 64, false);
    hub.ensure_channels(leaf.streams.len());
    for (i, evs) in leaf.streams.iter().enumerate() {
        let msgs: Vec<EventMsg> = evs
            .iter()
            .enumerate()
            .map(|(j, e)| reg_msg(&hub, class_name(j), e.ts, e.rank, e.tid))
            .collect();
        let dropped = hub.push_batch(i, msgs);
        assert_eq!(dropped, 0, "leaf hub must seal losslessly");
    }
    hub.close_all();
    hub
}

impl Scenario {
    /// Events scripted for leaf `i`.
    pub fn leaf_total(&self, i: usize) -> u64 {
        self.leaves[i].streams.iter().map(|s| s.len() as u64).sum()
    }

    /// Events scripted across every leaf.
    pub fn total_events(&self) -> u64 {
        (0..self.leaves.len()).map(|i| self.leaf_total(i)).sum()
    }

    /// Expand `seed` into a scenario. Equal seeds give equal scenarios.
    pub fn generate(seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        let n_leaves = rng.range(1, 5);

        // topology: maybe relays (needing >= 2 leaves), maybe one
        // direct leaf kept alongside them, maybe a second root attach
        // (only when every leaf is behind a relay — see module docs)
        let use_relays = n_leaves >= 2 && rng.chance(0.6);
        let (relay_parts, direct): (Vec<Vec<usize>>, Vec<usize>) = if use_relays {
            let n_direct = usize::from(n_leaves >= 3 && rng.chance(0.35));
            let relayed = n_leaves - n_direct;
            let mut parts: Vec<Vec<usize>> = Vec::new();
            if relayed >= 3 && rng.chance(0.5) {
                let cut = rng.range(1, relayed);
                parts.push((0..cut).collect());
                parts.push((cut..relayed).collect());
            } else {
                parts.push((0..relayed).collect());
            }
            (parts, (relayed..n_leaves).collect())
        } else {
            (Vec::new(), (0..n_leaves).collect())
        };
        let all_relayed = use_relays && direct.is_empty();
        let root_attaches = if all_relayed && rng.chance(0.25) { 2 } else { 1 };

        // hostnames, with deliberate cross-leaf collisions: identity
        // must travel by origin path, never by name
        let pool = ["nodeA", "nodeB", "leafC"];
        let hostnames: Vec<String> = (0..n_leaves)
            .map(|i| {
                if rng.chance(0.3) {
                    pool[rng.range(0, pool.len())].to_string()
                } else {
                    format!("leaf{i}")
                }
            })
            .collect();

        // stream shapes, then timestamps: one global monotone counter
        // assigned over a random interleaving of every (leaf, stream)
        // slot. With relays the counter always advances (unique ts —
        // determinism rule 2); flat scenarios may reuse a timestamp to
        // exercise the cross-stream merge tie-break.
        let shapes: Vec<Vec<usize>> = (0..n_leaves)
            .map(|_| {
                (0..rng.range(1, 3)).map(|_| rng.range(4, MAX_EVENTS_PER_STREAM + 1)).collect()
            })
            .collect();
        let mut streams: Vec<Vec<Vec<EventSpec>>> = shapes
            .iter()
            .map(|s| s.iter().map(|_| Vec::new()).collect())
            .collect();
        let mut remaining: Vec<(usize, usize, usize)> = shapes
            .iter()
            .enumerate()
            .flat_map(|(l, s)| s.iter().enumerate().map(move |(j, &n)| (l, j, n)))
            .collect();
        let allow_ties = !use_relays;
        let mut ts = 10u64;
        while !remaining.is_empty() {
            let k = rng.range(0, remaining.len());
            let (l, j, _) = remaining[k];
            if !(allow_ties && rng.chance(0.2)) {
                ts += rng.range(1, 5) as u64;
            }
            streams[l][j].push(EventSpec { ts, rank: l as u32, tid: (j + 1) as u32 });
            remaining[k].2 -= 1;
            if remaining[k].2 == 0 {
                remaining.swap_remove(k);
            }
        }

        let ev = event_len();
        let leaves: Vec<LeafSpec> = (0..n_leaves)
            .map(|i| {
                let wire = if rng.chance(0.5) { 3 } else { 2 };
                let total: usize = shapes[i].iter().sum();
                let hello = hello_wire_len(&hostnames[i]);
                let serve_faults: Vec<FaultSpec> = (0..rng.range(0, 3))
                    .map(|_| gen_leaf_fault(&mut rng, wire, total, hello, ev))
                    .collect();
                // a tight replay ring only matters under a lethal fault
                let lethal = serve_faults.iter().any(FaultSpec::is_lethal);
                let resume_buffer = if lethal && rng.chance(0.5) {
                    ev * rng.range(2, 6)
                } else {
                    1 << 20
                };
                let redial_refusals: Vec<u32> =
                    (0..rng.range(0, 3)).map(|_| rng.below(4) as u32).collect();
                LeafSpec {
                    hostname: hostnames[i].clone(),
                    epoch: 0x1EAF_0000 + i as u64 + 1,
                    wire,
                    resume_buffer,
                    streams: streams[i].clone(),
                    serve_faults,
                    redial_refusals,
                }
            })
            .collect();

        let relays: Vec<RelaySpec> = relay_parts
            .iter()
            .enumerate()
            .map(|(k, part)| {
                // with two concurrent attaches, which one an upstream
                // fault hits is a race — keep that hop clean instead
                let serve_faults = if root_attaches == 1 && rng.chance(0.4) {
                    vec![gen_relay_fault(&mut rng, part.len())]
                } else {
                    Vec::new()
                };
                let redial_refusals: Vec<u32> =
                    (0..rng.range(0, 2)).map(|_| rng.below(4) as u32).collect();
                RelaySpec {
                    label: format!("relay{}", k + 1),
                    leaves: part.clone(),
                    serve_faults,
                    redial_refusals,
                }
            })
            .collect();

        Scenario { seed, leaves, relays, direct, root_attaches, depth: 64 }
    }

    /// Execute the scenario and collect everything the oracles need.
    /// Panics (with context) on any *unscripted* failure — a scripted
    /// fault must never take the stack down, only leave ledger marks.
    pub fn run(&self) -> RunReport {
        std::thread::scope(|s| {
            // leaves: bind first so every dialer has a live endpoint
            let mut leaf_eps = Vec::new();
            let mut leaf_handles = Vec::new();
            for leaf in &self.leaves {
                let (listener, ep) = chaos_listener();
                leaf_eps.push(ep);
                leaf_handles.push(s.spawn(move || serve_leaf(leaf, listener)));
            }

            let mut relay_eps = Vec::new();
            let mut relay_handles = Vec::new();
            for relay in &self.relays {
                let (listener, ep) = chaos_listener();
                relay_eps.push(ep);
                let connectors: Vec<_> = relay
                    .leaves
                    .iter()
                    .map(|&i| {
                        refusing_connector(
                            leaf_eps[i].clone(),
                            self.leaves[i].redial_refusals.clone(),
                        )
                    })
                    .collect();
                let (subscribers, depth) = (self.root_attaches, self.depth);
                let faults = relay.serve_faults.clone();
                let label = relay.label.as_str();
                relay_handles.push(s.spawn(move || {
                    let mut conn_idx = 0usize;
                    let accept = move || -> io::Result<Option<ChaosConn<PipeEnd>>> {
                        match listener.try_accept() {
                            Some(conn) => {
                                let fault = faults.get(conn_idx).cloned().unwrap_or_default();
                                conn_idx += 1;
                                Ok(Some(ChaosConn::new(conn, &fault)))
                            }
                            None => {
                                std::thread::sleep(Duration::from_millis(1));
                                Ok(None)
                            }
                        }
                    };
                    run_relay(
                        connectors,
                        depth,
                        policy(),
                        Some(label),
                        accept,
                        subscribers,
                        RELAY_RING,
                        None,
                        &Default::default(),
                    )
                }));
            }

            // root attaches: relays first, then direct leaves — this
            // connection order IS the origin order the oracles assume
            let mut attach_handles = Vec::new();
            for _ in 0..self.root_attaches {
                let connectors: Vec<_> = self
                    .relays
                    .iter()
                    .enumerate()
                    .map(|(k, r)| {
                        refusing_connector(relay_eps[k].clone(), r.redial_refusals.clone())
                    })
                    .chain(self.direct.iter().map(|&i| {
                        refusing_connector(
                            leaf_eps[i].clone(),
                            self.leaves[i].redial_refusals.clone(),
                        )
                    }))
                    .collect();
                let depth = self.depth;
                attach_handles.push(s.spawn(move || attach_once(connectors, depth)));
            }
            drop(leaf_eps);
            drop(relay_eps);

            let attaches: Vec<AttachOutcome> =
                attach_handles.into_iter().map(|h| h.join().expect("attach thread")).collect();
            let relay_reports: Vec<RelayReport> = relay_handles
                .into_iter()
                .map(|h| h.join().expect("relay thread").expect("relay node failed"))
                .collect();
            let leaf_stats: Vec<PublishStats> =
                leaf_handles.into_iter().map(|h| h.join().expect("leaf thread")).collect();
            RunReport { attaches, leaf_stats, relay_reports }
        })
    }
}

/// One leaf fault: exactly one trigger per spec, chosen and sized from
/// the leaf's own wire geometry.
fn gen_leaf_fault(
    rng: &mut Rng,
    wire: u32,
    total_events: usize,
    hello: usize,
    ev: usize,
) -> FaultSpec {
    // upper bound on the session's wire size (v3 streams are shorter —
    // a budget past the real end simply never fires, which is fine)
    let approx_total = 8 + hello + total_events * ev + 64;
    match rng.range(0, 5) {
        0 => FaultSpec { kill_at_byte: Some(rng.range(2, approx_total)), ..Default::default() },
        1 => {
            let (kind, nth) = match rng.range(0, 3) {
                0 => {
                    let kind = if wire >= 3 { T_EVENT_BATCH } else { T_EVENT };
                    (kind, rng.range(1, total_events.min(20) + 1) as u32)
                }
                1 => (T_EOS, 1),
                _ => (T_CLOSE, 1),
            };
            FaultSpec { kill_at_frame: Some((kind, nth)), ..Default::default() }
        }
        2 => FaultSpec { throttle: Some(rng.range(1, 64)), ..Default::default() },
        3 => FaultSpec {
            delay: Some((rng.range(256, 1025), rng.range(20, 200) as u64)),
            ..Default::default()
        },
        _ => FaultSpec {
            stall: Some((rng.range(0, approx_total), rng.range(3, 20) as u64)),
            ..Default::default()
        },
    }
}

/// One relay upstream fault. The relay ring is roomy, so these only
/// exercise resume — they can never create a gap (determinism rule 3).
fn gen_relay_fault(rng: &mut Rng, n_leaves: usize) -> FaultSpec {
    match rng.range(0, 4) {
        0 => FaultSpec { kill_at_byte: Some(rng.range(2, 4096)), ..Default::default() },
        1 => FaultSpec { kill_at_frame: Some((T_HELLO, 1)), ..Default::default() },
        2 => FaultSpec {
            kill_at_frame: Some((T_ORIGIN, rng.range(1, n_leaves + 1) as u32)),
            ..Default::default()
        },
        _ => FaultSpec { kill_at_frame: Some((T_EOS, 1)), ..Default::default() },
    }
}

/// Serve one leaf until its single session completes, executing the
/// fault schedule connection by connection.
fn serve_leaf(leaf: &LeafSpec, listener: ChaosListener) -> PublishStats {
    let hub = build_leaf_hub(leaf);
    let mut publisher = Publisher::new(hub, leaf.epoch, leaf.resume_buffer).with_wire(leaf.wire);
    let mut conn_idx = 0usize;
    loop {
        let conn = listener.accept().expect("leaf listener");
        let fault = leaf.serve_faults.get(conn_idx).cloned().unwrap_or_default();
        conn_idx += 1;
        match publisher.serve_connection(ChaosConn::new(conn, &fault)) {
            ServeOutcome::Complete => return publisher.stats(),
            ServeOutcome::Lost(_) => {
                // push the undrained remainder into the replay ring NOW:
                // the resumed stream is then a pure ring replay, byte-
                // deterministic regardless of reconnect timing
                publisher.drain_to_ring();
            }
        }
    }
}

/// One root attach: open the resumable fan-in, drain the merge, and
/// snapshot ledgers + connection stats.
fn attach_once<C>(connectors: Vec<C>, depth: usize) -> AttachOutcome
where
    C: FnMut() -> io::Result<PipeEnd> + Send + 'static,
{
    let fan = FanIn::open_resumable(connectors, depth, policy()).expect("fan-in open");
    let merged: Vec<Merged> = fan
        .source()
        .map(|m| (m.ts, m.rank, m.tid, m.hostname.to_string(), m.class.name.clone()))
        .collect();
    let origins = fan.hub().origin_stats();
    let stats = fan.finish().expect("fan-in finish");
    AttachOutcome { merged, origins, stats }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "scenario seed={} depth={} root_attaches={}",
            self.seed, self.depth, self.root_attaches
        )?;
        for (i, l) in self.leaves.iter().enumerate() {
            writeln!(
                f,
                "  leaf {i}: host={} wire=v{} epoch={:#x} ring={} events/stream={:?} \
                 faults={:?} refusals={:?}",
                l.hostname,
                l.wire,
                l.epoch,
                l.resume_buffer,
                l.streams.iter().map(Vec::len).collect::<Vec<_>>(),
                l.serve_faults,
                l.redial_refusals
            )?;
        }
        for r in &self.relays {
            writeln!(
                f,
                "  relay {}: leaves={:?} faults={:?} refusals={:?}",
                r.label, r.leaves, r.serve_faults, r.redial_refusals
            )?;
        }
        writeln!(f, "  direct={:?}", self.direct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every generated scenario obeys the determinism contract the
    /// runner and oracles rely on.
    #[test]
    fn generator_upholds_the_determinism_contract() {
        for seed in 0..256 {
            let sc = Scenario::generate(seed);
            let ctx = format!("{sc}");
            assert!(!sc.leaves.is_empty(), "{ctx}");
            assert!(sc.root_attaches == 1 || sc.root_attaches == 2, "{ctx}");

            // partition: every leaf is relayed XOR direct, exactly once
            let mut seen = vec![0usize; sc.leaves.len()];
            for r in &sc.relays {
                assert!(!r.leaves.is_empty(), "{ctx}");
                for &i in &r.leaves {
                    seen[i] += 1;
                }
            }
            for &i in &sc.direct {
                seen[i] += 1;
            }
            assert!(seen.iter().all(|&n| n == 1), "partition broken: {ctx}");

            // rule 2: unique global timestamps whenever relays exist
            if !sc.relays.is_empty() {
                let mut all: Vec<u64> = sc
                    .leaves
                    .iter()
                    .flat_map(|l| l.streams.iter().flatten().map(|e| e.ts))
                    .collect();
                let n = all.len();
                all.sort_unstable();
                all.dedup();
                assert_eq!(all.len(), n, "duplicate ts under relays: {ctx}");
            }

            // multi-attach only when every leaf is behind a relay, and
            // then with a clean relay→root hop
            if sc.root_attaches == 2 {
                assert!(sc.direct.is_empty(), "{ctx}");
                assert!(sc.relays.iter().all(|r| r.serve_faults.is_empty()), "{ctx}");
            }

            for l in &sc.leaves {
                assert!(l.epoch != 0, "resumable publishers need a nonzero epoch: {ctx}");
                assert!(l.wire == 2 || l.wire == 3, "{ctx}");
                for st in &l.streams {
                    assert!((4..=MAX_EVENTS_PER_STREAM).contains(&st.len()), "{ctx}");
                    assert!(st.windows(2).all(|w| w[0].ts <= w[1].ts), "{ctx}");
                }
                // refusal quotas stay below the redial budget
                assert!(l.redial_refusals.iter().all(|&q| q < policy().attempts), "{ctx}");
            }
            for r in &sc.relays {
                assert!(r.redial_refusals.iter().all(|&q| q < policy().attempts), "{ctx}");
            }
        }
    }

    #[test]
    fn generation_is_deterministic_and_seed_sensitive() {
        let a = format!("{}", Scenario::generate(7));
        let b = format!("{}", Scenario::generate(7));
        assert_eq!(a, b);
        let c = format!("{}", Scenario::generate(8));
        assert_ne!(a, c, "different seeds should differ somewhere");
    }
}
