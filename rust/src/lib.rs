//! # THAPI-rs — Tracing Heterogeneous APIs, in Rust
//!
//! A reproduction of *"THAPI: Tracing Heterogeneous APIs"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system. The crate contains:
//!
//! * [`tracer`] — the LTTng-UST substitute: lockless per-thread ring buffers,
//!   sessions with selective event enabling, tracing modes, and the BTF
//!   binary trace format (CTF stand-in).
//! * [`model`] — the automatic tracepoint-generation pipeline: C-header /
//!   XML-registry parsing into the YAML API model, meta-parameter
//!   enrichment, and trace-model / event-class generation (paper Fig. 1b,
//!   Fig. 3).
//! * [`runtime`] — PJRT executor: loads the AOT-lowered HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them (real compute
//!   for every simulated kernel launch).
//! * [`device`] — the simulated heterogeneous node: GPUs with compute/copy
//!   engines, command queues/lists, events, device memory, telemetry.
//! * [`intercept`] — the traced programming-model frontends: Level-Zero,
//!   CUDA, HIP (layered on Level-Zero, i.e. HIPLZ), OpenCL, MPI and
//!   OpenMP-offload, each emitting full-context entry/exit events.
//! * [`analysis`] — the Babeltrace2/Metababel substitute: a streaming
//!   source → muxer → filter → sink graph (lazy time-ordered muxing,
//!   incremental interval pairing, single-pass sink fan-out) behind the
//!   generated plugins (pretty print, tally, timeline, validation). See
//!   `rust/ARCHITECTURE.md`.
//! * [`live`] — on-line analysis: the consumer thread decodes records as
//!   it drains them and feeds the same sink graph through bounded,
//!   watermarked per-stream channels (beacons for quiet streams), so
//!   every analysis runs while the application executes with
//!   O(streams × channel-depth) memory (`iprof --live`).
//! * [`remote`] — the network hop between hub and merge: a versioned,
//!   length-prefixed frame protocol (`docs/PROTOCOL.md`, frozen by the
//!   golden fixtures in `rust/tests/fixtures/thrl/`) over which
//!   `iprof serve` publishes the live channels and `iprof attach` drives
//!   the unmodified merge + sinks on another machine — for one publisher
//!   or, via the fan-in (`iprof attach <addr> <addr>...`), for a whole
//!   fleet merged by one subscriber.
//! * [`sampling`] — the device-telemetry sampling daemon (paper §3.5).
//! * [`telemetry`] — the collector's self-telemetry: a lock-free metrics
//!   registry instrumenting every pipeline stage, a built-in Prometheus
//!   scrape endpoint (`--telemetry <addr>`), periodic JSON snapshots
//!   (`--telemetry-json`), and the `iprof health` operator summary.
//! * [`aggregate`] — on-node aggregation and the local-/global-master
//!   composite-profile merge (paper §3.7).
//! * [`coordinator`] — the `iprof` launcher: session lifecycle, workload
//!   execution, post-mortem analysis dispatch.
//! * [`apps`] — the traced workloads: HeCBench-like mini-apps and
//!   SPEChpc-like MPI+offload benchmarks, all executing real PJRT kernels.
//! * [`bench_support`] — the in-crate benchmark harness (criterion
//!   substitute) used by `benches/`.
//! * [`testkit`] — the deterministic chaos harness: an in-process
//!   fault-injecting transport ([`testkit::ChaosConn`]) plus a seeded
//!   [`testkit::Scenario`] builder and invariant oracles (conservation,
//!   determinism, post-mortem golden) that drive the real
//!   publisher/broadcaster/fan-in/relay stack under composed fault
//!   schedules (`rust/tests/chaos.rs`).
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod aggregate;
pub mod analysis;
pub mod apps;
pub mod bench_support;
pub mod coordinator;
pub mod device;
pub mod intercept;
pub mod live;
pub mod model;
pub mod remote;
pub mod runtime;
pub mod sampling;
pub mod telemetry;
pub mod testkit;
pub mod tracer;
pub mod util;

/// Crate version (also reported in trace metadata env blocks).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
