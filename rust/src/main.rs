//! `iprof` — the THAPI-rs launcher (paper §3.4).
//!
//! ```text
//! iprof [OPTIONS] -- <workload>[,<workload>...]
//! iprof serve <bind-addr> [OPTIONS] -- <workload>    publish live channels
//!              [--resume-buffer <bytes>]             (resumable session:
//!              [--kill-after <bytes>]                 replay ring + epochs)
//!              [--wire <2|3>]                        wire version (3: batched)
//!              [--subscribers <n>] [--max-lag <b>]   broadcast: N concurrent
//!                                                    viewers, one shared ring
//! iprof attach <addr> [<addr>...] [-a <list>]        remote live viewer:
//!              [--refresh <ms>] [--reconnect <n>]    1 publisher, or N
//!              [--backoff <ms>]                      merged as one fan-in;
//!                                                    reconnect + resume
//! iprof relay <listen-addr> <addr> [<addr>...]       aggregation tree node:
//!              [--subscribers <n>] [--label <name>]   fan-in N downstream
//!              [--resume-buffer <b>] [--max-lag <b>]  publishers, re-publish
//!              [--reconnect <n>] [--backoff <ms>]     the merged union
//!                                                     upstream (wire v3)
//! iprof health <addr> [--strict [--max-drops <n>]]   scrape a --telemetry
//!                                                    endpoint, one-screen
//!                                                    operator summary
//!
//! `serve`, `attach` and `relay` all take `--telemetry <addr>`
//! (Prometheus scrape endpoint over the pipeline's self-telemetry
//! registry) and `--telemetry-json <path>` (periodic JSON snapshots).
//!
//!   -m, --mode <minimal|default|full>   tracing mode        [default]
//!   -s, --sample [<ms>]                 device sampling daemon (50 ms)
//!   -n, --node <aurora|polaris|small>   node configuration  [small]
//!   -t, --trace-dir <dir>               persist the BTF trace
//!       --no-trace                      baseline run (tracing off)
//!       --ranks <r0,r1,...>             trace only these ranks
//!       --filter <pattern>              disable matching event classes
//!   -a, --analysis <tally,pretty,timeline,validate|none>  [tally]
//!       --live                          analyze ON-LINE: sinks run from the
//!                                       consumer thread while the workload
//!                                       executes (bounded memory, beacons)
//!       --refresh <ms>                  with --live: periodic interim
//!                                       reports from refreshable sinks
//!       --live-depth <n>                per-stream live channel depth in
//!                                       messages               [1024]
//!       --live-strict                   with --live: exit nonzero if any
//!                                       event was dropped (ring or channel)
//!       --scale <f>                     workload intensity  [1.0]
//!       --list                          list available workloads
//! ```
//!
//! `-a` accepts a comma-separated list; all requested sinks are driven
//! by ONE streaming pass over the trace (source → muxer → filter →
//! sinks), and unknown analysis names are rejected at argument-parse
//! time — before any workload has run.

use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::sync::Arc;
use thapi::analysis::{
    self, AnalysisSink, PrettySink, Report, TallySink, TimelineSink, ValidateSink,
};
use thapi::apps::{hecbench, spechpc, Workload};
use thapi::coordinator::{self, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::LiveConfig;
use thapi::sampling::SamplingConfig;
use thapi::telemetry::{self, HealthSummary, TelemetryOptions};
use thapi::tracer::{SinkKind, TracingMode};

/// One requested analysis plugin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AnalysisKind {
    Tally,
    Pretty,
    Timeline,
    Validate,
}

impl AnalysisKind {
    fn parse(s: &str) -> Result<AnalysisKind> {
        Ok(match s {
            "tally" => AnalysisKind::Tally,
            "pretty" => AnalysisKind::Pretty,
            "timeline" => AnalysisKind::Timeline,
            "validate" => AnalysisKind::Validate,
            other => bail!("unknown analysis {other} (expected tally, pretty, timeline, validate or none)"),
        })
    }

    fn sink(&self) -> Box<dyn AnalysisSink + Send> {
        match self {
            AnalysisKind::Tally => Box::new(TallySink::new()),
            AnalysisKind::Pretty => Box::new(PrettySink::new()),
            AnalysisKind::Timeline => Box::new(TimelineSink::new()),
            AnalysisKind::Validate => Box::new(ValidateSink::new()),
        }
    }
}

/// Parse `-a` values: a comma-separated plugin list, or `none`.
/// Duplicates collapse; unknown names fail here, at parse time.
fn parse_analyses(v: &str) -> Result<Vec<AnalysisKind>> {
    if v == "none" {
        return Ok(Vec::new());
    }
    let mut kinds = Vec::new();
    for part in v.split(',').filter(|p| !p.is_empty()) {
        if part == "none" {
            bail!("analysis 'none' cannot be combined with other analyses");
        }
        let k = AnalysisKind::parse(part)?;
        if !kinds.contains(&k) {
            kinds.push(k);
        }
    }
    if kinds.is_empty() {
        bail!("--analysis needs at least one of tally, pretty, timeline, validate (or none)");
    }
    Ok(kinds)
}

struct Options {
    mode: TracingMode,
    sample_ms: Option<u64>,
    node: NodeConfig,
    trace_dir: Option<std::path::PathBuf>,
    tracing: bool,
    ranks: Option<HashSet<u32>>,
    filters: Vec<String>,
    analyses: Vec<AnalysisKind>,
    workloads: Vec<String>,
    list: bool,
    live: bool,
    refresh_ms: Option<u64>,
    live_depth: Option<usize>,
    live_strict: bool,
    /// serve: replay-ring byte budget; Some = resumable session.
    resume_buffer: Option<usize>,
    /// serve: fault injection — kill the FIRST subscriber connection
    /// after this many written bytes (reconnect testing/CI).
    kill_after: Option<usize>,
    /// attach: redial attempts per disconnect.
    reconnect: Option<u32>,
    /// attach: base backoff before the first redial, in ms.
    backoff_ms: Option<u64>,
    /// serve: THRL wire version (2 = per-event fallback, 3 = batched).
    wire: Option<u32>,
    /// serve: broadcast to this many concurrent subscribers over one
    /// shared replay ring (Some = broadcast session).
    subscribers: Option<usize>,
    /// serve: per-subscriber lag budget in bytes — a viewer further
    /// behind than this is demoted to gap delivery under ring pressure.
    max_lag: Option<usize>,
    /// relay: the name this node publishes upstream (its Hello hostname
    /// and the prefix of its leaves' hierarchical origin paths).
    label: Option<String>,
    /// serve/attach: bind a Prometheus scrape endpoint here.
    telemetry_addr: Option<String>,
    /// serve/attach: write periodic JSON telemetry snapshots here.
    telemetry_json: Option<std::path::PathBuf>,
}

impl Options {
    /// The self-telemetry exposure this invocation asked for.
    fn telemetry(&self) -> TelemetryOptions {
        TelemetryOptions {
            addr: self.telemetry_addr.clone(),
            json_path: self.telemetry_json.clone(),
            json_period: None,
        }
    }
}

/// Parse a byte count with an optional k/m/g suffix (powers of 1024):
/// `65536`, `512k`, `8m`, `1g`.
fn parse_bytes(v: &str) -> Result<usize> {
    let v = v.trim();
    let (digits, mult) = match v.chars().last() {
        Some('k') | Some('K') => (&v[..v.len() - 1], 1usize << 10),
        Some('m') | Some('M') => (&v[..v.len() - 1], 1usize << 20),
        Some('g') | Some('G') => (&v[..v.len() - 1], 1usize << 30),
        _ => (v, 1),
    };
    let n: usize = digits.parse().with_context(|| format!("bad byte count {v}"))?;
    n.checked_mul(mult).context("byte count overflows")
}

fn parse_args(args: &[String]) -> Result<Options> {
    let mut o = Options {
        mode: TracingMode::Default,
        sample_ms: None,
        node: NodeConfig::test_small(),
        trace_dir: None,
        tracing: true,
        ranks: None,
        filters: Vec::new(),
        analyses: vec![AnalysisKind::Tally],
        workloads: Vec::new(),
        list: false,
        live: false,
        refresh_ms: None,
        live_depth: None,
        live_strict: false,
        resume_buffer: None,
        kill_after: None,
        reconnect: None,
        backoff_ms: None,
        wire: None,
        subscribers: None,
        max_lag: None,
        label: None,
        telemetry_addr: None,
        telemetry_json: None,
    };
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-m" | "--mode" => {
                let v = it.next().context("--mode needs a value")?;
                o.mode = match v.as_str() {
                    "minimal" | "min" => TracingMode::Minimal,
                    "default" => TracingMode::Default,
                    "full" => TracingMode::Full,
                    other => bail!("unknown mode {other}"),
                };
            }
            "-s" | "--sample" => {
                let ms = it
                    .peek()
                    .and_then(|v| v.parse::<u64>().ok())
                    .map(|v| {
                        it.next();
                        v
                    })
                    .unwrap_or(50);
                o.sample_ms = Some(ms);
            }
            "-n" | "--node" => {
                let v = it.next().context("--node needs a value")?;
                o.node = match v.as_str() {
                    "aurora" => NodeConfig::aurora(),
                    "polaris" => NodeConfig::polaris(),
                    "small" => NodeConfig::test_small(),
                    other => bail!("unknown node {other}"),
                };
            }
            "-t" | "--trace-dir" => {
                o.trace_dir = Some(it.next().context("--trace-dir needs a value")?.into());
            }
            "--no-trace" => o.tracing = false,
            "--ranks" => {
                let v = it.next().context("--ranks needs a value")?;
                o.ranks = Some(
                    v.split(',')
                        .map(|r| r.parse::<u32>().context("bad rank"))
                        .collect::<Result<_>>()?,
                );
            }
            "--filter" => o.filters.push(it.next().context("--filter needs a value")?.clone()),
            "--live" => o.live = true,
            "--refresh" => {
                let v = it.next().context("--refresh needs a value (ms)")?;
                o.refresh_ms = Some(v.parse().context("bad --refresh value")?);
            }
            "--live-depth" => {
                let v = it.next().context("--live-depth needs a value")?;
                let depth: usize = v.parse().context("bad --live-depth value")?;
                if depth == 0 {
                    bail!("--live-depth must be at least 1");
                }
                o.live_depth = Some(depth);
            }
            "--live-strict" => o.live_strict = true,
            "--resume-buffer" => {
                let v = it.next().context("--resume-buffer needs a byte count")?;
                let bytes = parse_bytes(v)?;
                if bytes == 0 {
                    bail!("--resume-buffer must be at least 1 byte");
                }
                o.resume_buffer = Some(bytes);
            }
            "--kill-after" => {
                let v = it.next().context("--kill-after needs a byte count")?;
                o.kill_after = Some(parse_bytes(v)?);
            }
            "--reconnect" => {
                let v = it.next().context("--reconnect needs an attempt count")?;
                o.reconnect = Some(v.parse().context("bad --reconnect value")?);
            }
            "--backoff" => {
                let v = it.next().context("--backoff needs a value (ms)")?;
                o.backoff_ms = Some(v.parse().context("bad --backoff value")?);
            }
            "--wire" => {
                let v = it.next().context("--wire needs a version (2 or 3)")?;
                let version: u32 = v.parse().context("bad --wire value")?;
                if !thapi::remote::SUPPORTED_VERSIONS.contains(&version) {
                    bail!(
                        "--wire {version} unsupported (this build speaks {:?})",
                        thapi::remote::SUPPORTED_VERSIONS
                    );
                }
                o.wire = Some(version);
            }
            "--subscribers" => {
                let v = it.next().context("--subscribers needs a count")?;
                let n: usize = v.parse().context("bad --subscribers value")?;
                if n == 0 {
                    bail!("--subscribers must be at least 1");
                }
                o.subscribers = Some(n);
            }
            "--max-lag" => {
                let v = it.next().context("--max-lag needs a byte count")?;
                let bytes = parse_bytes(v)?;
                if bytes == 0 {
                    bail!("--max-lag must be at least 1 byte");
                }
                o.max_lag = Some(bytes);
            }
            "--label" => {
                let v = it.next().context("--label needs a name")?;
                if v.is_empty() || v.contains('/') {
                    bail!("--label must be a nonempty name without '/' (it prefixes origin paths)");
                }
                o.label = Some(v.clone());
            }
            "--telemetry" => {
                let v = it.next().context("--telemetry needs a bind address")?;
                o.telemetry_addr = Some(v.clone());
            }
            "--telemetry-json" => {
                let v = it.next().context("--telemetry-json needs a path")?;
                o.telemetry_json = Some(v.into());
            }
            "-a" | "--analysis" => {
                let v = it.next().context("--analysis needs a value")?;
                o.analyses = parse_analyses(v)?;
            }
            "--scale" => {
                let v = it.next().context("--scale needs a value")?;
                std::env::set_var("THAPI_APP_SCALE", v);
            }
            "--list" => o.list = true,
            "--" => {
                for w in it.by_ref() {
                    o.workloads.extend(w.split(',').map(String::from));
                }
            }
            "-h" | "--help" => {
                println!("{}", HELP);
                std::process::exit(0);
            }
            other => {
                if other.starts_with('-') {
                    bail!("unknown option {other} (see --help)");
                }
                o.workloads.extend(other.split(',').map(String::from));
            }
        }
    }
    Ok(o)
}

const HELP: &str = "iprof — THAPI-rs tracing launcher
USAGE: iprof [OPTIONS] [--] <workload>[,<workload>...]
       iprof serve <bind-addr> [OPTIONS] [--] <workload>
         trace the workload and PUBLISH the live per-stream channels over a
         socket (docs/PROTOCOL.md); waits for one subscriber, then runs.
         With --resume-buffer <bytes> the session is RESUMABLE: a dropped
         subscriber may reconnect and resume from where it left off, the
         lost tail replayed from a ring of that many bytes
       iprof attach <addr> [<addr>...] [-a <list>] [--refresh <ms>]
             [--live-depth <n>] [--reconnect <n>] [--backoff <ms>]
         connect to one or more publishers and run the analysis sinks here
         over the merged union of all their streams, fed by the same merge
         local --live uses (byte-identical for lossless feeds; with N
         addresses, identical to one local run over the concatenated
         streams). One dying publisher yields a partial analysis of the
         rest, with per-publisher accounting; --reconnect makes a dropped
         resumable publisher re-join its own streams instead of dying
       iprof relay <listen-addr> <addr> [<addr>...] [--subscribers <n>]
             [--resume-buffer <bytes>] [--max-lag <bytes>] [--label <name>]
             [--reconnect <n>] [--backoff <ms>]
         aggregation tree node: attach to N downstream publishers, merge
         their streams into one mirror hub, and re-publish the union
         upstream as a resumable broadcast (always wire v3). Per-leaf
         identity rides Origin frames with path-style hierarchical ids
         (0:relay1/0:nodeA), so the root books drops/eos/resume-gap
         ledgers and telemetry series per LEAF — never aliased across
         relays — and a 2-level tree merges byte-identically to a flat
         N-way attach
       iprof health <addr> [--strict [--max-drops <n>]]
         scrape a --telemetry endpoint once and render a one-screen operator
         summary (pipeline totals, per-origin ledgers, known loss); with
         --strict, exit nonzero when known loss exceeds --max-drops [0]
  -m, --mode <minimal|default|full>    tracing mode [default]
  -s, --sample [<ms>]                  enable device sampling (50 ms default)
  -n, --node <aurora|polaris|small>    node configuration [small]
  -t, --trace-dir <dir>                persist the BTF trace to <dir>
      --no-trace                       baseline run (tracing off)
      --ranks <r0,r1,...>              trace only these ranks
      --filter <pattern>               disable matching event classes
  -a, --analysis <list|none>           comma-separated sinks driven in one
                                       streaming pass: tally, pretty,
                                       timeline, validate   [tally]
      --live                           run the sinks ON-LINE from the consumer
                                       thread while the workload executes
      --refresh <ms>                   with --live: periodic interim reports
      --live-depth <n>                 per-stream live channel depth [1024]
      --live-strict                    with --live: exit nonzero on any
                                       dropped event (ring or channel)
      --resume-buffer <bytes>          serve: keep a replay ring of this many
                                       bytes and allow subscribers to
                                       reconnect + resume (suffixes k/m/g)
      --kill-after <bytes>             serve: fault injection — kill the first
                                       subscriber connection after this many
                                       written bytes (reconnect testing)
      --subscribers <n>                serve: broadcast to n concurrent
                                       subscribers over one shared replay
                                       ring — each connection negotiates its
                                       own wire version and may attach late
      --max-lag <bytes>                serve: per-subscriber lag budget — a
                                       viewer further behind than this is
                                       demoted to gap delivery when the ring
                                       is over budget, instead of stalling
                                       everyone (suffixes k/m/g)
      --wire <2|3>                     serve: THRL wire version — 3 batches
                                       events (EventBatch + vectored writes),
                                       2 keeps the frozen per-event stream
                                       for v2-only subscribers          [3]
      --telemetry <addr>               serve/attach/relay: bind a Prometheus
                                       scrape endpoint (text exposition
                                       v0.0.4) over the pipeline's
                                       self-telemetry registry
      --telemetry-json <path>          serve/attach/relay: write periodic JSON
                                       telemetry snapshots to <path>
      --label <name>                   relay: the name this node publishes
                                       upstream (its Hello hostname and the
                                       prefix of its leaves' origin paths)
                                       [first downstream hostname]
      --reconnect <n>                  attach/relay: redial a dropped resumable
                                       publisher up to n times per outage [0]
      --backoff <ms>                   attach: backoff before the first redial,
                                       doubling per attempt, cap 5 s   [250]
      --scale <f>                      workload intensity multiplier
      --list                           list available workloads";

fn all_workloads() -> Vec<Arc<dyn Workload>> {
    let mut v = hecbench::suite();
    v.extend(spechpc::suite());
    v
}

/// Print/persist one report per requested analysis (shared by the
/// post-mortem and live paths; both produce reports in `-a` order).
fn emit_reports(name: &str, analyses: &[AnalysisKind], reports: Vec<Report>) -> Result<()> {
    for (kind, rep) in analyses.iter().zip(reports) {
        match (kind, rep) {
            (AnalysisKind::Timeline, Report::Json(json)) => {
                let path = format!("{name}.trace.json");
                std::fs::write(&path, json)?;
                eprintln!("iprof: wrote {path} (open in Perfetto)");
            }
            (AnalysisKind::Pretty | AnalysisKind::Validate, Report::Text(text)) => {
                print!("{text}");
            }
            (_, Report::Text(text)) => println!("{text}"),
            (_, _) => {}
        }
    }
    Ok(())
}

/// `iprof serve <bind-addr> [OPTIONS] -- <workload>`: trace one workload
/// and publish its live channels to the first subscriber that connects.
fn serve_main(args: &[String]) -> Result<()> {
    let addr = args
        .first()
        .filter(|a| !a.starts_with('-'))
        .context("serve needs a bind address (e.g. iprof serve 127.0.0.1:7007 -- saxpy-ze)")?;
    let o = parse_args(&args[1..])?;
    if !o.tracing {
        bail!("serve requires tracing (drop --no-trace)");
    }
    if o.trace_dir.is_some() {
        bail!("serve relays on-line and persists no trace (drop --trace-dir)");
    }
    if o.refresh_ms.is_some() {
        bail!("--refresh belongs to the viewer: pass it to iprof attach instead");
    }
    if o.reconnect.is_some() || o.backoff_ms.is_some() {
        bail!("--reconnect/--backoff belong to the viewer: pass them to iprof attach instead");
    }
    if o.kill_after.is_some() && o.resume_buffer.is_none() && o.subscribers.is_none() {
        bail!("--kill-after is fault injection; it needs --resume-buffer or --subscribers");
    }
    if o.max_lag.is_some() && o.subscribers.is_none() {
        bail!("--max-lag is a broadcast lag budget; it needs --subscribers");
    }
    if o.label.is_some() {
        bail!("--label names a relay node: pass it to iprof relay");
    }
    if o.workloads.len() != 1 {
        bail!("serve publishes exactly one workload run (got {})", o.workloads.len());
    }
    let name = &o.workloads[0];
    let registry = all_workloads();
    let w = registry
        .iter()
        .find(|w| w.name() == name)
        .with_context(|| format!("unknown workload {name} (try --list)"))?;

    let node = Node::new(o.node.clone());
    let config = IprofConfig {
        tracing: true,
        mode: o.mode,
        sampling: o.sample_ms.map(|ms| SamplingConfig {
            interval: std::time::Duration::from_millis(ms),
        }),
        sink: SinkKind::Memory, // superseded by the live sink inside run_serve
        selected_ranks: o.ranks.clone(),
        disabled_patterns: o.filters.clone(),
        ..Default::default()
    };
    let live_cfg = LiveConfig {
        channel_depth: o.live_depth.unwrap_or(LiveConfig::default().channel_depth),
        retain: false,
        refresh: None,
    };

    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("cannot bind {addr}"))?;
    let wire = o.wire.unwrap_or(thapi::remote::VERSION);
    let tele = o.telemetry();
    if let Some(t) = &o.telemetry_addr {
        eprintln!("iprof: telemetry endpoint on {t} (scrape /metrics, or: iprof health {t})");
    }

    let r = if let Some(n) = o.subscribers {
        // Broadcast session: one pump fills a shared replay ring, every
        // accepted connection is served on its own thread with its own
        // cursors/wire/dictionary (docs/PROTOCOL.md § Broadcast). The
        // ring budget reuses --resume-buffer (default 64 MiB): broadcast
        // connections are resumable by construction.
        let budget = o.resume_buffer.unwrap_or(64 << 20);
        eprintln!(
            "iprof: serving {name} on {} — broadcast to {n} subscriber(s), ring {budget}B{}",
            listener.local_addr()?,
            match o.max_lag {
                Some(l) => format!(", lag budget {l}B"),
                None => String::new(),
            },
        );
        listener
            .set_nonblocking(true)
            .context("cannot poll the listener")?;
        let mut kill_budget = o.kill_after; // fault injection: first conn only
        let accept = move || -> std::io::Result<Option<thapi::remote::KillAfter<std::net::TcpStream>>> {
            match listener.accept() {
                Ok((conn, peer)) => {
                    conn.set_nonblocking(false)?;
                    eprintln!("iprof: subscriber {peer} connected");
                    let budget = kill_budget.take().unwrap_or(usize::MAX);
                    Ok(Some(thapi::remote::KillAfter::new(conn, budget)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    Ok(None)
                }
                Err(e) => Err(e),
            }
        };
        coordinator::run_serve_broadcast(
            &node, w.as_ref(), &config, &live_cfg, accept, n, budget, o.max_lag, wire, &tele,
        )
        .context("publishing failed")?
    } else if let Some(resume_buffer) = o.resume_buffer {
        // Resumable session: poll for subscribers so the publisher can
        // keep draining the hub into its replay ring while nobody (or
        // nobody *anymore*) is attached; a reconnecting subscriber
        // resumes from its cursors (docs/PROTOCOL.md § Session
        // resumption).
        eprintln!(
            "iprof: serving {name} on {} — resumable session, replay ring {resume_buffer}B \
             (iprof attach --reconnect <n>)",
            listener.local_addr()?
        );
        listener
            .set_nonblocking(true)
            .context("cannot poll the listener")?;
        let mut kill_budget = o.kill_after; // fault injection: first conn only
        let accept = move || -> std::io::Result<Option<thapi::remote::KillAfter<std::net::TcpStream>>> {
            match listener.accept() {
                Ok((conn, peer)) => {
                    conn.set_nonblocking(false)?;
                    eprintln!("iprof: subscriber {peer} connected");
                    let budget = kill_budget.take().unwrap_or(usize::MAX);
                    Ok(Some(thapi::remote::KillAfter::new(conn, budget)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    Ok(None)
                }
                Err(e) => Err(e),
            }
        };
        coordinator::run_serve_resumable(
            &node, w.as_ref(), &config, &live_cfg, accept, resume_buffer, wire, &tele,
        )
        .context("publishing failed")?
    } else {
        eprintln!(
            "iprof: serving {name} on {} — waiting for one subscriber (iprof attach)",
            listener.local_addr()?
        );
        let (conn, peer) = listener.accept().context("accept failed")?;
        eprintln!("iprof: subscriber {peer} connected, running {name} [{}]", w.backend());
        coordinator::run_serve(&node, w.as_ref(), &config, &live_cfg, conn, wire, &tele)
            .context("publishing failed")?
    };

    eprintln!(
        "iprof: {name}: wall={:.3}s events={} relayed={} ({} frames, {} batches, {}B, wire v{wire}) \
         dropped={} (ring {} + channel {}) beacons={} connections={} replayed={} gaps={}",
        r.wall.as_secs_f64(),
        r.stats.written,
        r.publish.events,
        r.publish.frames,
        r.publish.batches,
        r.publish.bytes,
        r.total_dropped(),
        r.stats.dropped,
        r.live.dropped,
        r.publish.beacons,
        r.publish.connections,
        r.publish.replayed,
        r.publish.gaps,
    );
    for s in &r.subscribers {
        eprintln!(
            "iprof: subscriber {}: wire=v{} forwarded={} lagged={} demoted={} disconnects={}{}",
            s.id,
            s.wire,
            s.forwarded,
            s.lagged,
            s.demoted,
            s.disconnects,
            match &s.error {
                Some(e) => format!(" DIED ({e})"),
                None => String::new(),
            },
        );
    }
    for reason in &r.disconnects {
        if o.subscribers.is_some() {
            eprintln!(
                "iprof: subscriber connection lost ({reason}) — other subscribers unaffected"
            );
        } else {
            eprintln!("iprof: subscriber connection lost ({reason}) — session resumed");
        }
    }
    if o.live_strict && (r.total_dropped() > 0 || r.publish.gaps > 0) {
        bail!(
            "serve: {} events dropped ({} at rings, {} at channels of depth {}), {} lost to \
             resume gaps (ring of {}B)",
            r.total_dropped(),
            r.stats.dropped,
            r.live.dropped,
            live_cfg.channel_depth,
            r.publish.gaps,
            o.resume_buffer.unwrap_or(0),
        );
    }
    Ok(())
}

/// `iprof attach <addr> [<addr>...] [-a <list>] [--refresh <ms>]`:
/// subscribe to one or more publishers and run the analysis sinks here
/// over the merged union of all their streams (multi-publisher fan-in).
fn attach_main(args: &[String]) -> Result<()> {
    let addrs: Vec<&String> = args.iter().take_while(|a| !a.starts_with('-')).collect();
    if addrs.is_empty() {
        bail!(
            "attach needs at least one publisher address \
             (e.g. iprof attach 127.0.0.1:7007 [127.0.0.1:7008 ...])"
        );
    }
    let o = parse_args(&args[addrs.len()..])?;
    if !o.workloads.is_empty() {
        bail!("attach analyzes remote runs; it takes no workload");
    }
    if o.analyses.is_empty() {
        bail!("attach needs at least one analysis sink (-a tally,...)");
    }
    if o.resume_buffer.is_some()
        || o.kill_after.is_some()
        || o.subscribers.is_some()
        || o.max_lag.is_some()
    {
        bail!(
            "--resume-buffer/--kill-after/--subscribers/--max-lag belong to the publisher: \
             pass them to iprof serve"
        );
    }
    if o.wire.is_some() {
        bail!("--wire belongs to the publisher: pass it to iprof serve (the subscriber learns the version from the preamble)");
    }
    if o.label.is_some() {
        bail!("--label names a relay node: pass it to iprof relay");
    }
    // Every TCP attach goes through the resumable path: a writable
    // connection is what lets us answer a resumable publisher's Hello
    // with a Resume frame, and --reconnect N adds redial-with-backoff.
    let policy = thapi::remote::ReconnectPolicy {
        attempts: o.reconnect.unwrap_or(0),
        backoff: std::time::Duration::from_millis(o.backoff_ms.unwrap_or(250)),
    };
    let connectors: Vec<_> = addrs
        .iter()
        .map(|addr| {
            let addr = addr.to_string();
            move || {
                std::net::TcpStream::connect(addr.as_str()).map_err(|e| {
                    std::io::Error::new(e.kind(), format!("cannot connect to {addr}: {e}"))
                })
            }
        })
        .collect();
    eprintln!(
        "iprof: attaching to {} publisher(s) (reconnect attempts per outage: {})",
        addrs.len(),
        policy.attempts
    );
    let depth = o.live_depth.unwrap_or(LiveConfig::default().channel_depth);
    let sinks: Vec<Box<dyn AnalysisSink>> = o
        .analyses
        .iter()
        .map(|k| -> Box<dyn AnalysisSink> { k.sink() })
        .collect();
    let refresh = o.refresh_ms.map(std::time::Duration::from_millis);
    let tele = o.telemetry();
    if let Some(t) = &o.telemetry_addr {
        eprintln!("iprof: telemetry endpoint on {t} (scrape /metrics, or: iprof health {t})");
    }
    let r = coordinator::run_fanin_resumable(
        connectors,
        depth,
        policy,
        sinks,
        refresh,
        |text| {
            eprintln!("iprof: live refresh [remote]\n{text}");
        },
        &tele,
    )
    .context("attach failed")?;
    // Per-publisher accounting: who contributed what, who dropped, who died.
    // "wire drops" is the cumulative per-stream Drops ledger — for a clean
    // publisher the Eos total subsumes it, but a publisher that died before
    // Eos has ONLY the ledger, so both are shown.
    for (i, (addr, stats)) in addrs.iter().zip(&r.stats.per).enumerate() {
        let origin = &r.origins[i];
        eprintln!(
            "iprof: remote {} ({addr}): wire=v{} ({}) streams={} merged={} frames={} beacons={} \
             server received={} server dropped={} wire drops={} reconnects={} resume gaps={}{}",
            r.hostnames[i],
            stats.wire_version,
            // the negotiation outcome: the publisher picked batched v3 or
            // the per-event fallback (docs/PROTOCOL.md § Versioning)
            if stats.batches > 0 {
                format!("batched, {} batches", stats.batches)
            } else {
                "per-event fallback".to_string()
            },
            origin.channels,
            origin.received,
            stats.frames,
            stats.beacons,
            stats.server_received,
            stats.server_dropped,
            origin.remote_dropped,
            stats.reconnects,
            origin.resume_gaps,
            match &stats.error {
                Some(e) => format!(" DIED ({e})"),
                None => String::new(),
            },
        );
    }
    eprintln!(
        "iprof: union: publishers={} merged={} server received={} known dropped={} \
         latency mean={:.2}ms max={:.2}ms",
        r.stats.per.len(),
        r.latency.merged,
        r.server_received(),
        r.known_dropped(),
        r.latency.mean().as_secs_f64() * 1e3,
        r.latency.max.as_secs_f64() * 1e3,
    );
    emit_reports(
        &format!("remote-{}", safe_name(&r.hostnames.join("+"))),
        &o.analyses,
        r.reports,
    )?;
    // reports are emitted first: a dying publisher still yields the partial
    // analysis of everything received before the cut (plus everything from
    // every surviving publisher)
    if r.failed_publishers() > 0 {
        bail!(
            "attach: {} of {} publisher connection(s) ended early; reports above are partial",
            r.failed_publishers(),
            r.stats.per.len()
        );
    }
    if o.live_strict && r.known_dropped() > 0 {
        bail!(
            "attach: publishers dropped {} events — the on-line view is incomplete",
            r.known_dropped()
        );
    }
    Ok(())
}

/// `iprof relay <listen-addr> <addr> [<addr>...]`: aggregate N downstream
/// publishers into one mirror hub and re-publish the merged union
/// upstream as a resumable broadcast — the interior node of a collection
/// tree. Always speaks wire v3 upstream: per-leaf accounting travels as
/// `Origin` frames, which do not exist on the frozen v2 wire.
fn relay_main(args: &[String]) -> Result<()> {
    let addrs: Vec<&String> = args.iter().take_while(|a| !a.starts_with('-')).collect();
    if addrs.len() < 2 {
        bail!(
            "relay needs a listen address and at least one downstream publisher \
             (e.g. iprof relay 127.0.0.1:7100 127.0.0.1:7007 127.0.0.1:7008)"
        );
    }
    let o = parse_args(&args[addrs.len()..])?;
    if !o.workloads.is_empty() {
        bail!("relay forwards remote runs; it takes no workload");
    }
    if o.live || o.refresh_ms.is_some() || o.live_strict {
        bail!("--live/--refresh/--live-strict belong to the viewer: pass them to iprof attach");
    }
    if o.wire.is_some() {
        bail!(
            "--wire belongs to the edge publisher: a relay's upstream wire is always v3 \
             (Origin frames do not exist on v2)"
        );
    }
    if o.kill_after.is_some() {
        bail!("--kill-after is publisher fault injection: pass it to iprof serve");
    }
    let listen = addrs[0];
    let down = &addrs[1..];
    // Downstream side: the same resumable fan-in `iprof attach` uses.
    let policy = thapi::remote::ReconnectPolicy {
        attempts: o.reconnect.unwrap_or(0),
        backoff: std::time::Duration::from_millis(o.backoff_ms.unwrap_or(250)),
    };
    let connectors: Vec<_> = down
        .iter()
        .map(|addr| {
            let addr = addr.to_string();
            move || {
                std::net::TcpStream::connect(addr.as_str()).map_err(|e| {
                    std::io::Error::new(e.kind(), format!("cannot connect to {addr}: {e}"))
                })
            }
        })
        .collect();
    let depth = o.live_depth.unwrap_or(LiveConfig::default().channel_depth);
    // Upstream side: the same broadcast session `iprof serve
    // --subscribers` runs — resumable by construction.
    let subscribers = o.subscribers.unwrap_or(1);
    let budget = o.resume_buffer.unwrap_or(64 << 20);
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("cannot bind {listen}"))?;
    eprintln!(
        "iprof: relaying {} downstream publisher(s) on {} — broadcast to {subscribers} \
         upstream subscriber(s), ring {budget}B{} (reconnect attempts per outage: {})",
        down.len(),
        listener.local_addr()?,
        match o.max_lag {
            Some(l) => format!(", lag budget {l}B"),
            None => String::new(),
        },
        policy.attempts,
    );
    listener
        .set_nonblocking(true)
        .context("cannot poll the listener")?;
    let accept = move || -> std::io::Result<Option<std::net::TcpStream>> {
        match listener.accept() {
            Ok((conn, peer)) => {
                conn.set_nonblocking(false)?;
                eprintln!("iprof: upstream subscriber {peer} connected");
                Ok(Some(conn))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
                Ok(None)
            }
            Err(e) => Err(e),
        }
    };
    let tele = o.telemetry();
    if let Some(t) = &o.telemetry_addr {
        eprintln!("iprof: telemetry endpoint on {t} (scrape /metrics, or: iprof health {t})");
    }
    let r = coordinator::run_relay(
        connectors,
        depth,
        policy,
        o.label.as_deref(),
        accept,
        subscribers,
        budget,
        o.max_lag,
        &tele,
    )
    .context("relay failed")?;
    // Per-downstream accounting mirrors the attach summary; what the
    // relay re-publishes upstream carries the same ledgers as Origin
    // frames, so the root sees these numbers too.
    for (i, (addr, stats)) in down.iter().zip(&r.downstream.per).enumerate() {
        let origin = &r.origins[i];
        eprintln!(
            "iprof: downstream {} ({addr}): wire=v{} streams={} merged={} wire drops={} \
             reconnects={} resume gaps={}{}",
            r.hostnames[i],
            stats.wire_version,
            origin.channels,
            origin.received,
            origin.remote_dropped,
            stats.reconnects,
            origin.resume_gaps,
            match &stats.error {
                Some(e) => format!(" DIED ({e})"),
                None => String::new(),
            },
        );
    }
    eprintln!(
        "iprof: relay {}: merged={} relayed={} ({} frames, {} batches, {}B, wire v3) \
         dropped={} connections={} replayed={} gaps={}",
        r.label,
        r.local.received,
        r.publish.events,
        r.publish.frames,
        r.publish.batches,
        r.publish.bytes,
        r.local.dropped,
        r.publish.connections,
        r.publish.replayed,
        r.publish.gaps,
    );
    for s in &r.subscribers {
        eprintln!(
            "iprof: subscriber {}: wire=v{} forwarded={} lagged={} demoted={} disconnects={}{}",
            s.id,
            s.wire,
            s.forwarded,
            s.lagged,
            s.demoted,
            s.disconnects,
            match &s.error {
                Some(e) => format!(" DIED ({e})"),
                None => String::new(),
            },
        );
    }
    for reason in &r.disconnects {
        eprintln!("iprof: upstream connection lost ({reason}) — other subscribers unaffected");
    }
    if r.downstream.failed() > 0 {
        bail!(
            "relay: {} of {} downstream publisher connection(s) ended early; \
             the upstream view is partial",
            r.downstream.failed(),
            r.downstream.per.len()
        );
    }
    Ok(())
}

/// Remote hostnames arrive over the wire; keep only path-safe characters
/// before they reach a local filename (a malicious publisher must not
/// get to choose where `emit_reports` writes timeline output).
fn safe_name(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '+') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// `iprof health <addr> [--strict [--max-drops <n>]]`: scrape a
/// `--telemetry` endpoint once and render the one-screen operator
/// summary. With `--strict`, exit nonzero when the endpoint's known
/// loss (viewer drops + resume gaps + publisher-side drops) exceeds
/// `--max-drops` (default 0) — the operator-facing complement to
/// `--live-strict`, usable against a *running* pipeline.
fn health_main(args: &[String]) -> Result<()> {
    let mut addr: Option<String> = None;
    let mut strict = false;
    let mut max_drops: u64 = 0;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--strict" => strict = true,
            "--max-drops" => {
                let v = it.next().context("--max-drops needs a count")?;
                max_drops = v.parse().context("bad --max-drops value")?;
            }
            "-h" | "--help" => {
                println!("{}", HELP);
                return Ok(());
            }
            other if other.starts_with('-') => bail!("unknown option {other} (see --help)"),
            other => {
                if addr.is_some() {
                    bail!("health scrapes exactly one telemetry endpoint (got a second: {other})");
                }
                addr = Some(other.to_string());
            }
        }
    }
    let addr = addr.context(
        "health needs a telemetry endpoint address \
         (start the pipeline with --telemetry <addr>, then: iprof health <addr>)",
    )?;
    let text = telemetry::scrape(&addr).with_context(|| format!("cannot scrape {addr}"))?;
    let samples = telemetry::parse_exposition(&text)
        .map_err(|e| anyhow::anyhow!("malformed exposition from {addr}: {e}"))?;
    let health = HealthSummary::from_samples(&samples);
    print!("{}", health.render());
    if strict && health.known_loss() > max_drops {
        bail!(
            "health: known loss {} event(s) exceeds --max-drops {max_drops}",
            health.known_loss()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("serve") => return serve_main(&args[1..]),
        Some("attach") => return attach_main(&args[1..]),
        Some("relay") => return relay_main(&args[1..]),
        Some("health") => return health_main(&args[1..]),
        _ => {}
    }
    let o = parse_args(&args)?;
    if o.live {
        if !o.tracing {
            bail!("--live requires tracing (drop --no-trace)");
        }
        if o.trace_dir.is_some() {
            bail!("--live analyzes on-line and persists no trace (drop --trace-dir)");
        }
    } else if o.refresh_ms.is_some() || o.live_strict || o.live_depth.is_some() {
        bail!("--refresh/--live-depth/--live-strict only make sense with --live");
    }
    if o.resume_buffer.is_some()
        || o.kill_after.is_some()
        || o.subscribers.is_some()
        || o.max_lag.is_some()
    {
        bail!(
            "--resume-buffer/--kill-after/--subscribers/--max-lag only make sense with iprof serve"
        );
    }
    if o.reconnect.is_some() || o.backoff_ms.is_some() {
        bail!("--reconnect/--backoff only make sense with iprof attach");
    }
    if o.wire.is_some() {
        bail!("--wire only makes sense with iprof serve");
    }
    if o.label.is_some() {
        bail!("--label only makes sense with iprof relay");
    }
    if o.telemetry_addr.is_some() || o.telemetry_json.is_some() {
        bail!("--telemetry/--telemetry-json only make sense with iprof serve or iprof attach");
    }

    let registry = all_workloads();
    if o.list || o.workloads.is_empty() {
        println!("available workloads:");
        for w in &registry {
            println!("  {:<22} [{}]", w.name(), w.backend());
        }
        if o.workloads.is_empty() && !o.list {
            println!("\nrun: iprof [OPTIONS] <workload>");
        }
        return Ok(());
    }

    let node = Node::new(o.node.clone());
    let config = IprofConfig {
        tracing: o.tracing,
        mode: o.mode,
        sampling: o.sample_ms.map(|ms| SamplingConfig {
            interval: std::time::Duration::from_millis(ms),
        }),
        sink: match &o.trace_dir {
            Some(d) => SinkKind::Dir(d.clone()),
            None => SinkKind::Memory,
        },
        selected_ranks: o.ranks.clone(),
        disabled_patterns: o.filters.clone(),
        ..Default::default()
    };

    for name in &o.workloads {
        let w = registry
            .iter()
            .find(|w| w.name() == name)
            .with_context(|| format!("unknown workload {name} (try --list)"))?;
        eprintln!("iprof: running {name} [{}] config={}", w.backend(), config.label());

        if o.live {
            // On-line path: sinks run from the consumer thread while the
            // workload executes; nothing trace-sized is materialized.
            let live_cfg = LiveConfig {
                channel_depth: o.live_depth.unwrap_or(LiveConfig::default().channel_depth),
                retain: false,
                refresh: o.refresh_ms.map(std::time::Duration::from_millis),
            };
            let sinks: Vec<Box<dyn AnalysisSink + Send>> =
                o.analyses.iter().map(|k| k.sink()).collect();
            let r = coordinator::run_live(&node, w.as_ref(), &config, &live_cfg, sinks, |text| {
                eprintln!("iprof: live refresh [{name}]\n{text}");
            });
            eprintln!(
                "iprof: {name}: wall={:.3}s events={} merged={} dropped={} \
                 (ring {} + channel {}) beacons={} latency mean={:.2}ms max={:.2}ms",
                r.wall.as_secs_f64(),
                r.stats.written,
                r.latency.merged,
                r.total_dropped(),
                r.stats.dropped,
                r.live.dropped,
                r.live.beacons,
                r.latency.mean().as_secs_f64() * 1e3,
                r.latency.max.as_secs_f64() * 1e3,
            );
            emit_reports(name, &o.analyses, r.reports)?;
            if o.live_strict && r.total_dropped() > 0 {
                bail!(
                    "live: {} events dropped ({} at rings, {} at channels of depth {})",
                    r.total_dropped(),
                    r.stats.dropped,
                    r.live.dropped,
                    live_cfg.channel_depth
                );
            }
            continue;
        }

        let report = coordinator::run(&node, w.as_ref(), &config);
        eprintln!(
            "iprof: {name}: wall={:.3}s events={} dropped={} trace={}B",
            report.wall.as_secs_f64(),
            report.stats.as_ref().map(|s| s.written).unwrap_or(0),
            report.stats.as_ref().map(|s| s.dropped).unwrap_or(0),
            report.trace_bytes()
        );
        if o.analyses.is_empty() {
            continue;
        }
        if let Some(trace) = &report.trace {
            // One streaming pass drives every requested sink.
            let parsed = analysis::parse_trace(trace)?;
            let mut sinks: Vec<Box<dyn AnalysisSink + Send>> =
                o.analyses.iter().map(|k| k.sink()).collect();
            let reports = analysis::run_pipeline(&parsed, &mut sinks);
            emit_reports(name, &o.analyses, reports)?;
        }
    }
    Ok(())
}
