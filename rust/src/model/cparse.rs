//! C-header-subset parser: prototypes + typedefs -> [`ApiModel`].
//!
//! THAPI parses the real vendor headers (CUDA, Level-Zero, HIP, OpenMP)
//! to build its API model; this module does the same for the bundled
//! header subset in `assets/headers/`. Supported grammar:
//!
//! * `typedef struct _X *X;` — declares an opaque handle type `X`.
//! * `typedef enum _X { NAME = INT, ... } X;` — declares an enum with values.
//! * `RET name(TYPE p1, TYPE p2, ...);` — a function prototype (may span
//!   lines). `TYPE` is `[const] base [*...*]`.
//! * `/* ... */` and `//` comments are stripped.

use super::api::{ApiModel, CType, FnModel, Param};
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};

/// Parse a bundled header into an API model.
pub fn parse_header(src: &str) -> Result<ApiModel> {
    let clean = strip_comments(src);
    let mut model = ApiModel::default();
    let mut handles: HashSet<String> = HashSet::new();
    let mut enums: HashMap<String, Vec<(String, i64)>> = HashMap::new();

    // Statements are ';'-terminated. Enum bodies contain no ';'.
    for stmt in clean.split(';') {
        let stmt = stmt.trim();
        if stmt.is_empty() {
            continue;
        }
        if let Some(rest) = stmt.strip_prefix("typedef") {
            parse_typedef(rest.trim(), &mut handles, &mut enums)
                .with_context(|| format!("bad typedef: {stmt}"))?;
        } else {
            let f = parse_proto(stmt, &handles, &enums)
                .with_context(|| format!("bad prototype: {stmt}"))?;
            model.functions.push(f);
        }
    }
    model.enums = enums.into_iter().collect();
    model.enums.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(model)
}

fn strip_comments(src: &str) -> String {
    let mut out = String::with_capacity(src.len());
    let mut chars = src.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '/' && chars.peek() == Some(&'*') {
            chars.next();
            let mut prev = ' ';
            for c2 in chars.by_ref() {
                if prev == '*' && c2 == '/' {
                    break;
                }
                prev = c2;
            }
            out.push(' ');
        } else if c == '/' && chars.peek() == Some(&'/') {
            for c2 in chars.by_ref() {
                if c2 == '\n' {
                    out.push('\n');
                    break;
                }
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn parse_typedef(
    rest: &str,
    handles: &mut HashSet<String>,
    enums: &mut HashMap<String, Vec<(String, i64)>>,
) -> Result<()> {
    if let Some(rest) = rest.strip_prefix("struct") {
        // typedef struct _X *X   (opaque handle)  or struct body (skipped)
        if let Some(star) = rest.find('*') {
            let name = rest[star + 1..].trim().to_string();
            if name.is_empty() {
                bail!("missing handle name");
            }
            handles.insert(name);
        }
        Ok(())
    } else if let Some(rest) = rest.strip_prefix("enum") {
        let open = rest.find('{').context("enum without body")?;
        let close = rest.rfind('}').context("enum without closing brace")?;
        let body = &rest[open + 1..close];
        let name = rest[close + 1..].trim().to_string();
        let mut values = Vec::new();
        let mut next = 0i64;
        for item in body.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (vname, value) = match item.split_once('=') {
                Some((n, v)) => {
                    let value = v.trim().parse::<i64>().context("bad enum value")?;
                    (n.trim().to_string(), value)
                }
                None => (item.to_string(), next),
            };
            next = value + 1;
            values.push((vname, value));
        }
        if name.is_empty() {
            bail!("anonymous enum");
        }
        enums.insert(name, values);
        Ok(())
    } else {
        bail!("unsupported typedef kind: {rest}");
    }
}

/// Parse a type expression like `const ze_event_handle_t*` or `uint32_t`.
fn parse_type(
    expr: &str,
    handles: &HashSet<String>,
    enums: &HashMap<String, Vec<(String, i64)>>,
) -> Result<CType> {
    let mut s = expr.trim().to_string();
    // count and strip trailing stars
    let mut stars = 0;
    while s.ends_with('*') {
        s.pop();
        s = s.trim_end().to_string();
        stars += 1;
    }
    let is_const = if let Some(r) = s.strip_prefix("const ") {
        s = r.trim().to_string();
        true
    } else {
        false
    };
    // also allow stars between const and the name already handled above
    let base = match s.as_str() {
        "void" => CType::Void,
        "char" => {
            // `char*` is a C string; bare `char` unlikely in our headers
            if stars > 0 {
                let mut t = CType::CString;
                for _ in 1..stars {
                    t = CType::Ptr { inner: Box::new(t), is_const };
                }
                return Ok(t);
            }
            CType::Int { bits: 8, name: "char".into() }
        }
        "int" | "int32_t" => CType::Int { bits: 32, name: s.clone() },
        "int64_t" => CType::Int { bits: 64, name: s.clone() },
        "uint32_t" | "unsigned" | "unsigned int" | "cl_uint" => {
            CType::Uint { bits: 32, name: s.clone() }
        }
        "uint64_t" | "size_t" | "intptr_t" => CType::Uint { bits: 64, name: s.clone() },
        "float" => CType::Float { bits: 32, name: s.clone() },
        "double" => CType::Float { bits: 64, name: s.clone() },
        other => {
            if enums.contains_key(other) {
                CType::Enum { name: other.into() }
            } else if handles.contains(other) {
                CType::Handle { name: other.into() }
            } else {
                // Unknown named type (struct descriptor etc.) — opaque.
                CType::Handle { name: other.into() }
            }
        }
    };
    let mut t = base;
    for _ in 0..stars {
        t = CType::Ptr { inner: Box::new(t), is_const };
    }
    Ok(t)
}

fn parse_proto(
    stmt: &str,
    handles: &HashSet<String>,
    enums: &HashMap<String, Vec<(String, i64)>>,
) -> Result<FnModel> {
    let stmt: String = stmt.split_whitespace().collect::<Vec<_>>().join(" ");
    let open = stmt.find('(').context("no '(' in prototype")?;
    let close = stmt.rfind(')').context("no ')' in prototype")?;
    let head = stmt[..open].trim();
    let args = &stmt[open + 1..close];

    let name_start = head.rfind(|c: char| c.is_whitespace() || c == '*').map(|i| i + 1).unwrap_or(0);
    let name = head[name_start..].to_string();
    let ret_expr = head[..name_start].trim();
    let ret = parse_type(ret_expr, handles, enums)?;
    if name.is_empty() {
        bail!("missing function name");
    }

    let mut params = Vec::new();
    if args.trim() != "void" && !args.trim().is_empty() {
        for arg in args.split(',') {
            let arg: String = arg.split_whitespace().collect::<Vec<_>>().join(" ");
            // Parameter name is the last identifier; stars may be glued to it.
            let pos = arg
                .rfind(|c: char| c.is_whitespace() || c == '*')
                .context("cannot split parameter")?;
            let (ty_expr, pname) = arg.split_at(pos + 1);
            let ty = parse_type(ty_expr, handles, enums)?;
            params.push(Param { name: pname.trim().to_string(), ty });
        }
    }
    Ok(FnModel { name, ret, params })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::api::FieldType;

    const HDR: &str = r#"
        /* comment */
        typedef enum _ze_result_t { ZE_OK = 0, ZE_NOT_READY = 1, } ze_result_t;
        typedef struct _ze_driver_handle_t *ze_driver_handle_t;
        ze_result_t zeInit(uint32_t flags);
        ze_result_t zeDriverGet(uint32_t* pCount, ze_driver_handle_t* phDrivers);
        ze_result_t zeMemCopy(void* dst, const void* src, size_t size);
        ze_result_t zeName(const char* name); // trailing comment
        ze_result_t zeNoArgs(void);
    "#;

    #[test]
    fn parses_functions_and_types() {
        let m = parse_header(HDR).unwrap();
        assert_eq!(m.functions.len(), 5);
        let f = m.function("zeInit").unwrap();
        assert_eq!(f.params.len(), 1);
        assert_eq!(f.params[0].name, "flags");
        assert_eq!(f.params[0].ty.field_type(), FieldType::U64);
        assert!(matches!(f.ret, CType::Enum { .. }));
    }

    #[test]
    fn pointer_params_are_pointers() {
        let m = parse_header(HDR).unwrap();
        let f = m.function("zeDriverGet").unwrap();
        assert!(f.params[0].ty.is_pointer());
        assert!(f.params[1].ty.is_pointer());
        assert_eq!(f.params[1].name, "phDrivers");
    }

    #[test]
    fn const_void_ptr_and_cstring() {
        let m = parse_header(HDR).unwrap();
        let f = m.function("zeMemCopy").unwrap();
        assert!(matches!(&f.params[1].ty, CType::Ptr { is_const: true, .. }));
        let g = m.function("zeName").unwrap();
        assert_eq!(g.params[0].ty.field_type(), FieldType::Str);
    }

    #[test]
    fn void_arglist_is_empty() {
        let m = parse_header(HDR).unwrap();
        assert!(m.function("zeNoArgs").unwrap().params.is_empty());
    }

    #[test]
    fn enum_values_recorded() {
        let m = parse_header(HDR).unwrap();
        let (_, vals) = m.enums.iter().find(|(n, _)| n == "ze_result_t").unwrap();
        assert_eq!(vals[0], ("ZE_OK".to_string(), 0));
        assert_eq!(vals[1], ("ZE_NOT_READY".to_string(), 1));
    }

    #[test]
    fn parses_all_bundled_headers() {
        for (name, src) in super::super::headers::ALL_HEADERS {
            let m = parse_header(src).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(!m.functions.is_empty(), "{name} has no functions");
        }
    }
}
