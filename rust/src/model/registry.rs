//! Runtime tracepoint registry: stable event-class ids + name lookup.
//!
//! Built once (lazily) by running the whole generation pipeline over the
//! bundled API descriptions. Classes are leaked to `&'static` so the
//! emit hot path can hold plain references with zero refcounting.

use super::api::{Api, ApiModel, EventClass};
use super::cparse::parse_header;
use super::headers;
use super::tracepoints::{generate_classes, internal_classes};
use super::xml::parse_cl_registry;
use once_cell::sync::Lazy;
use std::collections::HashMap;

/// The global tracepoint registry.
pub struct Registry {
    classes: Vec<&'static EventClass>,
    by_name: HashMap<&'static str, &'static EventClass>,
    models: HashMap<Api, ApiModel>,
}

impl Registry {
    fn build() -> Self {
        let mut models: Vec<(Api, ApiModel)> = vec![
            (Api::Ze, parse_header(headers::ZE_HEADER).expect("ze header")),
            (Api::Cuda, parse_header(headers::CUDA_HEADER).expect("cuda header")),
            (Api::Hip, parse_header(headers::HIP_HEADER).expect("hip header")),
            (Api::Cl, parse_cl_registry(headers::CL_XML).expect("cl registry")),
            (Api::Mpi, parse_header(headers::MPI_HEADER).expect("mpi header")),
            (Api::Omp, parse_header(headers::OMP_HEADER).expect("omp header")),
        ];
        for (api, m) in models.iter_mut() {
            m.api = Some(*api);
        }

        let mut all: Vec<EventClass> = Vec::new();
        for (api, model) in &models {
            all.extend(generate_classes(*api, model));
        }
        all.extend(internal_classes());

        let mut classes: Vec<&'static EventClass> = Vec::with_capacity(all.len());
        let mut by_name = HashMap::with_capacity(all.len());
        for (id, mut c) in all.into_iter().enumerate() {
            c.id = id as u32;
            let leaked: &'static EventClass = Box::leak(Box::new(c));
            classes.push(leaked);
            by_name.insert(leaked.name.as_str(), leaked);
        }
        Registry { classes, by_name, models: models.into_iter().collect() }
    }

    /// All classes, indexed by id.
    pub fn classes(&self) -> &[&'static EventClass] {
        &self.classes
    }

    /// Look up a class by full name (`provider:function_entry`).
    pub fn class(&self, name: &str) -> Option<&'static EventClass> {
        self.by_name.get(name).copied()
    }

    /// Entry+exit classes for an API function; panics if unknown
    /// (interception wrappers resolve these once at startup).
    pub fn tp(&self, api: Api, function: &str) -> (&'static EventClass, &'static EventClass) {
        let entry = format!("{}:{function}_entry", api.provider());
        let exit = format!("{}:{function}_exit", api.provider());
        match (self.class(&entry), self.class(&exit)) {
            (Some(e), Some(x)) => (e, x),
            _ => panic!("unknown tracepoint {api:?}::{function}"),
        }
    }

    /// The parsed API model for one API (for pretty-print enum rendering
    /// and the YAML interchange tests).
    pub fn model(&self, api: Api) -> &ApiModel {
        &self.models[&api]
    }

    /// Number of registered classes (size of session enable bitmaps).
    pub fn count(&self) -> usize {
        self.classes.len()
    }
}

static REGISTRY: Lazy<Registry> = Lazy::new(Registry::build);

/// The global registry.
pub fn registry() -> &'static Registry {
    &REGISTRY
}

/// All event classes.
pub fn all_classes() -> &'static [&'static EventClass] {
    registry().classes()
}

/// Class lookup by name.
pub fn class_by_name(name: &str) -> Option<&'static EventClass> {
    registry().class(name)
}

/// Total class count.
pub fn class_count() -> usize {
    registry().count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_and_ids_are_dense() {
        let r = registry();
        assert!(r.count() > 150, "expected >150 classes, got {}", r.count());
        for (i, c) in r.classes().iter().enumerate() {
            assert_eq!(c.id as usize, i);
        }
    }

    #[test]
    fn names_are_unique() {
        let r = registry();
        let mut names: Vec<_> = r.classes().iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), r.count());
    }

    #[test]
    fn tp_lookup_returns_matching_pair() {
        let (e, x) = registry().tp(Api::Ze, "zeCommandListAppendMemoryCopy");
        assert!(e.is_entry() && x.is_exit());
        assert_eq!(e.api_function(), "zeCommandListAppendMemoryCopy");
        assert_eq!(e.api, Api::Ze);
    }

    #[test]
    #[should_panic(expected = "unknown tracepoint")]
    fn tp_lookup_panics_on_unknown() {
        registry().tp(Api::Ze, "zeDoesNotExist");
    }

    #[test]
    fn every_external_api_has_classes() {
        let r = registry();
        for api in Api::all_external() {
            assert!(
                r.classes().iter().any(|c| c.api == api),
                "no classes for {api:?}"
            );
            assert!(!r.model(api).functions.is_empty());
        }
    }

    #[test]
    fn paper_headline_tracepoints_exist() {
        // The specific tracepoints the paper's figures/case-studies rely on.
        for name in [
            "lttng_ust_ze:zeCommandListAppendMemoryCopy_entry",
            "lttng_ust_cuda:cuMemGetInfo_exit",
            "lttng_ust_hip:hipDeviceSynchronize_entry",
            "lttng_ust_ze:zeEventHostSynchronize_entry",
            "lttng_ust_profiling:command_completed",
            "lttng_ust_sampling:gpu_power",
        ] {
            assert!(class_by_name(name).is_some(), "{name} missing");
        }
    }
}
