//! Bundled API descriptions (embedded at compile time).
//!
//! THAPI consumes the vendor headers / Khronos XML shipped with the
//! toolchains; THAPI-rs bundles equivalent subsets under `assets/` and
//! embeds them so the binary is self-contained.

/// Level-Zero header subset.
pub const ZE_HEADER: &str = include_str!("../../../assets/headers/ze_api.h");
/// CUDA driver API header subset.
pub const CUDA_HEADER: &str = include_str!("../../../assets/headers/cuda.h");
/// HIP header subset.
pub const HIP_HEADER: &str = include_str!("../../../assets/headers/hip.h");
/// MPI header subset.
pub const MPI_HEADER: &str = include_str!("../../../assets/headers/mpi.h");
/// OpenMP target-offload header subset.
pub const OMP_HEADER: &str = include_str!("../../../assets/headers/omp.h");
/// OpenCL XML registry subset.
pub const CL_XML: &str = include_str!("../../../assets/cl_api.xml");

/// All C-parsed headers as (name, source) pairs.
pub const ALL_HEADERS: &[(&str, &str)] = &[
    ("ze_api.h", ZE_HEADER),
    ("cuda.h", CUDA_HEADER),
    ("hip.h", HIP_HEADER),
    ("mpi.h", MPI_HEADER),
    ("omp.h", OMP_HEADER),
];
