//! Core types of the API model and the generated trace model.

use std::fmt;

/// The programming-model APIs THAPI-rs supports (paper: OpenCL, CUDA,
/// Level-Zero, HIP, MPI, OpenMP).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Api {
    /// Intel Level-Zero (`ze*`).
    Ze,
    /// CUDA driver API (`cu*`).
    Cuda,
    /// HIP (`hip*`) — implemented on the Level-Zero backend (HIPLZ).
    Hip,
    /// OpenCL (`cl*`).
    Cl,
    /// MPI (`MPI_*`).
    Mpi,
    /// OpenMP target offload (OMPT-style callbacks, `omp_*`).
    Omp,
    /// THAPI-internal: GPU profiling pseudo-events.
    Profiling,
    /// THAPI-internal: device telemetry sampling events.
    Sampling,
}

impl Api {
    /// The LTTng provider-name prefix used in event names,
    /// e.g. `lttng_ust_ze`.
    pub fn provider(&self) -> &'static str {
        match self {
            Api::Ze => "lttng_ust_ze",
            Api::Cuda => "lttng_ust_cuda",
            Api::Hip => "lttng_ust_hip",
            Api::Cl => "lttng_ust_opencl",
            Api::Mpi => "lttng_ust_mpi",
            Api::Omp => "lttng_ust_omp",
            Api::Profiling => "lttng_ust_profiling",
            Api::Sampling => "lttng_ust_sampling",
        }
    }

    /// Short label used in tally "BACKEND_*" headers.
    pub fn backend_label(&self) -> &'static str {
        match self {
            Api::Ze => "ZE",
            Api::Cuda => "CUDA",
            Api::Hip => "HIP",
            Api::Cl => "CL",
            Api::Mpi => "MPI",
            Api::Omp => "OMP",
            Api::Profiling => "GPU",
            Api::Sampling => "SAMPLING",
        }
    }

    /// All externally traced APIs (not the internal pseudo-providers).
    pub fn all_external() -> [Api; 6] {
        [Api::Ze, Api::Cuda, Api::Hip, Api::Cl, Api::Mpi, Api::Omp]
    }
}

impl fmt::Display for Api {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.backend_label())
    }
}

/// A C type as parsed from the API headers — just enough structure to
/// drive tracepoint generation (paper Fig. 3 "API Model: params/type").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CType {
    /// `void`.
    Void,
    /// Signed integer type of the given bit width (`int`, `int64_t`, ...).
    Int { bits: u8, name: String },
    /// Unsigned integer type (`uint32_t`, `size_t`, ...).
    Uint { bits: u8, name: String },
    /// Floating-point type (`float`, `double`).
    Float { bits: u8, name: String },
    /// `char*` / `const char*` — traced as a string.
    CString,
    /// A named handle type (`ze_driver_handle_t`, `CUdeviceptr`, ...).
    Handle { name: String },
    /// An enum type (`ze_result_t`, `CUresult`, ...).
    Enum { name: String },
    /// Pointer to `inner` (`const` flag kept for in/out inference).
    Ptr { inner: Box<CType>, is_const: bool },
}

impl CType {
    /// The display name of the type (as written in the header).
    pub fn name(&self) -> String {
        match self {
            CType::Void => "void".into(),
            CType::Int { name, .. }
            | CType::Uint { name, .. }
            | CType::Float { name, .. }
            | CType::Handle { name }
            | CType::Enum { name } => name.clone(),
            CType::CString => "const char*".into(),
            CType::Ptr { inner, is_const } => {
                if *is_const {
                    format!("const {}*", inner.name())
                } else {
                    format!("{}*", inner.name())
                }
            }
        }
    }

    /// True if this is any pointer type.
    pub fn is_pointer(&self) -> bool {
        matches!(self, CType::Ptr { .. } | CType::CString)
    }

    /// The trace field type a *by-value* occurrence of this type maps to.
    pub fn field_type(&self) -> FieldType {
        match self {
            CType::Int { .. } => FieldType::I64,
            CType::Uint { .. } | CType::Enum { .. } => FieldType::U64,
            CType::Float { .. } => FieldType::F64,
            CType::Handle { .. } => FieldType::Ptr,
            CType::CString => FieldType::Str,
            CType::Ptr { .. } => FieldType::Ptr,
            CType::Void => FieldType::U64,
        }
    }
}

/// One formal parameter of an API function.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    /// Parameter name as written in the header.
    pub name: String,
    /// Parsed C type.
    pub ty: CType,
}

/// One API function in the API model.
#[derive(Debug, Clone, PartialEq)]
pub struct FnModel {
    /// Function name (`zeCommandListAppendMemoryCopy`, ...).
    pub name: String,
    /// Return type.
    pub ret: CType,
    /// Formal parameters, in order.
    pub params: Vec<Param>,
}

/// The API model for one programming model: the parsed functions plus the
/// enum values needed to pretty-print results.
#[derive(Debug, Clone, Default)]
pub struct ApiModel {
    /// Which API this model describes.
    pub api: Option<Api>,
    /// Functions, in header order.
    pub functions: Vec<FnModel>,
    /// Enum definitions: name -> (value-name, value) pairs.
    pub enums: Vec<(String, Vec<(String, i64)>)>,
}

impl ApiModel {
    /// Find a function by name.
    pub fn function(&self, name: &str) -> Option<&FnModel> {
        self.functions.iter().find(|f| f.name == name)
    }
}

/// Wire type of one trace field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldType {
    /// 32-bit unsigned.
    U32,
    /// 64-bit unsigned.
    U64,
    /// 64-bit signed.
    I64,
    /// 64-bit float.
    F64,
    /// Pointer/handle (u64, hex-rendered).
    Ptr,
    /// Length-prefixed UTF-8 string.
    Str,
}

/// One field of an event class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FieldDef {
    /// Field name (parameter name, `*param` for dereferenced out-values,
    /// or `result`).
    pub name: String,
    /// Wire type.
    pub ty: FieldType,
}

impl FieldDef {
    /// Construct a field definition.
    pub fn new(name: impl Into<String>, ty: FieldType) -> Self {
        FieldDef { name: name.into(), ty }
    }
}

/// Behavioural flags on an event class, driving tracing-mode selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassFlags {
    /// Entry/exit of a host API call.
    pub host_api: bool,
    /// A "non-spawned" polling API (e.g. `zeEventQueryStatus`) invoked in
    /// spin-lock scenarios — excluded from the *default* tracing mode.
    pub polling: bool,
    /// A device-command event (launch/append) — kept in *minimal* mode.
    pub device_command: bool,
    /// GPU profiling pseudo-event (device timings) — kept in *minimal*.
    pub profiling: bool,
    /// Telemetry sampling event.
    pub sampling: bool,
}

/// A generated event class: the runtime descriptor of one tracepoint
/// (paper Fig. 3 "Lttng Trace Model" + `TRACEPOINT_EVENT`).
#[derive(Debug, Clone)]
pub struct EventClass {
    /// Stable id assigned by the registry (index into the enable bitmap).
    pub id: u32,
    /// Full event name, e.g. `lttng_ust_ze:zeCommandListAppendMemoryCopy_entry`.
    pub name: String,
    /// Originating API.
    pub api: Api,
    /// Payload fields in wire order.
    pub fields: Vec<FieldDef>,
    /// Mode-selection flags.
    pub flags: ClassFlags,
}

impl EventClass {
    /// Test helper: build a descriptor outside the registry.
    pub fn new_for_test(name: &str, fields: Vec<FieldDef>) -> Self {
        EventClass {
            id: 0,
            name: name.into(),
            api: Api::Ze,
            fields,
            flags: ClassFlags::default(),
        }
    }

    /// The API function name this class traces (strips provider prefix and
    /// `_entry`/`_exit` suffix).
    pub fn api_function(&self) -> &str {
        let base = self.name.split(':').nth(1).unwrap_or(&self.name);
        base.strip_suffix("_entry")
            .or_else(|| base.strip_suffix("_exit"))
            .unwrap_or(base)
    }

    /// True if this is an `_entry` event.
    pub fn is_entry(&self) -> bool {
        self.name.ends_with("_entry")
    }

    /// True if this is an `_exit` event.
    pub fn is_exit(&self) -> bool {
        self.name.ends_with("_exit")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctype_names() {
        let t = CType::Ptr {
            inner: Box::new(CType::Uint { bits: 64, name: "uint64_t".into() }),
            is_const: true,
        };
        assert_eq!(t.name(), "const uint64_t*");
        assert!(t.is_pointer());
        assert_eq!(t.field_type(), FieldType::Ptr);
    }

    #[test]
    fn event_class_name_helpers() {
        let c = EventClass::new_for_test("lttng_ust_ze:zeInit_entry", vec![]);
        assert_eq!(c.api_function(), "zeInit");
        assert!(c.is_entry());
        assert!(!c.is_exit());
    }

    #[test]
    fn api_provider_prefixes() {
        assert_eq!(Api::Ze.provider(), "lttng_ust_ze");
        assert_eq!(Api::Cuda.provider(), "lttng_ust_cuda");
        assert_eq!(Api::all_external().len(), 6);
    }

    #[test]
    fn field_type_mapping() {
        assert_eq!(
            CType::Int { bits: 32, name: "int".into() }.field_type(),
            FieldType::I64
        );
        assert_eq!(CType::CString.field_type(), FieldType::Str);
        assert_eq!(
            CType::Enum { name: "ze_result_t".into() }.field_type(),
            FieldType::U64
        );
    }
}
