//! Automatic tracepoint generation (paper §3.3, Fig. 1b, Fig. 3).
//!
//! The pipeline reproduced here, end to end:
//!
//! ```text
//! API headers (assets/headers/*.h)      OpenCL XML (assets/cl_api.xml)
//!        │ [cparse]                            │ [xml]
//!        └──────────────► API model ◄──────────┘
//!                            │  + meta-parameters [metaparams]
//!                            ▼  (in/out semantics, expert knowledge)
//!                     YAML API model [yaml]   (the interchange form)
//!                            │  [tracepoints]
//!                            ▼
//!            trace model: event classes (entry/exit, typed fields)
//!                            │  [registry]
//!                            ▼
//!        runtime tracepoint registry (stable ids, enable bitmaps)
//! ```
//!
//! The interception frontends in [`crate::intercept`] resolve their event
//! classes from the registry at startup; the debug-mode [`crate::tracer::Encoder`]
//! asserts the emitted fields match the generated descriptors, so wrappers
//! cannot drift from the model.

pub mod api;
pub mod cparse;
pub mod headers;
pub mod metaparams;
pub mod registry;
pub mod tracepoints;
pub mod xml;
pub mod yaml;

pub use api::{
    Api, ApiModel, CType, ClassFlags, EventClass, FieldDef, FieldType, FnModel, Param,
};
pub use registry::{all_classes, class_by_name, class_count, registry};
