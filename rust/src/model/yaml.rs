//! Mini-YAML: the intermediary API-model interchange format (paper Fig. 3).
//!
//! THAPI parses headers into an "intermediary YAML file, that we call the
//! API model". This module provides the same stage: [`emit_api_model`]
//! serializes an [`ApiModel`] to a YAML subset, [`parse`] reads a YAML
//! subset back into a generic tree, and [`parse_api_model`] reconstructs
//! the model — round-trip tested so the interchange is lossless.
//!
//! Supported YAML subset: block maps (`key: value`), block lists
//! (`- item`), nesting by 2-space indent, plain scalars.

use super::api::{ApiModel, CType, FnModel, Param};
use anyhow::{bail, Context, Result};

/// Generic YAML tree node.
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    /// Plain scalar (kept as a string).
    Scalar(String),
    /// Ordered map.
    Map(Vec<(String, Yaml)>),
    /// Sequence.
    List(Vec<Yaml>),
}

impl Yaml {
    /// Map lookup.
    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Scalar view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Scalar(s) => Some(s),
            _ => None,
        }
    }

    /// List view.
    pub fn as_list(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::List(items) => Some(items),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

fn emit_node(node: &Yaml, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match node {
        Yaml::Scalar(s) => {
            out.push_str(s);
            out.push('\n');
        }
        Yaml::Map(entries) => {
            for (i, (k, v)) in entries.iter().enumerate() {
                if i > 0 || indent == 0 || true {
                    out.push_str(&pad);
                }
                out.push_str(k);
                out.push(':');
                match v {
                    Yaml::Scalar(s) => {
                        out.push(' ');
                        out.push_str(s);
                        out.push('\n');
                    }
                    _ => {
                        out.push('\n');
                        emit_node(v, indent + 1, out);
                    }
                }
            }
        }
        Yaml::List(items) => {
            for item in items {
                out.push_str(&pad);
                out.push_str("- ");
                match item {
                    Yaml::Scalar(s) => {
                        out.push_str(s);
                        out.push('\n');
                    }
                    Yaml::Map(entries) if !entries.is_empty() => {
                        // first entry on the dash line, rest indented
                        let (k0, v0) = &entries[0];
                        out.push_str(k0);
                        out.push(':');
                        match v0 {
                            Yaml::Scalar(s) => {
                                out.push(' ');
                                out.push_str(s);
                                out.push('\n');
                            }
                            _ => {
                                out.push('\n');
                                emit_node(v0, indent + 2, out);
                            }
                        }
                        for (k, v) in &entries[1..] {
                            out.push_str(&pad);
                            out.push_str("  ");
                            out.push_str(k);
                            out.push(':');
                            match v {
                                Yaml::Scalar(s) => {
                                    out.push(' ');
                                    out.push_str(s);
                                    out.push('\n');
                                }
                                _ => {
                                    out.push('\n');
                                    emit_node(v, indent + 2, out);
                                }
                            }
                        }
                    }
                    other => {
                        out.push('\n');
                        emit_node(other, indent + 1, out);
                    }
                }
            }
        }
    }
}

/// Serialize any YAML tree.
pub fn emit(node: &Yaml) -> String {
    let mut out = String::new();
    emit_node(node, 0, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

struct Line<'a> {
    indent: usize,
    content: &'a str,
}

fn lines(src: &str) -> Vec<Line<'_>> {
    src.lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| {
            let indent = l.len() - l.trim_start().len();
            Line { indent, content: l.trim_start() }
        })
        .collect()
}

/// Parse a YAML-subset document.
pub fn parse(src: &str) -> Result<Yaml> {
    let ls = lines(src);
    let mut pos = 0;
    let node = parse_block(&ls, &mut pos, 0)?;
    if pos != ls.len() {
        bail!("trailing content at line index {pos}");
    }
    Ok(node)
}

fn parse_block(ls: &[Line<'_>], pos: &mut usize, indent: usize) -> Result<Yaml> {
    if *pos >= ls.len() {
        bail!("empty block");
    }
    if ls[*pos].content.starts_with("- ") || ls[*pos].content == "-" {
        // list block
        let mut items = Vec::new();
        while *pos < ls.len() && ls[*pos].indent == indent && ls[*pos].content.starts_with('-') {
            let rest = ls[*pos].content[1..].trim_start();
            if rest.is_empty() {
                *pos += 1;
                items.push(parse_block(ls, pos, indent + 2)?);
            } else if let Some((k, v)) = split_kv(rest) {
                // inline first map entry; subsequent entries at indent+2
                *pos += 1;
                let mut entries = vec![];
                if v.is_empty() {
                    entries.push((k.to_string(), parse_empty_value(ls, pos, indent + 2)?));
                } else {
                    entries.push((k.to_string(), Yaml::Scalar(v.to_string())));
                }
                while *pos < ls.len()
                    && ls[*pos].indent == indent + 2
                    && !ls[*pos].content.starts_with('-')
                {
                    let (k2, v2) = split_kv(ls[*pos].content)
                        .context("expected key: value inside list map")?;
                    *pos += 1;
                    if v2.is_empty() {
                        entries.push((k2.to_string(), parse_empty_value(ls, pos, indent + 2)?));
                    } else {
                        entries.push((k2.to_string(), Yaml::Scalar(v2.to_string())));
                    }
                }
                items.push(Yaml::Map(entries));
            } else {
                *pos += 1;
                items.push(Yaml::Scalar(rest.to_string()));
            }
        }
        Ok(Yaml::List(items))
    } else {
        // map block
        let mut entries = Vec::new();
        while *pos < ls.len() && ls[*pos].indent == indent && !ls[*pos].content.starts_with('-') {
            let (k, v) = split_kv(ls[*pos].content).context("expected key: value")?;
            *pos += 1;
            if v.is_empty() {
                let child_indent = if *pos < ls.len() { ls[*pos].indent } else { indent };
                if child_indent <= indent {
                    entries.push((k.to_string(), Yaml::Scalar(String::new())));
                } else {
                    let val = parse_block(ls, pos, child_indent)?;
                    entries.push((k.to_string(), val));
                }
            } else {
                entries.push((k.to_string(), Yaml::Scalar(v.to_string())));
            }
        }
        if entries.is_empty() {
            bail!("expected map entries at indent {indent}");
        }
        Ok(Yaml::Map(entries))
    }
}

/// Parse the value of a `key:` line with nothing after the colon: a
/// nested block if the next line is more indented than `key_indent`,
/// otherwise an empty list (the shape our emitter produces for empty
/// sequences — it writes nothing).
fn parse_empty_value(ls: &[Line<'_>], pos: &mut usize, key_indent: usize) -> Result<Yaml> {
    match ls.get(*pos) {
        Some(next) if next.indent > key_indent => parse_block(ls, pos, next.indent),
        _ => Ok(Yaml::List(vec![])),
    }
}

fn split_kv(s: &str) -> Option<(&str, &str)> {
    let idx = s.find(':')?;
    let (k, v) = s.split_at(idx);
    Some((k.trim(), v[1..].trim()))
}

// ---------------------------------------------------------------------------
// ApiModel <-> YAML
// ---------------------------------------------------------------------------

fn type_to_yaml(t: &CType) -> Yaml {
    match t {
        CType::Ptr { inner, is_const } => Yaml::Map(vec![
            ("kind".into(), Yaml::Scalar("pointer".into())),
            ("const".into(), Yaml::Scalar(is_const.to_string())),
            ("type".into(), type_to_yaml(inner)),
        ]),
        other => Yaml::Map(vec![
            ("kind".into(), Yaml::Scalar(kind_name(other).into())),
            ("name".into(), Yaml::Scalar(other.name())),
        ]),
    }
}

fn kind_name(t: &CType) -> &'static str {
    match t {
        CType::Void => "void",
        CType::Int { .. } => "int",
        CType::Uint { .. } => "unsigned",
        CType::Float { .. } => "float",
        CType::CString => "cstring",
        CType::Handle { .. } => "handle",
        CType::Enum { .. } => "enum",
        CType::Ptr { .. } => "pointer",
    }
}

fn yaml_to_type(y: &Yaml) -> Result<CType> {
    let kind = y.get("kind").and_then(Yaml::as_str).context("type missing kind")?;
    Ok(match kind {
        "pointer" => {
            let is_const = y.get("const").and_then(Yaml::as_str) == Some("true");
            let inner = yaml_to_type(y.get("type").context("pointer missing inner type")?)?;
            CType::Ptr { inner: Box::new(inner), is_const }
        }
        "void" => CType::Void,
        "cstring" => CType::CString,
        other => {
            let name = y.get("name").and_then(Yaml::as_str).context("type missing name")?;
            match other {
                "int" => CType::Int { bits: bits_of(name), name: name.into() },
                "unsigned" => CType::Uint { bits: bits_of(name), name: name.into() },
                "float" => CType::Float {
                    bits: if name == "double" { 64 } else { 32 },
                    name: name.into(),
                },
                "handle" => CType::Handle { name: name.into() },
                "enum" => CType::Enum { name: name.into() },
                _ => bail!("unknown type kind {other}"),
            }
        }
    })
}

fn bits_of(name: &str) -> u8 {
    if name.contains("64") || name == "size_t" || name == "intptr_t" {
        64
    } else if name == "char" {
        8
    } else {
        32
    }
}

/// Serialize an API model to the intermediary YAML form.
pub fn emit_api_model(model: &ApiModel) -> String {
    let fns: Vec<Yaml> = model
        .functions
        .iter()
        .map(|f| {
            Yaml::Map(vec![
                ("name".into(), Yaml::Scalar(f.name.clone())),
                ("type".into(), type_to_yaml(&f.ret)),
                (
                    "params".into(),
                    if f.params.is_empty() {
                        Yaml::List(vec![])
                    } else {
                        Yaml::List(
                            f.params
                                .iter()
                                .map(|p| {
                                    Yaml::Map(vec![
                                        ("name".into(), Yaml::Scalar(p.name.clone())),
                                        ("type".into(), type_to_yaml(&p.ty)),
                                    ])
                                })
                                .collect(),
                        )
                    },
                ),
            ])
        })
        .collect();
    let enums: Vec<Yaml> = model
        .enums
        .iter()
        .map(|(name, vals)| {
            Yaml::Map(vec![
                ("name".into(), Yaml::Scalar(name.clone())),
                (
                    "values".into(),
                    Yaml::List(
                        vals.iter()
                            .map(|(n, v)| Yaml::Scalar(format!("{n}={v}")))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    emit(&Yaml::Map(vec![
        ("functions".into(), Yaml::List(fns)),
        ("enums".into(), Yaml::List(enums)),
    ]))
}

/// Parse the intermediary YAML form back into an API model.
pub fn parse_api_model(src: &str) -> Result<ApiModel> {
    let doc = parse(src)?;
    let mut model = ApiModel::default();
    if let Some(fns) = doc.get("functions").and_then(Yaml::as_list) {
        for f in fns {
            let name = f.get("name").and_then(Yaml::as_str).context("fn missing name")?;
            let ret = yaml_to_type(f.get("type").context("fn missing type")?)?;
            let mut params = Vec::new();
            if let Some(ps) = f.get("params").and_then(Yaml::as_list) {
                for p in ps {
                    let pname =
                        p.get("name").and_then(Yaml::as_str).context("param missing name")?;
                    let ty = yaml_to_type(p.get("type").context("param missing type")?)?;
                    params.push(Param { name: pname.into(), ty });
                }
            }
            model.functions.push(FnModel { name: name.into(), ret, params });
        }
    }
    if let Some(enums) = doc.get("enums").and_then(Yaml::as_list) {
        for e in enums {
            let name = e.get("name").and_then(Yaml::as_str).context("enum missing name")?;
            let mut vals = Vec::new();
            if let Some(vs) = e.get("values").and_then(Yaml::as_list) {
                for v in vs {
                    let s = v.as_str().context("enum value not scalar")?;
                    let (n, val) = s.split_once('=').context("enum value missing '='")?;
                    vals.push((n.to_string(), val.parse::<i64>()?));
                }
            }
            model.enums.push((name.into(), vals));
        }
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cparse::parse_header;
    use crate::model::headers::ALL_HEADERS;

    #[test]
    fn scalar_map_roundtrip() {
        let doc = Yaml::Map(vec![
            ("a".into(), Yaml::Scalar("1".into())),
            ("b".into(), Yaml::List(vec![Yaml::Scalar("x".into()), Yaml::Scalar("y".into())])),
        ]);
        let text = emit(&doc);
        let back = parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn nested_list_of_maps_roundtrip() {
        let doc = Yaml::Map(vec![(
            "items".into(),
            Yaml::List(vec![
                Yaml::Map(vec![
                    ("name".into(), Yaml::Scalar("first".into())),
                    ("v".into(), Yaml::Scalar("1".into())),
                ]),
                Yaml::Map(vec![
                    ("name".into(), Yaml::Scalar("second".into())),
                    (
                        "inner".into(),
                        Yaml::Map(vec![("k".into(), Yaml::Scalar("v".into()))]),
                    ),
                ]),
            ]),
        )]);
        let text = emit(&doc);
        let back = parse(&text).unwrap();
        assert_eq!(doc, back, "emitted:\n{text}");
    }

    #[test]
    fn api_model_roundtrips_for_every_header() {
        for (name, src) in ALL_HEADERS {
            let model = parse_header(src).unwrap();
            let yaml = emit_api_model(&model);
            let back = parse_api_model(&yaml).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(model.functions, back.functions, "{name} functions drifted");
            assert_eq!(model.enums, back.enums, "{name} enums drifted");
        }
    }

    #[test]
    fn cl_registry_model_roundtrips() {
        let model = crate::model::xml::parse_cl_registry(crate::model::headers::CL_XML).unwrap();
        let yaml = emit_api_model(&model);
        let back = parse_api_model(&yaml).unwrap();
        assert_eq!(model.functions, back.functions);
    }
}
