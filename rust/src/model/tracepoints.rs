//! Trace-model generation: API model + meta-parameters -> event classes.
//!
//! This is the paper's Fig. 3 transformation: for every API function an
//! `_entry` class (all by-value params plus entry-side meta fields) and an
//! `_exit` class (the result plus the values written through out
//! pointers). Class flags (polling / device-command) come from the
//! [`metaparams`](super::metaparams) rule tables and drive tracing modes.

use super::api::{Api, ApiModel, ClassFlags, CType, EventClass, FieldDef, FieldType};
use super::metaparams::{is_device_command, is_polling, metaparams};

/// Generate entry+exit event classes for every function of `model`.
/// Ids are assigned later by the registry; here they are left 0.
pub fn generate_classes(api: Api, model: &ApiModel) -> Vec<EventClass> {
    let mut out = Vec::with_capacity(model.functions.len() * 2);
    for f in &model.functions {
        let metas = metaparams(api, &f.name);
        let flags = ClassFlags {
            host_api: true,
            polling: is_polling(api, &f.name),
            device_command: is_device_command(api, &f.name),
            profiling: false,
            sampling: false,
        };

        let mut entry_fields = Vec::with_capacity(f.params.len() + 1);
        for p in &f.params {
            entry_fields.push(FieldDef::new(p.name.clone(), p.ty.field_type()));
        }
        for m in metas.iter().filter(|m| m.at_entry()) {
            entry_fields.push(FieldDef::new(m.field_name(), m.field_type()));
        }

        let mut exit_fields = Vec::new();
        if f.ret != CType::Void {
            exit_fields.push(FieldDef::new("result", f.ret.field_type()));
        }
        for m in metas.iter().filter(|m| !m.at_entry()) {
            exit_fields.push(FieldDef::new(m.field_name(), m.field_type()));
        }

        out.push(EventClass {
            id: 0,
            name: format!("{}:{}_entry", api.provider(), f.name),
            api,
            fields: entry_fields,
            flags,
        });
        out.push(EventClass {
            id: 0,
            name: format!("{}:{}_exit", api.provider(), f.name),
            api,
            fields: exit_fields,
            flags,
        });
    }
    out
}

/// The hand-defined internal classes: GPU-profiling pseudo-events emitted
/// by the profiling helpers at synchronization points, and the telemetry
/// sampling events emitted by the daemon (paper §3.5).
pub fn internal_classes() -> Vec<EventClass> {
    let prof_flags = ClassFlags { profiling: true, ..Default::default() };
    let samp_flags = ClassFlags { sampling: true, ..Default::default() };
    vec![
        // Device command completed: timings in host-clock ns, captured at
        // synchronize (paper: "Level-Zero profiling / get the info during
        // wait").
        EventClass {
            id: 0,
            name: "lttng_ust_profiling:command_completed".into(),
            api: Api::Profiling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("engine_ordinal", FieldType::U32),
                FieldDef::new("engine_kind", FieldType::U32), // 0=compute 1=copy
                FieldDef::new("kind", FieldType::Str),        // kernel|memcpy|barrier
                FieldDef::new("name", FieldType::Str),        // kernel name or ""
                FieldDef::new("queue", FieldType::Ptr),
                FieldDef::new("ts_start", FieldType::U64),
                FieldDef::new("ts_end", FieldType::U64),
                FieldDef::new("bytes", FieldType::U64),
            ],
            flags: prof_flags,
        },
        EventClass {
            id: 0,
            name: "lttng_ust_sampling:gpu_power".into(),
            api: Api::Sampling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("domain", FieldType::U32),
                FieldDef::new("watts", FieldType::F64),
                FieldDef::new("energy_uj", FieldType::U64),
            ],
            flags: samp_flags,
        },
        EventClass {
            id: 0,
            name: "lttng_ust_sampling:gpu_frequency".into(),
            api: Api::Sampling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("domain", FieldType::U32),
                FieldDef::new("mhz", FieldType::F64),
            ],
            flags: samp_flags,
        },
        EventClass {
            id: 0,
            name: "lttng_ust_sampling:gpu_engine_util".into(),
            api: Api::Sampling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("engine_kind", FieldType::U32), // 0=compute 1=copy
                FieldDef::new("domain", FieldType::U32),      // tile
                FieldDef::new("util", FieldType::F64),        // 0..1
            ],
            flags: samp_flags,
        },
        EventClass {
            id: 0,
            name: "lttng_ust_sampling:gpu_memory".into(),
            api: Api::Sampling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("used_bytes", FieldType::U64),
                FieldDef::new("total_bytes", FieldType::U64),
            ],
            flags: samp_flags,
        },
        // Tile-to-tile fabric traffic counters.
        EventClass {
            id: 0,
            name: "lttng_ust_sampling:gpu_fabric".into(),
            api: Api::Sampling,
            fields: vec![
                FieldDef::new("device", FieldType::Ptr),
                FieldDef::new("tx_bytes", FieldType::U64),
                FieldDef::new("rx_bytes", FieldType::U64),
            ],
            flags: samp_flags,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cparse::parse_header;
    use crate::model::headers::CUDA_HEADER;

    fn cuda_model() -> ApiModel {
        parse_header(CUDA_HEADER).unwrap()
    }

    #[test]
    fn cu_mem_get_info_generates_fig3_classes() {
        // Paper Fig. 3: cuMemGetInfo_entry carries the two pointers;
        // cuMemGetInfo_exit carries cuResult + *free + *total.
        let classes = generate_classes(Api::Cuda, &cuda_model());
        let entry = classes
            .iter()
            .find(|c| c.name == "lttng_ust_cuda:cuMemGetInfo_entry")
            .unwrap();
        assert_eq!(entry.fields.len(), 2);
        assert_eq!(entry.fields[0].name, "free");
        assert_eq!(entry.fields[0].ty, FieldType::Ptr);
        let exit = classes
            .iter()
            .find(|c| c.name == "lttng_ust_cuda:cuMemGetInfo_exit")
            .unwrap();
        assert_eq!(exit.fields.len(), 3);
        assert_eq!(exit.fields[0].name, "result");
        assert_eq!(exit.fields[1].name, "*free");
        assert_eq!(exit.fields[1].ty, FieldType::U64);
        assert_eq!(exit.fields[2].name, "*total");
    }

    #[test]
    fn every_function_gets_entry_and_exit() {
        let model = cuda_model();
        let classes = generate_classes(Api::Cuda, &model);
        assert_eq!(classes.len(), model.functions.len() * 2);
        for f in &model.functions {
            assert!(classes.iter().any(|c| c.name.ends_with(&format!("{}_entry", f.name))));
            assert!(classes.iter().any(|c| c.name.ends_with(&format!("{}_exit", f.name))));
        }
    }

    #[test]
    fn polling_flag_set_on_query_classes() {
        let classes = generate_classes(Api::Cuda, &cuda_model());
        let q = classes.iter().find(|c| c.name.contains("cuEventQuery_entry")).unwrap();
        assert!(q.flags.polling);
        let l = classes.iter().find(|c| c.name.contains("cuLaunchKernel_entry")).unwrap();
        assert!(!l.flags.polling);
        assert!(l.flags.device_command);
    }

    #[test]
    fn internal_classes_have_expected_names() {
        let ic = internal_classes();
        let names: Vec<_> = ic.iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"lttng_ust_profiling:command_completed"));
        assert!(names.contains(&"lttng_ust_sampling:gpu_power"));
        assert!(ic.iter().all(|c| c.flags.profiling || c.flags.sampling));
    }
}
