//! Mini XML parser for the OpenCL API registry (`assets/cl_api.xml`).
//!
//! The paper: *"For OpenCL, the structured data is accessed directly from
//! the XML API description."* This module parses the Khronos-`cl.xml`-style
//! `<command>` elements into the same [`ApiModel`] the header parser
//! produces. The parser supports exactly what the registry needs: nested
//! elements, text content, comments, and the XML declaration.

use super::api::{ApiModel, CType, FnModel, Param};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// One parsed XML element.
#[derive(Debug, Clone)]
pub struct Element {
    /// Tag name.
    pub tag: String,
    /// Child elements in order.
    pub children: Vec<Element>,
    /// Concatenated direct text content (children's text not included),
    /// in document order relative to children boundaries.
    pub text: String,
}

impl Element {
    /// First child with the given tag.
    pub fn child(&self, tag: &str) -> Option<&Element> {
        self.children.iter().find(|c| c.tag == tag)
    }

    /// All children with the given tag.
    pub fn children_named<'a>(&'a self, tag: &'a str) -> impl Iterator<Item = &'a Element> {
        self.children.iter().filter(move |c| c.tag == tag)
    }
}

/// Parse an XML document into its root element.
pub fn parse_xml(src: &str) -> Result<Element> {
    let mut pos = 0;
    let bytes = src.as_bytes();
    skip_misc(bytes, &mut pos);
    let root = parse_element(src, &mut pos)?;
    Ok(root)
}

fn skip_misc(bytes: &[u8], pos: &mut usize) {
    loop {
        while *pos < bytes.len() && (bytes[*pos] as char).is_whitespace() {
            *pos += 1;
        }
        if bytes[*pos..].starts_with(b"<?") {
            while *pos < bytes.len() && !bytes[*pos..].starts_with(b"?>") {
                *pos += 1;
            }
            *pos += 2;
        } else if bytes[*pos..].starts_with(b"<!--") {
            while *pos < bytes.len() && !bytes[*pos..].starts_with(b"-->") {
                *pos += 1;
            }
            *pos += 3;
        } else {
            return;
        }
    }
}

fn parse_element(src: &str, pos: &mut usize) -> Result<Element> {
    let bytes = src.as_bytes();
    if bytes.get(*pos) != Some(&b'<') {
        bail!("expected '<' at byte {pos}");
    }
    *pos += 1;
    let tag_start = *pos;
    while *pos < bytes.len() && !b" \t\n/>".contains(&bytes[*pos]) {
        *pos += 1;
    }
    let tag = src[tag_start..*pos].to_string();
    // skip attributes (none used by our registry, but tolerate them)
    while *pos < bytes.len() && bytes[*pos] != b'>' && !bytes[*pos..].starts_with(b"/>") {
        *pos += 1;
    }
    if bytes[*pos..].starts_with(b"/>") {
        *pos += 2;
        return Ok(Element { tag, children: vec![], text: String::new() });
    }
    *pos += 1; // consume '>'

    let mut children = Vec::new();
    let mut text = String::new();
    loop {
        if bytes[*pos..].starts_with(b"<!--") {
            while *pos < bytes.len() && !bytes[*pos..].starts_with(b"-->") {
                *pos += 1;
            }
            *pos += 3;
            continue;
        }
        if bytes[*pos..].starts_with(b"</") {
            *pos += 2;
            let end_start = *pos;
            while bytes[*pos] != b'>' {
                *pos += 1;
            }
            let end_tag = &src[end_start..*pos];
            *pos += 1;
            if end_tag != tag {
                bail!("mismatched close tag: <{tag}> vs </{end_tag}>");
            }
            return Ok(Element { tag, children, text });
        }
        if bytes[*pos] == b'<' {
            children.push(parse_element(src, pos)?);
        } else {
            let t_start = *pos;
            while *pos < bytes.len() && bytes[*pos] != b'<' {
                *pos += 1;
            }
            text.push_str(&src[t_start..*pos]);
        }
        if *pos >= bytes.len() {
            bail!("unexpected EOF inside <{tag}>");
        }
    }
}

/// Map a registry `<type>` name into a [`CType`].
fn cl_type(name: &str) -> CType {
    match name {
        "void" => CType::Void,
        "char" => CType::CString, // only appears as `char*` in the registry
        "cl_int" => CType::Int { bits: 32, name: name.into() },
        "cl_uint" => CType::Uint { bits: 32, name: name.into() },
        "size_t" | "intptr_t" => CType::Uint { bits: 64, name: name.into() },
        other => CType::Handle { name: other.into() },
    }
}

/// Parse the OpenCL registry XML into an [`ApiModel`].
pub fn parse_cl_registry(src: &str) -> Result<ApiModel> {
    let root = parse_xml(src)?;
    if root.tag != "registry" {
        bail!("root element is <{}>, expected <registry>", root.tag);
    }
    let commands = root.child("commands").context("<commands> missing")?;
    let mut model = ApiModel::default();
    for cmd in commands.children_named("command") {
        let proto = cmd.child("proto").context("<proto> missing")?;
        let ret_ty = proto.child("type").context("proto <type> missing")?;
        let name = proto.child("name").context("proto <name> missing")?;
        let mut params = Vec::new();
        for p in cmd.children_named("param") {
            let tyname = p.child("type").context("param <type> missing")?.text.trim().to_string();
            let pname = p.child("name").context("param <name> missing")?.text.trim().to_string();
            let is_const = p.text.contains("const");
            let stars = p.text.matches('*').count();
            let mut ty = cl_type(&tyname);
            // `char` + `*` is already a CString; extra stars wrap further.
            let wrap = if matches!(ty, CType::CString) { stars.saturating_sub(1) } else { stars };
            for _ in 0..wrap {
                ty = CType::Ptr { inner: Box::new(ty), is_const };
            }
            params.push(Param { name: pname, ty });
        }
        model.functions.push(FnModel {
            name: name.text.trim().to_string(),
            ret: cl_type(ret_ty.text.trim()),
            params,
        });
    }
    // The registry carries no enums; error codes are cl_int values.
    model.enums = Vec::new();
    let _unused: HashMap<(), ()> = HashMap::new();
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::api::FieldType;
    use crate::model::headers::CL_XML;

    #[test]
    fn parses_simple_document() {
        let e = parse_xml("<a><b>hi</b><b>yo</b><c/></a>").unwrap();
        assert_eq!(e.tag, "a");
        assert_eq!(e.children.len(), 3);
        assert_eq!(e.children[0].text, "hi");
        assert_eq!(e.children_named("b").count(), 2);
    }

    #[test]
    fn rejects_mismatched_tags() {
        assert!(parse_xml("<a></b>").is_err());
    }

    #[test]
    fn parses_cl_registry() {
        let m = parse_cl_registry(CL_XML).unwrap();
        assert!(m.functions.len() >= 14, "got {}", m.functions.len());
        let f = m.function("clEnqueueWriteBuffer").unwrap();
        assert_eq!(f.params.len(), 9);
        assert_eq!(f.params[4].name, "size");
        assert_eq!(f.params[4].ty.field_type(), FieldType::U64);
        assert!(f.params[5].ty.is_pointer());
    }

    #[test]
    fn cl_create_returns_handle() {
        let m = parse_cl_registry(CL_XML).unwrap();
        let f = m.function("clCreateBuffer").unwrap();
        assert!(matches!(f.ret, CType::Handle { .. }));
    }

    #[test]
    fn pointer_and_const_markers() {
        let m = parse_cl_registry(CL_XML).unwrap();
        let f = m.function("clCreateKernel").unwrap();
        // const char* kernel_name -> string field
        assert_eq!(f.params[1].ty.field_type(), FieldType::Str);
    }
}
