//! Meta-parameters: the "expert knowledge" layer of the API model.
//!
//! Headers alone cannot say whether a pointer is in or out, or that the
//! value *behind* a pointer should be recorded (paper §3.3, Scenario 2 /
//! Fig. 3 "Meta-parameter" block, e.g. `cuMemGetInfo: [OutScalar, free]`).
//! This module is that supplementary metadata for every bundled API, plus
//! the behavioural rule tables (polling APIs, device commands) that drive
//! tracing-mode selection.

use super::api::{Api, FieldType};

/// One meta-parameter: how to enrich the generated tracepoints for a
/// single API-function parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Meta {
    /// At *exit*, record the value behind this scalar out-pointer as u64.
    OutScalarU64(&'static str),
    /// At *exit*, record the value behind this scalar out-pointer as i64.
    OutScalarI64(&'static str),
    /// At *exit*, record the handle/pointer written through this
    /// out-pointer (e.g. `*phContext`).
    OutHandle(&'static str),
    /// At *entry*, record the 8-byte value behind this in-pointer
    /// (e.g. the device pointer passed via `pArgValue`).
    InScalarU64(&'static str),
    /// At *entry*, record the `pNext` field of the struct behind this
    /// pointer (enables the §4.2 uninitialized-pNext validation).
    InStructPNext(&'static str),
}

impl Meta {
    /// The parameter this meta applies to.
    pub fn param(&self) -> &'static str {
        match self {
            Meta::OutScalarU64(p)
            | Meta::OutScalarI64(p)
            | Meta::OutHandle(p)
            | Meta::InScalarU64(p)
            | Meta::InStructPNext(p) => p,
        }
    }

    /// True if the extra field is recorded on the entry event.
    pub fn at_entry(&self) -> bool {
        matches!(self, Meta::InScalarU64(_) | Meta::InStructPNext(_))
    }

    /// The generated extra field name.
    pub fn field_name(&self) -> String {
        match self {
            Meta::InStructPNext(p) => format!("{p}_pNext"),
            m => format!("*{}", m.param()),
        }
    }

    /// The generated extra field type.
    pub fn field_type(&self) -> FieldType {
        match self {
            Meta::OutScalarU64(_) | Meta::InScalarU64(_) => FieldType::U64,
            Meta::OutScalarI64(_) => FieldType::I64,
            Meta::OutHandle(_) | Meta::InStructPNext(_) => FieldType::Ptr,
        }
    }
}

/// Meta-parameters for one API function.
pub fn metaparams(api: Api, function: &str) -> &'static [Meta] {
    use Meta::*;
    match (api, function) {
        // ---- Level-Zero --------------------------------------------------
        (Api::Ze, "zeDriverGet") => &[OutScalarU64("pCount"), OutHandle("phDrivers")],
        (Api::Ze, "zeDeviceGet") => &[OutScalarU64("pCount"), OutHandle("phDevices")],
        (Api::Ze, "zeDeviceGetProperties") => &[InStructPNext("pDeviceProperties")],
        (Api::Ze, "zeContextCreate") => &[OutHandle("phContext")],
        (Api::Ze, "zeMemAllocDevice") | (Api::Ze, "zeMemAllocHost") | (Api::Ze, "zeMemAllocShared") => {
            &[OutHandle("pptr")]
        }
        (Api::Ze, "zeCommandQueueCreate") => &[OutHandle("phCommandQueue")],
        (Api::Ze, "zeCommandListCreate") => &[OutHandle("phCommandList")],
        (Api::Ze, "zeEventPoolCreate") => &[OutHandle("phEventPool")],
        (Api::Ze, "zeEventCreate") => &[OutHandle("phEvent")],
        (Api::Ze, "zeModuleCreate") => &[OutHandle("phModule"), OutHandle("phBuildLog")],
        (Api::Ze, "zeKernelCreate") => &[OutHandle("phKernel")],
        (Api::Ze, "zeKernelSetArgumentValue") => &[InScalarU64("pArgValue")],
        // ---- CUDA --------------------------------------------------------
        (Api::Cuda, "cuDeviceGetCount") => &[OutScalarI64("count")],
        (Api::Cuda, "cuDeviceGet") => &[OutHandle("device")],
        (Api::Cuda, "cuCtxCreate") => &[OutHandle("pctx")],
        (Api::Cuda, "cuMemGetInfo") => &[OutScalarU64("free"), OutScalarU64("total")],
        (Api::Cuda, "cuMemAlloc") => &[OutHandle("dptr")],
        (Api::Cuda, "cuMemAllocHost") => &[OutHandle("pp")],
        (Api::Cuda, "cuModuleLoadData") => &[OutHandle("module")],
        (Api::Cuda, "cuModuleGetFunction") => &[OutHandle("hfunc")],
        (Api::Cuda, "cuStreamCreate") => &[OutHandle("phStream")],
        (Api::Cuda, "cuEventCreate") => &[OutHandle("phEvent")],
        // ---- HIP ---------------------------------------------------------
        (Api::Hip, "hipGetDeviceCount") => &[OutScalarI64("count")],
        (Api::Hip, "hipMalloc") => &[OutHandle("ptr")],
        (Api::Hip, "hipModuleLoad") => &[OutHandle("module")],
        (Api::Hip, "hipModuleGetFunction") => &[OutHandle("function")],
        (Api::Hip, "hipStreamCreate") => &[OutHandle("stream")],
        (Api::Hip, "hipRegisterFatBinary") => &[OutHandle("handle")],
        // ---- MPI -----------------------------------------------------------
        (Api::Mpi, "MPI_Comm_size") => &[OutScalarI64("size")],
        (Api::Mpi, "MPI_Comm_rank") => &[OutScalarI64("rank")],
        (Api::Mpi, "MPI_Isend") | (Api::Mpi, "MPI_Irecv") => &[OutHandle("request")],
        (Api::Mpi, "MPI_Test") => &[OutScalarI64("flag")],
        // ---- OpenMP --------------------------------------------------------
        (Api::Omp, "omp_target_alloc") => &[OutHandle("ptr")],
        // ---- OpenCL --------------------------------------------------------
        (Api::Cl, "clGetPlatformIDs") => &[OutScalarU64("num_platforms")],
        (Api::Cl, "clGetDeviceIDs") => &[OutScalarU64("num_devices")],
        (Api::Cl, "clCreateContext")
        | (Api::Cl, "clCreateCommandQueue")
        | (Api::Cl, "clCreateBuffer")
        | (Api::Cl, "clCreateProgramWithSource")
        | (Api::Cl, "clCreateKernel") => &[OutScalarI64("errcode_ret")],
        (Api::Cl, "clEnqueueWriteBuffer")
        | (Api::Cl, "clEnqueueReadBuffer")
        | (Api::Cl, "clEnqueueNDRangeKernel") => &[OutHandle("event")],
        _ => &[],
    }
}

/// Is this a "non-spawned" polling API (excluded from the *default*
/// tracing mode; paper §5.2: "e.g., cuQueryEvent, mpiEventReady")?
pub fn is_polling(api: Api, function: &str) -> bool {
    matches!(
        (api, function),
        (Api::Ze, "zeEventQueryStatus")
            | (Api::Cuda, "cuEventQuery")
            | (Api::Cuda, "cuStreamQuery")
            | (Api::Mpi, "MPI_Test")
    )
}

/// Is this a device-command API (kept in *minimal* mode: launches,
/// memory transfers, submissions)?
pub fn is_device_command(api: Api, function: &str) -> bool {
    let f = function;
    match api {
        Api::Ze => {
            f.starts_with("zeCommandListAppend")
                || f == "zeCommandQueueExecuteCommandLists"
                || f == "zeCommandQueueSynchronize"
        }
        Api::Cuda => {
            f.starts_with("cuMemcpy") || f == "cuLaunchKernel" || f == "cuCtxSynchronize"
        }
        Api::Hip => f == "hipMemcpy" || f == "hipLaunchKernel" || f == "hipDeviceSynchronize",
        Api::Cl => f.starts_with("clEnqueue") || f == "clFinish",
        Api::Omp => f == "ompt_target_submit" || f == "ompt_target_data_op",
        Api::Mpi => false,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cu_mem_get_info_matches_paper_fig3() {
        // Fig. 3: cuMemGetInfo: [OutScalar, free], [OutScalar, total]
        let m = metaparams(Api::Cuda, "cuMemGetInfo");
        assert_eq!(m.len(), 2);
        assert_eq!(m[0], Meta::OutScalarU64("free"));
        assert_eq!(m[1], Meta::OutScalarU64("total"));
        assert!(!m[0].at_entry());
        assert_eq!(m[0].field_name(), "*free");
    }

    #[test]
    fn pnext_meta_is_entry_side() {
        let m = metaparams(Api::Ze, "zeDeviceGetProperties");
        assert_eq!(m.len(), 1);
        assert!(m[0].at_entry());
        assert_eq!(m[0].field_name(), "pDeviceProperties_pNext");
        assert_eq!(m[0].field_type(), FieldType::Ptr);
    }

    #[test]
    fn polling_tables() {
        assert!(is_polling(Api::Ze, "zeEventQueryStatus"));
        assert!(is_polling(Api::Cuda, "cuEventQuery"));
        assert!(!is_polling(Api::Ze, "zeEventHostSynchronize"));
    }

    #[test]
    fn device_command_tables() {
        assert!(is_device_command(Api::Ze, "zeCommandListAppendMemoryCopy"));
        assert!(is_device_command(Api::Cuda, "cuLaunchKernel"));
        assert!(is_device_command(Api::Cl, "clEnqueueNDRangeKernel"));
        assert!(!is_device_command(Api::Ze, "zeMemAllocDevice"));
    }

    #[test]
    fn unknown_function_has_no_meta() {
        assert!(metaparams(Api::Ze, "zeInit").is_empty());
    }
}
