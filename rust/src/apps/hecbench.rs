//! HeCBench-like mini-app suite (paper §5.1: 70 apps from the real suite;
//! we ship 20 spanning the same archetypes).
//!
//! Archetypes and what they stress:
//! * bandwidth (saxpy, memcpy) — copy engines + big transfers
//! * compute (gemm, conv, stencil, lrn, softmax) — kernel time dominates
//! * launch-rate (reduction-cuda, miniweather) — many small submissions
//! * sync-heavy (eventspin) — `zeEventHostSynchronize` storms (HIPLZ-like)
//! * polling (queryspin) — `cuEventQuery` spin loops: events that exist
//!   only in *full* mode, separating T-full from T-default in Fig. 7/8.

use super::{scaled, Workload};
use crate::device::{AllocKind, Node};
use crate::intercept::cuda::{cu_result, CudaDriver};
use crate::intercept::hip::{memcpy_kind, HipRuntime};
use crate::intercept::omp::{OmpConfig, OmpRuntime};
use crate::intercept::opencl::ClRuntime;
use crate::intercept::ze::{ze_result, ZeDriver};
use crate::runtime::executor::f32_to_bytes;
use crate::util::Rng;
use std::sync::Arc;

// Kernel launch shapes (must match python/compile/model.py's registry).
const SAXPY_N: usize = 1 << 20;
const CONV_B: usize = 64;
const CONV_N: usize = 4096;
const CONV_K: usize = 33;
const LRN_ELEMS: usize = 32 * 64 * 256;
const STENCIL_ELEMS: usize = 512 * 512;
const MM_M: usize = 256;
const MM_K: usize = 256;
const MM_N: usize = 256;
const XENT_B: usize = 256;
const XENT_V: usize = 2048;

/// The full suite (20 apps).
pub fn suite() -> Vec<Arc<dyn Workload>> {
    vec![
        // --- Level-Zero ---
        Arc::new(ZeApp { name: "saxpy-ze", kind: ZeKind::Saxpy, iters: 30 }),
        Arc::new(ZeApp { name: "convolution1D-ze", kind: ZeKind::Conv1d, iters: 12 }),
        Arc::new(ZeApp { name: "jacobi2D-ze", kind: ZeKind::Stencil, iters: 16 }),
        Arc::new(ZeApp { name: "memcpy-ze", kind: ZeKind::MemcpyOnly, iters: 60 }),
        Arc::new(ZeApp { name: "eventspin-ze", kind: ZeKind::EventSpin, iters: 20 }),
        Arc::new(ZeApp { name: "miniweather-ze", kind: ZeKind::Mixed, iters: 8 }),
        // --- CUDA ---
        Arc::new(CudaApp { name: "saxpy-cuda", kind: CudaKind::Saxpy, iters: 30 }),
        Arc::new(CudaApp { name: "gemm-cuda", kind: CudaKind::Gemm, iters: 15 }),
        Arc::new(CudaApp { name: "softmax-cuda", kind: CudaKind::Softmax, iters: 20 }),
        Arc::new(CudaApp { name: "memcpyasync-cuda", kind: CudaKind::MemcpyAsync, iters: 40 }),
        Arc::new(CudaApp { name: "queryspin-cuda", kind: CudaKind::QuerySpin, iters: 12 }),
        Arc::new(CudaApp { name: "reduction-cuda", kind: CudaKind::LaunchStorm, iters: 60 }),
        // --- HIP on Level-Zero (HIPLZ) ---
        Arc::new(HipApp { name: "lrn-hip", kernel: "lrn", elems: LRN_ELEMS, iters: 16 }),
        Arc::new(HipApp { name: "saxpy-hip", kernel: "saxpy", elems: SAXPY_N, iters: 20 }),
        Arc::new(HipApp { name: "conv1d-hip", kernel: "conv1d", elems: CONV_B * CONV_N, iters: 10 }),
        // --- OpenCL ---
        Arc::new(ClApp { name: "gemm-cl", kind: ClKind::Gemm, iters: 12 }),
        Arc::new(ClApp { name: "saxpy-cl", kind: ClKind::Saxpy, iters: 25 }),
        Arc::new(ClApp { name: "conv1d-cl", kind: ClKind::Conv1d, iters: 10 }),
        // --- OpenMP offload ---
        Arc::new(OmpApp { name: "stencil-omp", kernel: "stencil", elems: STENCIL_ELEMS, iters: 12 }),
        Arc::new(OmpApp { name: "lrn-omp", kernel: "lrn", elems: LRN_ELEMS, iters: 12 }),
    ]
}

// ---------------------------------------------------------------------------
// Level-Zero apps
// ---------------------------------------------------------------------------

enum ZeKind {
    Saxpy,
    Conv1d,
    Stencil,
    MemcpyOnly,
    EventSpin,
    Mixed,
}

struct ZeApp {
    name: &'static str,
    kind: ZeKind,
    iters: u32,
}

struct ZeSession {
    ze: Arc<ZeDriver>,
    ctx: u64,
    dev: u64,
    queue: u64,
    list: u64,
    pool: u64,
    event: u64,
}

impl ZeSession {
    fn open(node: &Arc<Node>) -> Self {
        let ze = ZeDriver::new(node.clone());
        ze.ze_init(0);
        let mut drivers = vec![];
        ze.ze_driver_get(&mut drivers);
        let mut devices = vec![];
        ze.ze_device_get(drivers[0], &mut devices);
        let (_, ctx) = ze.ze_context_create(drivers[0]);
        let dev = devices[0];
        let (_, queue) = ze.ze_command_queue_create(ctx, dev, 0);
        let (_, list) = ze.ze_command_list_create(ctx, dev);
        let (_, pool) = ze.ze_event_pool_create(ctx, 8);
        let (_, event) = ze.ze_event_create(pool);
        ZeSession { ze, ctx, dev, queue, list, pool, event }
    }

    fn close(self) {
        self.ze.ze_event_destroy(self.event);
        self.ze.ze_event_pool_destroy(self.pool);
        self.ze.ze_command_list_destroy(self.list);
        self.ze.ze_command_queue_destroy(self.queue);
        self.ze.ze_context_destroy(self.ctx);
    }

    /// reset + fill + close + execute + synchronize
    fn run_list(&self, fill: impl FnOnce(&ZeSession)) {
        self.ze.ze_command_list_reset(self.list);
        fill(self);
        self.ze.ze_command_list_close(self.list);
        self.ze.ze_command_queue_execute_command_lists(self.queue, &[self.list]);
        self.ze.ze_command_queue_synchronize(self.queue, u64::MAX);
    }

    fn launch_kernel(&self, name: &str, args: &[u64], groups: (u32, u32, u32)) {
        let (r, module) = self.ze.ze_module_create(self.ctx, self.dev, name);
        assert_eq!(r, ze_result::SUCCESS, "module create {name}");
        let (_, kernel) = self.ze.ze_kernel_create(module, name);
        for (i, a) in args.iter().enumerate() {
            self.ze.ze_kernel_set_argument_value(kernel, i as u32, *a);
        }
        self.ze.ze_kernel_set_group_size(kernel, groups.0, groups.1, groups.2);
        self.run_list(|s| {
            s.ze.ze_command_list_append_launch_kernel(s.list, kernel, groups, 0);
        });
        self.ze.ze_kernel_destroy(kernel);
        self.ze.ze_module_destroy(module);
    }
}

impl Workload for ZeApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "ZE"
    }

    fn run(&self, node: &Arc<Node>) {
        let s = ZeSession::open(node);
        let ze = &s.ze;
        let gpu = node.gpu(0);
        let mut rng = Rng::new(0xbead + self.iters as u64);
        let iters = scaled(self.iters);
        match self.kind {
            ZeKind::Saxpy => {
                let bytes = (SAXPY_N * 4) as u64;
                let (_, ha) = ze.ze_mem_alloc_host(s.ctx, 4, 4);
                let (_, hx) = ze.ze_mem_alloc_host(s.ctx, bytes, 64);
                let (_, da) = ze.ze_mem_alloc_device(s.ctx, 4, 4, s.dev);
                let (_, dx) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                let (_, dy) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                let (_, dout) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                let mut data = vec![0f32; SAXPY_N];
                rng.fill_f32(&mut data);
                gpu.pool.write(ha, &2.0f32.to_le_bytes()).unwrap();
                gpu.pool.write(hx, &f32_to_bytes(&data)).unwrap();
                s.run_list(|s| {
                    s.ze.ze_command_list_append_memory_copy(s.list, da, ha, 4, 0);
                    s.ze.ze_command_list_append_memory_copy(s.list, dx, hx, bytes, 0);
                    s.ze.ze_command_list_append_memory_copy(s.list, dy, hx, bytes, 0);
                });
                for _ in 0..iters {
                    s.launch_kernel("saxpy", &[da, dx, dy, dout], (16, 1, 1));
                }
                s.run_list(|s| {
                    s.ze.ze_command_list_append_memory_copy(s.list, hx, dout, bytes, 0);
                });
                for p in [ha, hx, da, dx, dy, dout] {
                    ze.ze_mem_free(s.ctx, p);
                }
            }
            ZeKind::Conv1d => {
                let xb = (CONV_B * CONV_N * 4) as u64;
                let wb = (CONV_K * 4) as u64;
                let (_, hx) = ze.ze_mem_alloc_host(s.ctx, xb, 64);
                let (_, dx) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let (_, dw) = ze.ze_mem_alloc_device(s.ctx, wb, 64, s.dev);
                let (_, dbias) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let (_, dout) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let mut data = vec![0f32; CONV_B * CONV_N];
                rng.fill_f32(&mut data);
                gpu.pool.write(hx, &f32_to_bytes(&data)).unwrap();
                s.run_list(|s| {
                    s.ze.ze_command_list_append_memory_copy(s.list, dx, hx, xb, 0);
                });
                for _ in 0..iters {
                    s.launch_kernel("conv1d", &[dx, dw, dbias, dout], (CONV_B as u32 / 8, 1, 1));
                    s.run_list(|s| {
                        s.ze.ze_command_list_append_memory_copy(s.list, dx, dout, xb, 0);
                    });
                }
                for p in [hx, dx, dw, dbias, dout] {
                    ze.ze_mem_free(s.ctx, p);
                }
            }
            ZeKind::Stencil => {
                let gb = (STENCIL_ELEMS * 4) as u64;
                let (_, hg) = ze.ze_mem_alloc_host(s.ctx, gb, 64);
                let (_, dg) = ze.ze_mem_alloc_device(s.ctx, gb, 64, s.dev);
                let (_, dout) = ze.ze_mem_alloc_device(s.ctx, gb, 64, s.dev);
                let mut data = vec![0f32; STENCIL_ELEMS];
                rng.fill_f32(&mut data);
                gpu.pool.write(hg, &f32_to_bytes(&data)).unwrap();
                s.run_list(|s| {
                    s.ze.ze_command_list_append_memory_copy(s.list, dg, hg, gb, 0);
                });
                for _ in 0..iters {
                    s.launch_kernel("stencil", &[dg, dout], (8, 1, 1));
                    s.run_list(|s| {
                        s.ze.ze_command_list_append_memory_copy(s.list, dg, dout, gb, 0);
                    });
                }
                for p in [hg, dg, dout] {
                    ze.ze_mem_free(s.ctx, p);
                }
            }
            ZeKind::MemcpyOnly => {
                let bytes = 8u64 << 20;
                let (_, h) = ze.ze_mem_alloc_host(s.ctx, bytes, 64);
                let (_, d) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                for _ in 0..iters {
                    s.run_list(|s| {
                        s.ze.ze_command_list_append_memory_copy(s.list, d, h, bytes, 0);
                        s.ze.ze_command_list_append_memory_copy(s.list, h, d, bytes, 0);
                    });
                }
                ze.ze_mem_free(s.ctx, h);
                ze.ze_mem_free(s.ctx, d);
            }
            ZeKind::EventSpin => {
                // tiny kernel + event spin: sync-call-rate bound (HIPLZ-ish)
                let bytes = (SAXPY_N * 4) as u64;
                let (_, da) = ze.ze_mem_alloc_device(s.ctx, 4, 4, s.dev);
                let (_, dx) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                let (_, dout) = ze.ze_mem_alloc_device(s.ctx, bytes, 64, s.dev);
                let (r, module) = ze.ze_module_create(s.ctx, s.dev, "saxpy");
                assert_eq!(r, ze_result::SUCCESS);
                let (_, kernel) = ze.ze_kernel_create(module, "saxpy");
                for (i, a) in [da, dx, dx, dout].iter().enumerate() {
                    ze.ze_kernel_set_argument_value(kernel, i as u32, *a);
                }
                for _ in 0..iters {
                    ze.ze_command_list_reset(s.list);
                    ze.ze_event_host_reset(s.event);
                    ze.ze_command_list_append_launch_kernel(s.list, kernel, (16, 1, 1), s.event);
                    ze.ze_command_list_close(s.list);
                    ze.ze_command_queue_execute_command_lists(s.queue, &[s.list]);
                    // spin with 20µs timeouts — the §4.3 call-count shape
                    while ze.ze_event_host_synchronize(s.event, 20_000) != ze_result::SUCCESS {}
                    ze.ze_command_queue_synchronize(s.queue, u64::MAX);
                }
                ze.ze_kernel_destroy(kernel);
                ze.ze_module_destroy(module);
                for p in [da, dx, dout] {
                    ze.ze_mem_free(s.ctx, p);
                }
            }
            ZeKind::Mixed => {
                // alternating conv + stencil, checking memory info as it goes
                let xb = (CONV_B * CONV_N * 4) as u64;
                let gb = (STENCIL_ELEMS * 4) as u64;
                let (_, dx) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let (_, dw) = ze.ze_mem_alloc_device(s.ctx, (CONV_K * 4) as u64, 64, s.dev);
                let (_, dbias) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let (_, dco) = ze.ze_mem_alloc_device(s.ctx, xb, 64, s.dev);
                let (_, dg) = ze.ze_mem_alloc_device(s.ctx, gb, 64, s.dev);
                let (_, dgo) = ze.ze_mem_alloc_device(s.ctx, gb, 64, s.dev);
                for _ in 0..iters {
                    s.launch_kernel("conv1d", &[dx, dw, dbias, dco], (8, 1, 1));
                    s.launch_kernel("stencil", &[dg, dgo], (8, 1, 1));
                }
                for p in [dx, dw, dbias, dco, dg, dgo] {
                    ze.ze_mem_free(s.ctx, p);
                }
            }
        }
        s.close();
    }
}

// ---------------------------------------------------------------------------
// CUDA apps
// ---------------------------------------------------------------------------

enum CudaKind {
    Saxpy,
    Gemm,
    Softmax,
    MemcpyAsync,
    QuerySpin,
    LaunchStorm,
}

struct CudaApp {
    name: &'static str,
    kind: CudaKind,
    iters: u32,
}

impl Workload for CudaApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "CUDA"
    }

    fn run(&self, node: &Arc<Node>) {
        let cu = CudaDriver::new(node.clone());
        cu.cu_init(0);
        let (_, dev) = cu.cu_device_get(0);
        let (_, ctx) = cu.cu_ctx_create(0, dev);
        let gpu = node.gpu(0);
        let mut rng = Rng::new(0xcafe + self.iters as u64);
        let iters = scaled(self.iters);

        let load = |image: &str| -> u64 {
            let (r, module) = cu.cu_module_load_data(image);
            assert_eq!(r, cu_result::SUCCESS);
            let (_, f) = cu.cu_module_get_function(module, image);
            f
        };

        match self.kind {
            CudaKind::Saxpy => {
                let bytes = (SAXPY_N * 4) as u64;
                let (_, ha) = cu.cu_mem_alloc_host(4);
                let (_, hx) = cu.cu_mem_alloc_host(bytes);
                let (_, da) = cu.cu_mem_alloc(4);
                let (_, dx) = cu.cu_mem_alloc(bytes);
                let (_, dy) = cu.cu_mem_alloc(bytes);
                let (_, dout) = cu.cu_mem_alloc(bytes);
                let mut data = vec![0f32; SAXPY_N];
                rng.fill_f32(&mut data);
                gpu.pool.write(ha, &1.5f32.to_le_bytes()).unwrap();
                gpu.pool.write(hx, &f32_to_bytes(&data)).unwrap();
                cu.cu_memcpy_htod(da, ha, 4);
                cu.cu_memcpy_htod(dx, hx, bytes);
                cu.cu_memcpy_htod(dy, hx, bytes);
                let f = load("saxpy");
                for _ in 0..iters {
                    cu.cu_launch_kernel(f, (16, 1, 1), (256, 1, 1), 0, cu.default_stream, &[da, dx, dy, dout]);
                    cu.cu_ctx_synchronize();
                }
                cu.cu_memcpy_dtoh(hx, dout, bytes);
                for p in [da, dx, dy, dout, ha, hx] {
                    cu.cu_mem_free(p);
                }
            }
            CudaKind::Gemm => {
                let ab = (MM_M * MM_K * 4) as u64;
                let bb = (MM_K * MM_N * 4) as u64;
                let biasb = (MM_N * 4) as u64;
                let ob = (MM_M * MM_N * 4) as u64;
                let (_, da) = cu.cu_mem_alloc(ab);
                let (_, db) = cu.cu_mem_alloc(bb);
                let (_, dbias) = cu.cu_mem_alloc(biasb);
                let (_, dout) = cu.cu_mem_alloc(ob);
                let (_, h) = cu.cu_mem_alloc_host(ab.max(bb));
                let mut data = vec![0f32; MM_M * MM_K];
                rng.fill_f32(&mut data);
                gpu.pool.write(h, &f32_to_bytes(&data)).unwrap();
                cu.cu_memcpy_htod(da, h, ab);
                cu.cu_memcpy_htod(db, h, bb);
                let f = load("matmul");
                for _ in 0..iters {
                    cu.cu_launch_kernel(f, (4, 4, 4), (8, 8, 1), 0, cu.default_stream, &[da, db, dbias, dout]);
                    cu.cu_ctx_synchronize();
                }
                let (_, _free, _total) = cu.cu_mem_get_info();
                for p in [da, db, dbias, dout, h] {
                    cu.cu_mem_free(p);
                }
            }
            CudaKind::Softmax => {
                let lb = (XENT_B * XENT_V * 4) as u64;
                let labb = (XENT_B * 4) as u64;
                let (_, dl) = cu.cu_mem_alloc(lb);
                let (_, dlab) = cu.cu_mem_alloc(labb);
                let (_, dout) = cu.cu_mem_alloc(4);
                let (_, h) = cu.cu_mem_alloc_host(lb);
                let mut data = vec![0f32; XENT_B * XENT_V];
                rng.fill_f32(&mut data);
                gpu.pool.write(h, &f32_to_bytes(&data)).unwrap();
                cu.cu_memcpy_htod(dl, h, lb);
                let labels: Vec<i32> =
                    (0..XENT_B).map(|_| rng.below(XENT_V as u64) as i32).collect();
                gpu.pool.write(h, &crate::runtime::executor::i32_to_bytes(&labels)).unwrap();
                cu.cu_memcpy_htod(dlab, h, labb);
                let f = load("xent");
                for _ in 0..iters {
                    cu.cu_launch_kernel(f, (16, 1, 1), (128, 1, 1), 0, cu.default_stream, &[dl, dlab, dout]);
                    cu.cu_ctx_synchronize();
                }
                for p in [dl, dlab, dout, h] {
                    cu.cu_mem_free(p);
                }
            }
            CudaKind::MemcpyAsync => {
                let bytes = 4u64 << 20;
                let (_, stream) = cu.cu_stream_create(0);
                let (_, h) = cu.cu_mem_alloc_host(bytes);
                let (_, d) = cu.cu_mem_alloc(bytes);
                for _ in 0..iters {
                    cu.cu_memcpy_htod_async(d, h, bytes, stream);
                    cu.cu_memcpy_dtoh_async(h, d, bytes, stream);
                    cu.cu_stream_synchronize(stream);
                }
                cu.cu_stream_destroy(stream);
                cu.cu_mem_free(h);
                cu.cu_mem_free(d);
            }
            CudaKind::QuerySpin => {
                // polling archetype: cuEventQuery storms (full-mode only
                // events — the T-full vs T-default separator)
                let bytes = (SAXPY_N * 4) as u64;
                let (_, da) = cu.cu_mem_alloc(4);
                let (_, dx) = cu.cu_mem_alloc(bytes);
                let (_, dout) = cu.cu_mem_alloc(bytes);
                let (_, stream) = cu.cu_stream_create(0);
                let (_, ev) = cu.cu_event_create(0);
                let f = load("saxpy");
                for _ in 0..iters {
                    cu.cu_launch_kernel(f, (16, 1, 1), (256, 1, 1), 0, stream, &[da, dx, dx, dout]);
                    cu.cu_event_record(ev, stream);
                    while cu.cu_event_query(ev) != cu_result::SUCCESS {
                        // polite spin: on small machines a hard spin starves
                        // the engine worker entirely
                        std::thread::yield_now();
                    }
                    cu.cu_stream_synchronize(stream);
                }
                cu.cu_event_destroy(ev);
                cu.cu_stream_destroy(stream);
                for p in [da, dx, dout] {
                    cu.cu_mem_free(p);
                }
            }
            CudaKind::LaunchStorm => {
                // many small launches back-to-back: API-rate bound
                let lb = (XENT_B * XENT_V * 4) as u64;
                let (_, dl) = cu.cu_mem_alloc(lb);
                let (_, dlab) = cu.cu_mem_alloc((XENT_B * 4) as u64);
                let (_, dout) = cu.cu_mem_alloc(4);
                let f = load("xent");
                for _ in 0..iters {
                    for _ in 0..4 {
                        cu.cu_launch_kernel(f, (16, 1, 1), (128, 1, 1), 0, cu.default_stream, &[dl, dlab, dout]);
                    }
                    cu.cu_ctx_synchronize();
                }
                for p in [dl, dlab, dout] {
                    cu.cu_mem_free(p);
                }
            }
        }
        cu.cu_ctx_destroy(ctx);
    }
}

// ---------------------------------------------------------------------------
// HIP apps (HIPLZ)
// ---------------------------------------------------------------------------

struct HipApp {
    name: &'static str,
    kernel: &'static str,
    elems: usize,
    iters: u32,
}

impl Workload for HipApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "HIP"
    }

    fn run(&self, node: &Arc<Node>) {
        let hip = HipRuntime::new(ZeDriver::new(node.clone()));
        hip.hip_init(0);
        hip.hip_set_device(0);
        let (_, fat) = hip.hip_register_fat_binary(&[self.kernel]);
        let gpu = node.gpu(0);
        let bytes = (self.elems * 4) as u64;
        let host = gpu.pool.alloc(AllocKind::Host, bytes).unwrap();
        let mut rng = Rng::new(0x417 + self.iters as u64);
        let mut data = vec![0f32; self.elems];
        rng.fill_f32(&mut data);
        gpu.pool.write(host, &f32_to_bytes(&data)).unwrap();

        let iters = scaled(self.iters);
        let (_, module) = hip.hip_module_load(self.kernel);
        let (_, f) = hip.hip_module_get_function(module, self.kernel);

        // argument sets per kernel (inputs..., output)
        let args: Vec<u64> = match self.kernel {
            "lrn" => {
                let (_, dx) = hip.hip_malloc(bytes);
                let (_, dout) = hip.hip_malloc(bytes);
                hip.hip_memcpy(dx, host, bytes, memcpy_kind::H2D);
                vec![dx, dout]
            }
            "saxpy" => {
                let (_, da) = hip.hip_malloc(4);
                let (_, dx) = hip.hip_malloc(bytes);
                let (_, dy) = hip.hip_malloc(bytes);
                let (_, dout) = hip.hip_malloc(bytes);
                hip.hip_memcpy(dx, host, bytes, memcpy_kind::H2D);
                hip.hip_memcpy(dy, host, bytes, memcpy_kind::H2D);
                vec![da, dx, dy, dout]
            }
            "conv1d" => {
                let wb = (CONV_K * 4) as u64;
                let (_, dx) = hip.hip_malloc(bytes);
                let (_, dw) = hip.hip_malloc(wb);
                let (_, dbias) = hip.hip_malloc(bytes);
                let (_, dout) = hip.hip_malloc(bytes);
                hip.hip_memcpy(dx, host, bytes, memcpy_kind::H2D);
                vec![dx, dw, dbias, dout]
            }
            other => panic!("unknown hip kernel {other}"),
        };

        for _ in 0..iters {
            hip.hip_launch_kernel(f, (16, 1, 1), (64, 1, 1), 0, 0, &args);
            hip.hip_device_synchronize();
        }
        // copy back from the output (last arg)
        hip.hip_memcpy(host, *args.last().unwrap(), bytes, memcpy_kind::D2H);
        for a in &args {
            hip.hip_free(*a);
        }
        hip.hip_module_unload(module);
        hip.hip_unregister_fat_binary(fat);
        let _ = gpu.pool.free(host);
    }
}

// ---------------------------------------------------------------------------
// OpenCL apps
// ---------------------------------------------------------------------------

enum ClKind {
    Saxpy,
    Gemm,
    Conv1d,
}

struct ClApp {
    name: &'static str,
    kind: ClKind,
    iters: u32,
}

impl Workload for ClApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "CL"
    }

    fn run(&self, node: &Arc<Node>) {
        let cl = ClRuntime::new(node.clone());
        let mut platforms = vec![];
        cl.cl_get_platform_ids(&mut platforms);
        let mut devices = vec![];
        cl.cl_get_device_ids(platforms[0], &mut devices);
        let (ctx, _) = cl.cl_create_context(&devices);
        let (queue, _) = cl.cl_create_command_queue(ctx, devices[0]);
        let gpu = node.gpu(0);
        let mut rng = Rng::new(0xc1 + self.iters as u64);
        let iters = scaled(self.iters);

        let (kernel_name, buf_sizes, global): (&str, Vec<u64>, (u64, u64, u64)) = match self.kind {
            ClKind::Saxpy => (
                "saxpy",
                vec![4, (SAXPY_N * 4) as u64, (SAXPY_N * 4) as u64, (SAXPY_N * 4) as u64],
                (SAXPY_N as u64, 1, 1),
            ),
            ClKind::Gemm => (
                "matmul",
                vec![
                    (MM_M * MM_K * 4) as u64,
                    (MM_K * MM_N * 4) as u64,
                    (MM_N * 4) as u64,
                    (MM_M * MM_N * 4) as u64,
                ],
                (MM_M as u64, MM_N as u64, 1),
            ),
            ClKind::Conv1d => (
                "conv1d",
                vec![
                    (CONV_B * CONV_N * 4) as u64,
                    (CONV_K * 4) as u64,
                    (CONV_B * CONV_N * 4) as u64,
                    (CONV_B * CONV_N * 4) as u64,
                ],
                (CONV_B as u64, CONV_N as u64, 1),
            ),
        };

        let bufs: Vec<u64> = buf_sizes
            .iter()
            .map(|sz| {
                let (b, err) = cl.cl_create_buffer(ctx, 0, *sz);
                assert_eq!(err, crate::intercept::opencl::cl_error::SUCCESS);
                b
            })
            .collect();
        // fill first input
        let h = gpu.pool.alloc(AllocKind::Host, buf_sizes[0]).unwrap();
        let mut data = vec![0f32; (buf_sizes[0] / 4) as usize];
        rng.fill_f32(&mut data);
        gpu.pool.write(h, &f32_to_bytes(&data)).unwrap();
        cl.cl_enqueue_write_buffer(queue, bufs[0], true, 0, buf_sizes[0], h);

        let (program, _) = cl.cl_create_program_with_source(ctx, kernel_name);
        cl.cl_build_program(program, "-cl-fast-relaxed-math");
        let (kernel, err) = cl.cl_create_kernel(program, kernel_name);
        assert_eq!(err, crate::intercept::opencl::cl_error::SUCCESS);
        for (i, b) in bufs.iter().enumerate() {
            cl.cl_set_kernel_arg(kernel, i as u32, *b);
        }
        for _ in 0..iters {
            cl.cl_enqueue_ndrange_kernel(queue, kernel, global);
            cl.cl_flush(queue);
            cl.cl_finish(queue);
        }
        let out_h = gpu.pool.alloc(AllocKind::Host, *buf_sizes.last().unwrap()).unwrap();
        cl.cl_enqueue_read_buffer(queue, *bufs.last().unwrap(), true, 0, *buf_sizes.last().unwrap(), out_h);
        for b in bufs {
            cl.cl_release_mem_object(b);
        }
        let _ = gpu.pool.free(h);
        let _ = gpu.pool.free(out_h);
    }
}

// ---------------------------------------------------------------------------
// OpenMP offload apps
// ---------------------------------------------------------------------------

struct OmpApp {
    name: &'static str,
    kernel: &'static str,
    elems: usize,
    iters: u32,
}

impl Workload for OmpApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "OMP"
    }

    fn run(&self, node: &Arc<Node>) {
        let omp = OmpRuntime::new(ZeDriver::new(node.clone()), OmpConfig::default());
        let gpu = node.gpu(0);
        let bytes = (self.elems * 4) as u64;
        let (_, din) = omp.omp_target_alloc(bytes, 0);
        let (_, dout) = omp.omp_target_alloc(bytes, 0);
        let host = gpu.pool.alloc(AllocKind::Host, bytes).unwrap();
        let mut rng = Rng::new(0x09 + self.iters as u64);
        let mut data = vec![0f32; self.elems];
        rng.fill_f32(&mut data);
        gpu.pool.write(host, &f32_to_bytes(&data)).unwrap();
        let iters = scaled(self.iters);
        for _ in 0..iters {
            omp.omp_target_memcpy(din, host, bytes, 0, 0, 0, -1);
            omp.omp_target_submit(self.kernel, 0, 8, &[din, dout]);
            omp.omp_target_memcpy(host, dout, bytes, 0, 0, -1, 0);
        }
        omp.omp_target_sync(0);
        omp.omp_target_free(din, 0);
        omp.omp_target_free(dout, 0);
        let _ = gpu.pool.free(host);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;

    /// Every app must run to completion untraced on a small node.
    /// (Traced coverage comes from the coordinator tests and benches.)
    #[test]
    fn all_hecbench_apps_run_untraced() {
        let _g = test_support::lock();
        std::env::set_var("THAPI_APP_SCALE", "0.05");
        let node = crate::device::Node::new(NodeConfig::test_small());
        for app in suite() {
            app.run(&node);
            node.synchronize();
        }
        std::env::remove_var("THAPI_APP_SCALE");
    }
}
